"""Pallas gf_matmul kernel micro-bench (interpret mode on CPU — the numbers
are correctness-path timings, the TPU perf model lives in the roofline)."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.field import FERMAT_Q
from repro.kernels.gf_matmul import gf_matmul
from repro.kernels.ref import gf_matmul_ref


def _time(fn, reps=3):
    fn()  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        fn().block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def rows() -> list[str]:
    rng = np.random.default_rng(3)
    out = []
    for (M, K, N) in [(128, 128, 128), (256, 256, 128)]:
        a = jnp.asarray(rng.integers(0, FERMAT_Q, (M, K)).astype(np.uint32))
        b = jnp.asarray(rng.integers(0, FERMAT_Q, (K, N)).astype(np.uint32))
        us_k = _time(lambda: gf_matmul(a, b))
        us_r = _time(lambda: gf_matmul_ref(a, b))
        gf_ops = 2 * M * K * N
        out.append(f"kernel/gf_matmul_{M}x{K}x{N},{us_k:.0f},"
                   f"gf_ops={gf_ops};interp_mode=1;ref_us={us_r:.0f}")

    from repro.kernels.ntt import ntt

    for K in (256, 1024):
        W = 128
        x = jnp.asarray(rng.integers(0, FERMAT_Q, (K, W)).astype(np.uint32))
        us_n = _time(lambda: ntt(x))
        # O(K log K * W) vs the O(K^2 * W) matmul encode
        import math
        ops_ntt = K * int(math.log2(K)) * W
        ops_mm = K * K * W
        out.append(f"kernel/ntt_{K}x{W},{us_n:.0f},"
                   f"field_ops={ops_ntt};matmul_equiv_ops={ops_mm};"
                   f"algorithmic_gain={ops_mm / ops_ntt:.1f}x")
    return out
