"""Standalone (subprocess) bench: HLO collective bytes of the coded
checkpoint parity encode on an 8-device host mesh — universal vs RS-specific
scheduling.  This is the paper's Table-I C2 gain *measured in lowered XLA
collective traffic* rather than the abstract model.

Must run in its own process: forces 8 host devices before jax init.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.field import FERMAT
from repro.core.parity import build_parity_tables, mesh_parity_encode
from repro.launch.hlo_cost import analyze


def main():
    f = FERMAT
    mesh = Mesh(np.array(jax.devices()), ("d",))
    N, W = 8, 4096
    x = jnp.asarray(f.rand((N, W), np.random.default_rng(0)).astype(np.uint32))
    for method in ("universal", "rs"):
        t = build_parity_tables(f, N, 4, p=1, method=method)
        arrs = t.device_arrays()
        keys = list(arrs)

        @partial(jax.shard_map, mesh=mesh,
                 in_specs=(P("d"),) + tuple(P("d") for _ in keys),
                 out_specs=P("d"))
        def step(xb, *tb):
            rows = {k: v[0] for k, v in zip(keys, tb)}
            return mesh_parity_encode(xb[0], rows, t, "d")[None]

        args = [jnp.asarray(arrs[k]) for k in keys]
        t0 = time.perf_counter()
        lowered = jax.jit(lambda xg: step(xg, *args)).lower(x)
        compiled = lowered.compile()
        census = analyze(compiled.as_text())
        us = (time.perf_counter() - t0) * 1e6
        y = step(x, *args)  # execute once for correctness
        A = t.sgrs.grs.A_direct()
        ok = np.array_equal(np.asarray(y)[:4], f.matmul(A.T, np.asarray(x, np.int64)))
        print(f"mesh_encode/{method}_N8_R4_W{W},{us:.0f},"
              f"collective_bytes={census['collective_bytes']:.0f};"
              f"correct={int(ok)}")


if __name__ == "__main__":
    main()
