"""Standalone (subprocess) bench: HLO collective bytes of the coded
checkpoint parity encode on an 8-device host mesh — universal vs RS-specific
scheduling, planned through the unified `repro.api.Encoder`.  This is the
paper's Table-I C2 gain *measured in lowered XLA collective traffic* rather
than the abstract model.

Must run in its own process: forces 8 host devices before jax init.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import CodeSpec, Encoder
from repro.core.field import FERMAT
from repro.launch.hlo_cost import analyze


def main():
    f = FERMAT
    N, R, W = 8, 4, 4096
    x = jnp.asarray(f.rand((N, W), np.random.default_rng(0)).astype(np.uint32))
    bytes_of, all_ok = {}, 1
    for method in ("universal", "rs"):
        spec = CodeSpec(kind="rs", K=N, R=R, p=1, W=W)
        plan = Encoder.plan(spec, backend="mesh", method=method)
        step = plan.mesh_callable()
        t0 = time.perf_counter()
        compiled = jax.jit(step).lower(x).compile()
        census = analyze(compiled.as_text())
        us = (time.perf_counter() - t0) * 1e6
        y = plan.run(np.asarray(x, np.int64))  # execute once for correctness
        ok = np.array_equal(y, f.matmul(plan.A.T, np.asarray(x, np.int64)))
        c = plan.cost()
        bytes_of[method] = census["collective_bytes"]
        all_ok &= int(ok)
        print(f"mesh_encode/{method}_N{N}_R{R}_W{W},{us:.0f},"
              f"collective_bytes={census['collective_bytes']:.0f};"
              f"model_C1={c.C1};model_C2={c.C2};correct={int(ok)}")
    # stable (HLO-census, no wall clock) rows for the gated mesh/* section
    print(f"mesh/encode_bytes_gain_K{N}_R{R}_W{W},"
          f"{bytes_of['rs'] / bytes_of['universal']:.3f},"
          f"rs_bytes={bytes_of['rs']:.0f};"
          f"universal_bytes={bytes_of['universal']:.0f};backend=mesh")
    print(f"mesh/encode_ok_K{N}_R{R}_W{W},{all_ok},both schedules bitwise "
          f"vs the dense matmul;backend=mesh")


if __name__ == "__main__":
    main()
