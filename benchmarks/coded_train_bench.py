"""Coded computation under failure — the headline "training keeps its step
time while workers die" claim, measured.

Two families of rows (section `coded/*`, gated in CI):

  coded/train_step_s{0,1,2}  — wall time per gradient-coded train step
        (tiny config, 6 data-parallel workers) with s stragglers injected
        EVERY step.  Because the fractional-repetition decode is a masked
        cross-group sum with the same device program for every mask, the
        straggled step must stay within 1.25x of the fault-free one —
        that ratio is the gated row coded/straggle_ratio (max 1.25).
  coded/train_exact          — 1 if the s=2 straggled steps' parameters
        are bitwise-equal to the all-alive step's (min 1 gate).
  coded/infer_*_K8_R4        — Lagrange-coded matmul (CodedMatmul, local
        kernel backend): encode + worker products + decode wall time at
        dropout counts E = 0 / 2 / 4, and coded/infer_exact_K8_R4 = 1 iff
        every dropout count 0..R decoded Y = X @ W bitwise (min 1 gate).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.coding import CodedMatmul, GradientCoder
from repro.configs import get_config
from repro.core.field import FERMAT
from repro.data import SyntheticLM
from repro.train import (init_state, make_straggler_train_step,
                         make_train_setup)


def _time(fn, reps: int = 5) -> float:
    fn()  # warm (compile / plan-cache fill)
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def _train_rows() -> list[str]:
    import dataclasses

    cfg = dataclasses.replace(get_config("qwen3_1_7b").smoke(), n_layers=2)
    opt, _ = make_train_setup(cfg, total_steps=50, peak_lr=3e-3)
    state = init_state(cfg, jax.random.PRNGKey(0), opt)
    n = 6  # (s+1) | 6 for s in {0, 1, 2}
    batch = SyntheticLM(cfg.vocab, seq_len=32, global_batch=12).device_batch(0)

    out, walls = [], {}
    coder = GradientCoder(n, s=2)
    step = make_straggler_train_step(cfg, opt, coder)
    ref, _ = step(state, batch)  # all alive
    rng = np.random.default_rng(11)
    exact = 1
    for s_inject in (0, 1, 2):
        masks = []
        for i in range(8):  # rotate straggler patterns across reps
            dead = rng.choice(n, size=s_inject, replace=False)
            alive = np.ones(n, bool)
            alive[dead] = False
            masks.append(alive)
        it = iter(range(10 ** 9))

        def stepped():
            st, _ = step(state, batch, masks[next(it) % len(masks)])
            jax.block_until_ready(st.params)
            return st

        us = _time(stepped, reps=8)
        walls[s_inject] = us
        out.append(f"coded/train_step_s{s_inject},{us:.0f},"
                   f"workers={n};s=2;mode=every-step")
        if s_inject:
            st = stepped()
            same = all(np.array_equal(np.asarray(a), np.asarray(b))
                       for a, b in zip(jax.tree.leaves(st.params),
                                       jax.tree.leaves(ref.params)))
            exact &= int(same)
    ratio = walls[2] / walls[0]
    out.append(f"coded/straggle_ratio,{ratio:.3f},"
               f"s2_us={walls[2]:.0f};s0_us={walls[0]:.0f};max=1.25")
    out.append(f"coded/train_exact,{exact},bitwise s1+s2 vs all-alive")
    return out


def _infer_rows() -> list[str]:
    rng = np.random.default_rng(23)
    K, R, b, d, o = 8, 4, 8, 128, 128
    X = FERMAT.rand((K * b, d), rng)
    W = FERMAT.rand((d, o), rng)
    truth = FERMAT.matmul(X, W)
    out = []
    with CodedMatmul(K, R) as cm:
        shards = cm.encode(X)
        results = cm.worker_compute(shards, W)
        exact = 1
        for nd in range(R + 1):
            dead = rng.choice(K + R, size=nd, replace=False)
            exact &= int(np.array_equal(cm.decode(results, dead=dead), truth))
        for nd in (0, 2, 4):
            dead = list(range(0, 2 * nd, 2))[:nd]
            us = _time(lambda: cm(X, W, dead=dead))
            out.append(f"coded/infer_matmul_K{K}_R{R}_E{nd},{us:.0f},"
                       f"backend=local;b={b};d={d}")
        out.append(f"coded/infer_exact_K{K}_R{R},{exact},"
                   "bitwise Y=XW for all dropout counts 0..R")
    return out


def rows() -> list[str]:
    return _train_rows() + _infer_rows()
