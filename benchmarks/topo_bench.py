"""Topology perf cells: what does placement buy on a hierarchical fleet?

One scenario — rs K=16 R=4 W=64 on a Topology(5 hosts x 4 devices) —
priced under a two-tier link model while sweeping the inter/intra
bandwidth ratio.  The rows are model/simulator quantities (exact, no
wall clock), so the gate can pin them tightly:

  * per-placement inter-tier C2 (elems that cross the host network),
    measured by the round simulator and asserted == the closed form;
  * the affinity-vs-flat inter-traffic ratio (the "what the network
    saves" headline);
  * the crossover ratio: the smallest swept inter/intra cost ratio at
    which the affinity placement's best schedule is strictly cheaper
    than the flat round-robin's (at ratio 1 the tiers price equally, so
    placement cannot matter);
  * a strictly-cheaper flag at ratio 4 (the paper-style "fast intra
    fabric" regime).

All rows are deterministic; drift here means the placement logic or the
per-tier accounting changed, not the machine.
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.api import (CodeSpec, Encoder, TieredLinkModel, Topology, place,
                       tiered_encode_cost)

K, R, W = 16, 4, 64
RATIOS = (1.0, 1.5, 2.0, 3.0, 4.0, 6.0, 8.0)


def _tiers(spec, placement, link):
    """(method, TieredCost) of the auto-selected schedule under `link`."""
    plan = Encoder.plan(spec, backend="simulator", topology=placement,
                        link=link)
    return plan.method, plan.tiered_cost()


def rows():
    spec = CodeSpec(kind="rs", K=K, R=R, W=W)
    topo = Topology(hosts=5, devices_per_host=4)
    placements = {pol: place(spec, topo, pol) for pol in ("affinity", "flat")}

    # measured per-tier split at ratio 4, cross-checked against the form
    link4 = TieredLinkModel.from_ratio(4.0)
    inter_c2 = {}
    exact = 1
    for pol, pl in placements.items():
        plan = Encoder.plan(spec, backend="simulator", topology=pl,
                            link=link4)
        x = spec.field.rand((K, W), np.random.default_rng(0))
        plan.run(x)
        measured = plan.sim_net.by_tier()
        tc = plan.tiered_cost()
        model = {"intra": (tc.intra.C1, tc.intra.C2),
                 "inter": (tc.inter.C1, tc.inter.C2)}
        if measured != model:
            exact = 0
        inter_c2[pol] = measured["inter"][1]
        yield (f"topo/{pol}_inter_c2_K{K}_R{R}_W{W},{inter_c2[pol]},"
               f"method={plan.method};intra_c2={measured['intra'][1]};"
               f"model_inter_c2={model['inter'][1]};backend=simulator")
    yield (f"topo/tiers_exact_K{K}_R{R}_W{W},{exact},"
           f"model==measured per tier, both placements;backend=simulator")
    yield (f"topo/inter_c2_ratio_K{K}_R{R}_W{W},"
           f"{inter_c2['flat'] / inter_c2['affinity']:.3f},"
           f"flat={inter_c2['flat']};affinity={inter_c2['affinity']};"
           f"backend=simulator")

    # tier_commute rewrite: inter-host ROUND counts (latency, not bytes)
    # with and without the schedule-IR pass, plus an exactness flag — the
    # commuted program must produce bitwise-identical sink values and its
    # attribute() split must equal the simulator's measured per-tier counts
    pl_aff = placements["affinity"]
    base = Encoder.plan(spec, backend="simulator", topology=pl_aff)
    opt = Encoder.plan(spec, backend="simulator", topology=pl_aff,
                       commute=True)
    b_tiers = base.schedule_ir().attribute(pl_aff)
    o_tiers = opt.schedule_ir().attribute(pl_aff)
    x = spec.field.rand((K, W), np.random.default_rng(1))
    same = int(np.array_equal(base.run(x), opt.run(x)))
    measured = opt.sim_net.by_tier()
    model = {t: (c[0], c[1] * W) for t, c in o_tiers.items()}
    exact_commute = int(same and measured == model)
    yield (f"topo/rounds_inter_base_K{K}_R{R},{b_tiers['inter'][0]},"
           f"canonical inter-host rounds, affinity 5x4;"
           f"intra={b_tiers['intra'][0]};backend=simulator")
    yield (f"topo/rounds_inter_K{K}_R{R},{o_tiers['inter'][0]},"
           f"tier_commute inter-host rounds, affinity 5x4;"
           f"intra={o_tiers['intra'][0]};backend=simulator")
    yield (f"topo/commute_exact_K{K}_R{R},{exact_commute},"
           f"commuted outputs bitwise == canonical AND measured tiers == "
           f"schedule_ir().attribute();backend=simulator")

    # ratio sweep: price each placement's best schedule, find the crossover
    crossover = 0.0
    cheaper_at_4 = 0
    for ratio in RATIOS:
        link = TieredLinkModel.from_ratio(ratio)
        us = {}
        for pol, pl in placements.items():
            method, tc = _tiers(spec, pl, link)
            if tc is None:  # closed form declined: price flat (conservative)
                tc = tiered_encode_cost(spec, method, pl)
            us[pol] = link.us(tc)
        if us["affinity"] < us["flat"] and crossover == 0.0:
            crossover = ratio
        if ratio == 4.0:
            cheaper_at_4 = int(us["affinity"] < us["flat"])
            yield (f"topo/affinity_us_r4_K{K}_R{R}_W{W},"
                   f"{us['affinity']:.2f},flat_us={us['flat']:.2f};"
                   f"backend=simulator")
    yield (f"topo/crossover_ratio_K{K}_R{R},{crossover},"
           f"smallest swept inter/intra ratio with affinity strictly "
           f"cheaper;sweep={'/'.join(str(r) for r in RATIOS)};"
           f"backend=simulator")
    yield (f"topo/affinity_cheaper_r4_K{K}_R{R},{cheaper_at_4},"
           f"affinity strictly cheaper than flat at ratio 4;"
           f"backend=simulator")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in rows():
        print(row)
