"""Decode vs encode cost — the recovery half of the coded pipeline.

Two families of rows:

  recover/decode_local_*  — wall time of the cached-`DecodePlan` kernel hot
                            path (Pallas/jnp `decode_blocks`) vs the matching
                            encode (`EncodePlan` local backend) on the same
                            (K, R, W); derived carries the encode us and the
                            decode:encode ratio
  recover/decode_model_*  — the simulator's closed-form network costs
                            (C1 rounds, C2 elems/port, exact per
                            `repro.recover.decode_cost`) next to the encode
                            plan's Table-I model cost for the same spec
"""
from __future__ import annotations

import time

import numpy as np

from repro.api import CodeSpec, Encoder
from repro.core.field import FERMAT
from repro.recover import Decoder


def _time(fn, reps: int = 5) -> float:
    fn()  # warm (compile / plan-cache fill)
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def rows() -> list[str]:
    rng = np.random.default_rng(17)
    out = []
    for K, R, n_erased, W in [(16, 4, 4, 4096), (32, 8, 8, 4096),
                              (64, 16, 8, 16384)]:
        spec = CodeSpec(kind="rs", K=K, R=R, W=W)
        x = FERMAT.rand((K, W), rng)
        enc = Encoder.plan(spec, backend="local")
        parity = enc.run(x)
        cw = np.concatenate([x % FERMAT.q, parity])
        erased = tuple(range(0, 2 * n_erased, 2))[:n_erased]  # data shards
        dec = Decoder.plan(spec, erased=erased, backend="local")
        v = cw[list(dec.kept)]

        us_enc = _time(lambda: enc.run(x))
        us_dec = _time(lambda: dec.run(v))
        us_data = _time(lambda: dec.data(v))
        out.append(
            f"recover/decode_local_K{K}_R{R}_E{n_erased}_W{W},{us_dec:.0f},"
            f"backend=local;encode_us={us_enc:.0f};data_us={us_data:.0f};"
            f"ratio={us_dec / max(us_enc, 1e-9):.2f}")

        c_dec = dec.cost()  # decode_cost with the spec's W folded into C2
        c_enc = enc.cost()  # Table-I model, W likewise folded
        model_us = c_dec.total(Decoder.ALPHA, Decoder.BETA_BITS) * 1e6
        out.append(
            f"recover/decode_model_K{K}_R{R}_E{n_erased},{model_us:.1f},"
            f"backend=model;C1={c_dec.C1};C2={c_dec.C2};"
            f"enc_C1={c_enc.C1};enc_C2={c_enc.C2}")
    return out
