"""Paper-technique perf cell: all-to-all encode ON THE MESH at N=64 —
universal (prepare-and-shoot) vs specific (radix-2 DFT) scheduling for the
same DFT coding matrix, measured as lowered ppermute traffic.

Table I at K=64, p=1 predicts C2: universal 14 vs DFT-specific 6 (2.33x).
Runs in its own process (64 forced host devices).
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=64 "
    + os.environ.get("XLA_FLAGS", ""))

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.field import FERMAT
from repro.core.matrices import permuted_dft_matrix
from repro.core.shardmap_exec import (
    build_dft_tables, build_universal_tables, mesh_dft, mesh_universal_a2a,
    shard_map)
from repro.launch.hlo_cost import analyze


def main():
    f = FERMAT
    N, W = 64, 8192
    mesh = Mesh(np.array(jax.devices()), ("d",))
    x = jnp.asarray(f.rand((N, W), np.random.default_rng(0)).astype(np.uint32))
    D = permuted_dft_matrix(f, N, 2)

    # --- universal scheduling on the DFT matrix ---------------------------
    tu = build_universal_tables(f, [D], N, p=1)

    @partial(shard_map, mesh=mesh, in_specs=(P("d"),) * 3, out_specs=P("d"))
    def step_u(xb, coef, corr):
        return mesh_universal_a2a(xb[0], coef[0], corr[0], tu, "d")[None]

    # --- specific (radix-2 DFT) scheduling --------------------------------
    td = build_dft_tables(f, N, 64)

    @partial(shard_map, mesh=mesh, in_specs=(P("d"),) * 3, out_specs=P("d"))
    def step_d(xb, ca, cb):
        return mesh_dft(xb[0], ca[0], cb[0], td, "d")[None]

    exp = f.matmul(D.T, np.asarray(x, np.int64))
    bytes_of, all_ok = {}, 1
    for name, fn, args in [
        ("universal", step_u, (jnp.asarray(tu.coef), jnp.asarray(tu.corr))),
        ("dft_specific", step_d, (jnp.asarray(td.ca.T), jnp.asarray(td.cb.T))),
    ]:
        t0 = time.perf_counter()
        compiled = jax.jit(lambda xg: fn(xg, *args)).lower(x).compile()
        census = analyze(compiled.as_text())
        us = (time.perf_counter() - t0) * 1e6
        ok = np.array_equal(np.asarray(fn(x, *args)), exp)
        bytes_of[name] = census["collective_bytes"]
        all_ok &= int(ok)
        print(f"mesh_a2a/{name}_N64_W{W},{us:.0f},"
              f"ppermute_bytes={census['collective_bytes']:.0f};correct={int(ok)}")
    # stable (HLO-census, no wall clock) rows for the gated mesh/* section
    print(f"mesh/a2a_bytes_gain_W{W},"
          f"{bytes_of['universal'] / bytes_of['dft_specific']:.3f},"
          f"universal_bytes={bytes_of['universal']:.0f};"
          f"dft_bytes={bytes_of['dft_specific']:.0f};backend=mesh")
    print(f"mesh/a2a_ok_W{W},{all_ok},both schedules bitwise vs the dense "
          f"matmul;backend=mesh")


if __name__ == "__main__":
    main()
