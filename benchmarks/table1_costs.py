"""Table I of the paper: costs of the all-to-all encode schemes.

Measured (simulator) vs analytic (theorems) C1/C2 for:
  universal (prepare-and-shoot, Thm. 3)
  specific DFT (Thm. 4 / Cor. 1)
  specific Vandermonde (draw-and-loose, Thm. 5)
plus the Lemma 1/2 lower bounds.  Emits CSV rows:
  name,us_per_call,derived
where derived packs "C1=..;C2=..;C=.." with the paper's cost
C = alpha*C1 + beta*log2(q)*C2 at (alpha=1e-5 s, beta=1e-9 s/bit).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (
    FERMAT, RoundNetwork, StructuredPoints, cost_dft, cost_draw_loose,
    cost_universal, dft_a2a, draw_loose, universal_a2a,
)
from repro.core.cost_model import lower_bound_c1, lower_bound_c2

ALPHA, BETA_BITS = 1e-5, 1e-9 * 17  # beta * ceil(log2 q)


def _run(name, fn, reps=1):
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    us = (time.perf_counter() - t0) / reps * 1e6
    return us, out


def rows() -> list[str]:
    f = FERMAT
    rng = np.random.default_rng(0)
    out = []
    for K in (16, 64, 256, 1024):
        for p in (1, 2):
            x = f.rand(K, rng)
            C = f.rand((K, K), rng)
            net = RoundNetwork(K, p)
            us, _ = _run(f"univ_K{K}", lambda: universal_a2a(f, C, x, p=p,
                                                             net=RoundNetwork(K, p)))
            c1t, c2t = cost_universal(K, p)
            net = RoundNetwork(K, p)
            universal_a2a(f, C, x, p=p, net=net)
            cost = net.cost(ALPHA, BETA_BITS)
            lb1, lb2 = lower_bound_c1(K, p), lower_bound_c2(K, p)
            out.append(
                f"table1/universal_K{K}_p{p},{us:.1f},"
                f"C1={net.C1};C2={net.C2};C1_thm={c1t};C2_thm={c2t};"
                f"C1_lb={lb1};C2_lb={lb2:.1f};C={cost:.2e}")
            if K & (K - 1) == 0 and p == 1:
                xs = {k: x[k] for k in range(K)}
                res = {}
                net = RoundNetwork(K, p)
                us, _ = _run(f"dft_K{K}", lambda: RoundNetwork(K, p).run(
                    dft_a2a(f, xs, list(range(K)), p, 2, {})))
                net = RoundNetwork(K, p)
                net.run(dft_a2a(f, xs, list(range(K)), p, 2, res))
                c1t, c2t = cost_dft(K, 2, p)
                out.append(
                    f"table1/dft_K{K}_p{p},{us:.1f},"
                    f"C1={net.C1};C2={net.C2};C1_thm={c1t};C2_thm={c2t};"
                    f"C={net.cost(ALPHA, BETA_BITS):.2e}")
            if p == 1:
                sp = StructuredPoints.build(f, K, P=2)
                res = {}
                net = RoundNetwork(K, p)
                us, _ = _run(f"vand_K{K}", lambda: RoundNetwork(K, p).run(
                    draw_loose(f, sp, {k: x[k] for k in range(K)},
                               list(range(K)), p, {})))
                net.run(draw_loose(f, sp, {k: x[k] for k in range(K)},
                                   list(range(K)), p, res))
                c1t, c2t = cost_draw_loose(sp, p)
                out.append(
                    f"table1/vandermonde_K{K}_p{p},{us:.1f},"
                    f"C1={net.C1};C2={net.C2};C1_thm={c1t};C2_thm={c2t};"
                    f"gain_vs_univ_C2={cost_universal(K, p)[1] - net.C2};"
                    f"C={net.cost(ALPHA, BETA_BITS):.2e}")
    return out
