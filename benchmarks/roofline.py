"""Roofline table generator.

Two row families:

  * dry-run cells (`rows()`): reads results/dryrun/*.json (produced by
    `python -m repro.launch.dryrun`) and emits the §Roofline rows + a
    markdown table for EXPERIMENTS.md — only when that directory exists;
  * coding-kernel cells (`coding_rows()`): the NTT fast path and the
    dense `encode_blocks` field matmul, each streamed through
    `plan.run_stream` and reported as the achieved fraction of an
    empirically-measured streaming-bandwidth ceiling on THIS host.  The
    element counts come from the unified metrics registry
    (`stream_elems_total` deltas) — the same counters every production
    path publishes — so the row measures what the instrumented pipeline
    actually moved, not what the bench thinks it asked for.  Always
    runnable (local backend, no dry-run artifacts needed); gated with
    loose `min` bounds in benchmarks/baselines/baseline.json.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(out_dir: str = "results/dryrun") -> list[dict]:
    cells = []
    for p in sorted(Path(out_dir).glob("*.json")):
        cells.append(json.loads(p.read_text()))
    return cells


def rows() -> list[str]:
    out = []
    for c in load():
        name = f"roofline/{c['arch']}__{c['shape']}__{c['mesh']}"
        if "error" in c:
            out.append(f"{name},0,ERROR={c['error'][:60]}")
            continue
        if "skipped" in c:
            out.append(f"{name},0,SKIP={c['skipped'][:60]}")
            continue
        r = c["roofline"]
        out.append(
            f"{name},{c['compile_s'] * 1e6:.0f},"
            f"compute_s={r['compute_s']:.4f};memory_s={r['memory_s']:.4f};"
            f"collective_s={r['collective_s']:.4f};dominant={r['dominant']};"
            f"useful_ratio={c['useful_ratio']:.3f};"
            f"roofline_frac={c['roofline_fraction']:.4f}")
    return out


def _bandwidth_ceiling_gbs(nbytes: int = 1 << 26, reps: int = 3) -> float:
    """Empirical streaming-bandwidth ceiling: best-of-reps large memcpy
    (read + write counted), in GB/s — the roofline the coding kernels are
    measured against on this host."""
    import numpy as np

    src = np.ones(nbytes // 8, np.float64)
    dst = np.empty_like(src)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        np.copyto(dst, src)
        best = min(best, time.perf_counter() - t0)
    return 2 * nbytes / best / 1e9


def coding_rows() -> list[str]:
    """`roofline/{ntt,dense}_encode_*` rows: streamed local-encode
    throughput as a fraction of the memcpy ceiling (see module
    docstring)."""
    import numpy as np

    from repro.api import CodeSpec, Encoder
    from repro.core.field import FERMAT
    from repro.obs.metrics import REGISTRY

    ceiling = _bandwidth_ceiling_gbs()
    rng = np.random.default_rng(5)
    elems_ctr = "stream_elems_total"
    out = []
    cases = [
        ("ntt", CodeSpec(kind="rs", K=256, R=64), 1 << 16),
        ("dense", CodeSpec(kind="universal", K=64, R=16, seed=5), 1 << 16),
    ]
    for label, spec, W in cases:
        plan = Encoder.plan(spec, backend="local")
        assert plan.local_impl == label, (label, plan.local_impl)
        x = FERMAT.rand((spec.K, W), rng)

        def run():
            for _ in plan.run_stream(x):
                pass

        def streamed_elems() -> float:
            vals = REGISTRY.snapshot().get(elems_ctr, {}).get("values", {})
            return vals.get("backend=local,op=encode", 0)

        run()  # warm the chunk callables (compile outside the timing)
        e0 = streamed_elems()
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            run()
            best = min(best, time.perf_counter() - t0)
        per_run = (streamed_elems() - e0) / 3
        # uint32 stream: read the (K, W) payload, write the (R, W) parity
        moved = (spec.K + spec.R) * W * 4
        achieved = moved / best / 1e9
        out.append(
            f"roofline/{label}_encode_K{spec.K}_R{spec.R}_W{W},"
            f"{achieved / ceiling:.4f},"
            f"backend=local;dimensionless=1;achieved_gbs={achieved:.2f};"
            f"ceiling_gbs={ceiling:.2f};streamed_elems={per_run:.0f}")
    return out


def markdown_table(out_dir: str = "results/dryrun", mesh: str = "single") -> str:
    cells = [c for c in load(out_dir) if c.get("mesh") == mesh]
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL/HLO flops | roofline frac | fits HBM (temp GB/dev) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    key = lambda c: (c["arch"], SHAPE_ORDER.index(c["shape"]))
    for c in sorted(cells, key=key):
        if "skipped" in c:
            lines.append(f"| {c['arch']} | {c['shape']} | — | — | — | skipped: "
                         f"{c['skipped'][:40]} | — | — | — |")
            continue
        if "error" in c:
            lines.append(f"| {c['arch']} | {c['shape']} | ERROR | | | "
                         f"{c['error'][:60]} | | | |")
            continue
        r = c["roofline"]
        tgb = (c["memory"]["temp_bytes"] or 0) / 1e9
        lines.append(
            f"| {c['arch']} | {c['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['dominant'].replace('_s','')} | {c['useful_ratio']:.2f} | "
            f"{c['roofline_fraction']:.3f} | {tgb:.1f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
