"""Roofline table generator: reads results/dryrun/*.json (produced by
`python -m repro.launch.dryrun`) and emits the §Roofline rows + a markdown
table for EXPERIMENTS.md."""
from __future__ import annotations

import json
from pathlib import Path

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(out_dir: str = "results/dryrun") -> list[dict]:
    cells = []
    for p in sorted(Path(out_dir).glob("*.json")):
        cells.append(json.loads(p.read_text()))
    return cells


def rows() -> list[str]:
    out = []
    for c in load():
        name = f"roofline/{c['arch']}__{c['shape']}__{c['mesh']}"
        if "error" in c:
            out.append(f"{name},0,ERROR={c['error'][:60]}")
            continue
        if "skipped" in c:
            out.append(f"{name},0,SKIP={c['skipped'][:60]}")
            continue
        r = c["roofline"]
        out.append(
            f"{name},{c['compile_s'] * 1e6:.0f},"
            f"compute_s={r['compute_s']:.4f};memory_s={r['memory_s']:.4f};"
            f"collective_s={r['collective_s']:.4f};dominant={r['dominant']};"
            f"useful_ratio={c['useful_ratio']:.3f};"
            f"roofline_frac={c['roofline_fraction']:.4f}")
    return out


def markdown_table(out_dir: str = "results/dryrun", mesh: str = "single") -> str:
    cells = [c for c in load(out_dir) if c.get("mesh") == mesh]
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL/HLO flops | roofline frac | fits HBM (temp GB/dev) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    key = lambda c: (c["arch"], SHAPE_ORDER.index(c["shape"]))
    for c in sorted(cells, key=key):
        if "skipped" in c:
            lines.append(f"| {c['arch']} | {c['shape']} | — | — | — | skipped: "
                         f"{c['skipped'][:40]} | — | — | — |")
            continue
        if "error" in c:
            lines.append(f"| {c['arch']} | {c['shape']} | ERROR | | | "
                         f"{c['error'][:60]} | | | |")
            continue
        r = c["roofline"]
        tgb = (c["memory"]["temp_bytes"] or 0) / 1e9
        lines.append(
            f"| {c['arch']} | {c['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['dominant'].replace('_s','')} | {c['useful_ratio']:.2f} | "
            f"{c['roofline_fraction']:.3f} | {tgb:.1f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
