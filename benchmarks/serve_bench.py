"""Multi-tenant serving load generator: Zipf volumes, open/closed-loop
arrivals, and chaos-under-load — the `serve/*` gated section.

Drives `repro.launch.service.CodedService` the way a storage frontend is
driven in production (ClusterDFS's `experiment_nettraff` methodology):
many client threads, volume popularity Zipf-skewed so one hot volume
dominates, every payload verified bitwise against the volume's known
codeword.  Three legs:

  serve/closed_*       — closed-loop: C clients submit-wait-repeat over V
                         Zipf-ranked volumes (each volume its own
                         generator matrix, so only same-volume requests
                         may coalesce).  Rows: sustained QPS (gated
                         ``better: higher``) and p50/p99/p999 completion
                         latency.
  serve/coalesce_hot_* — the hot volume's cross-session batching ratio
                         (mean coalesced group size over its ops; gated
                         ``min: 1.5`` — the acceptance criterion that the
                         shared queue really merges independent sessions).
  serve/open_*         — open-loop: seeded-exponential arrivals at a fixed
                         offered rate, ``block=False`` admission (full
                         queue => loud `QueueFullError`, counted, never a
                         silent drop); p99 completion latency row.
  serve/chaos_ok_*     — chaos UNDER load: processors killed/healed while
                         thousands of queued ops are in flight across
                         three tenants' sessions.  Every submitted future
                         must resolve bitwise-correct or raise; the row's
                         value is 1.0 only when there were ZERO silent
                         drops and ZERO mismatches (gated ``min: 1``).

Run standalone for bigger sweeps::

    python benchmarks/serve_bench.py --ops 20000 --clients 32 --chaos
"""
from __future__ import annotations

import argparse
import sys
import threading
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.api import CodeSpec, Encoder  # noqa: E402
from repro.core.field import FERMAT  # noqa: E402
from repro.launch.service import (  # noqa: E402
    CodedService,
    QueueFullError,
    TenantQuota,
)
from repro.launch.tenancy import percentile  # noqa: E402


def _zipf_probs(v: int, s: float = 1.1) -> np.ndarray:
    p = 1.0 / np.arange(1, v + 1) ** s
    return p / p.sum()


def _make_volumes(n_vol: int, n_tenants: int, K: int, R: int, W: int,
                  rng: np.random.Generator) -> list[dict]:
    """One volume = (tenant, universal spec, its OWN generator matrix,
    a fixed payload and its known codeword).  Distinct matrices mean
    distinct plan digests: only same-volume requests may coalesce, so the
    hot volume's batching ratio measures real popularity-driven merging."""
    spec = CodeSpec(kind="universal", K=K, R=R, W=W)
    vols = []
    for v in range(n_vol):
        A = FERMAT.rand((K, R), rng)
        x = FERMAT.rand((K, W), rng)
        plan = Encoder.plan(spec, backend="local", A=A)
        parity = plan.run(x)
        cw = np.concatenate([x % FERMAT.q, parity], axis=0)
        vols.append({"name": f"vol{v}", "tenant": f"tenant{v % n_tenants}",
                     "spec": spec, "A": A, "x": x, "cw": cw})
    return vols


# ---------------------------------------------------------------------------
# closed loop
# ---------------------------------------------------------------------------

def closed_loop(*, n_clients: int = 12, ops_per_client: int = 80,
                n_vol: int = 6, n_tenants: int = 4, K: int = 16, R: int = 4,
                W: int = 256, seed: int = 7) -> dict:
    rng = np.random.default_rng(seed)
    vols = _make_volumes(n_vol, n_tenants, K, R, W, rng)
    probs = _zipf_probs(n_vol)
    svc = CodedService(backend="local", max_inflight_ops=4096, chunk_w=W)
    try:
        for v in vols:  # warm the per-volume plan + chunk callables
            svc.submit(v["tenant"], v["spec"], "encode", v["x"],
                       A=v["A"], tag=v["name"]).result(timeout=120)
        errors: list[str] = []
        barrier = threading.Barrier(n_clients + 1)

        def client(cid: int) -> None:
            r = np.random.default_rng(seed + 100 + cid)
            try:
                barrier.wait(timeout=60)
                for i in range(ops_per_client):
                    v = vols[int(r.choice(n_vol, p=probs))]
                    fut = svc.submit(v["tenant"], v["spec"], "encode",
                                     v["x"], A=v["A"], tag=v["name"])
                    got = fut.result(timeout=120)
                    if i % 10 == 0 and not np.array_equal(
                            got, v["cw"][K:]):
                        errors.append(f"client {cid}: bitwise mismatch "
                                      f"on {v['name']}")
            except Exception as exc:  # noqa: BLE001 — surfaced below
                errors.append(f"client {cid}: {exc!r}")

        threads = [threading.Thread(target=client, args=(c,))
                   for c in range(n_clients)]
        for t in threads:
            t.start()
        barrier.wait(timeout=60)
        t0 = time.perf_counter()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        if errors:
            raise RuntimeError(f"closed-loop errors: {errors[:4]}")
        st = svc.stats()
        lats = svc.latencies_us()
        n_ops = n_clients * ops_per_client
        return {
            "qps": n_ops / wall,
            "ops": n_ops,
            "p50_us": percentile(lats, 0.5),
            "p99_us": percentile(lats, 0.99),
            "p999_us": percentile(lats, 0.999),
            "hot_ratio": st["tags"]["vol0"]["coalescing_ratio"],
            "service_ratio": st["service"]["coalescing_ratio"],
            "K": K, "R": R, "W": W,
        }
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# open loop
# ---------------------------------------------------------------------------

def open_loop(*, rate: float = 300.0, duration: float = 2.0,
              n_vol: int = 6, n_tenants: int = 4, K: int = 16, R: int = 4,
              W: int = 256, max_inflight: int = 256, seed: int = 13) -> dict:
    """Seeded-exponential arrivals at `rate`/s for `duration`s; admission
    is non-blocking — when the bounded queue is full the submission fails
    LOUDLY with QueueFullError and is counted, never dropped."""
    rng = np.random.default_rng(seed)
    vols = _make_volumes(n_vol, n_tenants, K, R, W, rng)
    probs = _zipf_probs(n_vol)
    svc = CodedService(backend="local", max_inflight_ops=max_inflight,
                       chunk_w=W)
    try:
        for v in vols:
            svc.submit(v["tenant"], v["spec"], "encode", v["x"],
                       A=v["A"]).result(timeout=120)
        gaps = rng.exponential(1.0 / rate, size=int(rate * duration))
        futs: list[tuple[dict, object]] = []
        rejected = 0
        t0 = time.perf_counter()
        for gap in gaps:
            v = vols[int(rng.choice(n_vol, p=probs))]
            try:
                futs.append((v, svc.submit(v["tenant"], v["spec"], "encode",
                                           v["x"], A=v["A"], tag=v["name"],
                                           block=False)))
            except QueueFullError:
                rejected += 1
            time.sleep(gap)
        for v, fut in futs:
            got = fut.result(timeout=120)
            if not np.array_equal(got, v["cw"][K:]):
                raise RuntimeError(f"open-loop mismatch on {v['name']}")
        wall = time.perf_counter() - t0
        lats = svc.latencies_us()
        return {
            "offered_qps": rate,
            "achieved_qps": len(futs) / wall,
            "submitted": len(futs),
            "rejected": rejected,
            "p50_us": percentile(lats, 0.5),
            "p99_us": percentile(lats, 0.99),
            "K": K, "R": R, "W": W,
        }
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# chaos under load
# ---------------------------------------------------------------------------

def chaos_under_load(*, n_ops: int = 2400, n_clients: int = 6,
                     n_tenants: int = 3, K: int = 16, R: int = 4,
                     W: int = 128, seed: int = 29,
                     trace_path: str | None = None) -> dict:
    """Kill/heal processors while thousands of queued ops are in flight.

    Clients submit WITHOUT waiting (deep queues), a chaos thread per
    tenant's session randomly fails survivors / heals via rebuild while
    the queue drains; decode submissions pin their pattern under a
    per-session lock so every future has an exact expected value.  Every
    future must resolve bitwise-correct or raise — both are counted; a
    future that does neither is a silent drop and fails the row.

    With `trace_path`, the whole scenario is captured as a Chrome
    trace-event timeline (per-tenant op spans, queue execution, stream
    pipeline) plus one simulator-backed fail->decode leg under the same
    tracer, so the artifact also carries per-processor round tracks.
    """
    rng = np.random.default_rng(seed)
    spec = CodeSpec(kind="rs", K=K, R=R, W=W)
    svc = CodedService(backend="local", max_inflight_ops=8192, chunk_w=1024,
                       trace=trace_path)
    tenants = []
    try:
        for t in range(n_tenants):
            name = f"tenant{t}"
            # default per-tenant quota (64) would backpressure the flood at
            # 3*64 in flight; chaos wants a genuinely deep queue
            svc.set_quota(name, TenantQuota(max_inflight_ops=4096,
                                            max_inflight_bytes=1 << 33))
            x = FERMAT.rand((K, W), rng)
            sess = svc.session(name, spec)
            cw = sess.codeword(x)
            tenants.append({"name": name, "sess": sess, "x": x, "cw": cw,
                            "lock": threading.Lock()})
        svc.submit("tenant0", spec, "encode", tenants[0]["x"]).result(
            timeout=120)  # warm the chunk callables

        futs: list[tuple[str, tuple | None, dict, object]] = []
        futs_lock = threading.Lock()
        stop_chaos = threading.Event()
        submit_errors: list[str] = []

        def chaos(tn: dict, cseed: int) -> None:
            # mostly cheap fail/heal churn (every pattern change forces the
            # queue's pinned-pattern failover / replan machinery); the
            # occasional SYNCHRONOUS rebuild heals mid-load, racing the
            # queued decodes it invalidates.  Sleeps keep the session lock
            # mostly free so the clients can actually flood the queue.
            r = np.random.default_rng(cseed)
            sess = tn["sess"]
            while not stop_chaos.is_set():
                roll = r.random()
                with tn["lock"]:
                    try:
                        if roll < 0.5 and len(sess.failed) < R:
                            alive = [i for i in range(spec.N)
                                     if i not in sess.failed]
                            sess.fail(int(r.choice(alive)))
                        elif roll < 0.54 and sess.failed:
                            healed = sess.rebuild(tn["cw"])
                            assert np.array_equal(healed, tn["cw"])
                        elif sess.failed:
                            sess.heal(int(r.choice(list(sess.failed))))
                    except ValueError:
                        pass  # lost the <=R race to a concurrent client
                time.sleep(0.02)

        def client(cid: int) -> None:
            r = np.random.default_rng(seed + 1000 + cid)
            try:
                for _ in range(n_ops // n_clients):
                    tn = tenants[int(r.integers(n_tenants))]
                    roll = r.random()
                    if roll < 0.6:
                        fut = svc.submit(tn["name"], spec, "encode", tn["x"])
                        rec = ("encode", None, tn, fut)
                    elif roll < 0.85:
                        # pin the expected pattern under the session lock:
                        # chaos cannot move it between read and submit
                        with tn["lock"]:
                            pinned = tn["sess"].failed
                            fut = svc.submit(tn["name"], spec, "decode",
                                             tn["cw"])
                        rec = ("decode", pinned, tn, fut)
                    else:
                        fut = svc.submit(tn["name"], spec, "rebuild",
                                         tn["cw"])
                        rec = ("rebuild", None, tn, fut)
                    with futs_lock:
                        futs.append(rec)
            except Exception as exc:  # noqa: BLE001 — loud, counted
                submit_errors.append(f"client {cid}: {exc!r}")

        peak = {"depth": 0}

        def sampler() -> None:
            while not stop_chaos.is_set():
                peak["depth"] = max(peak["depth"], svc.queue_depth)
                time.sleep(0.001)

        chaos_threads = [
            threading.Thread(target=chaos, args=(tn, seed + 7 * i), daemon=True)
            for i, tn in enumerate(tenants)]
        sample_thread = threading.Thread(target=sampler, daemon=True)
        client_threads = [threading.Thread(target=client, args=(c,))
                          for c in range(n_clients)]
        t0 = time.perf_counter()
        for t in chaos_threads + client_threads + [sample_thread]:
            t.start()
        for t in client_threads:
            t.join()

        ok = loud = mismatch = unresolved = 0
        for op, pinned, tn, fut in futs:
            try:
                got = fut.result(timeout=300)
            except Exception:  # noqa: BLE001 — a LOUD failure, counted
                loud += 1
                continue
            cw = tn["cw"]
            ref = (cw[K:] if op == "encode"
                   else cw[list(pinned)] if op == "decode" else cw)
            if np.array_equal(got, ref):
                ok += 1
            else:
                mismatch += 1
        wall = time.perf_counter() - t0
        stop_chaos.set()
        for t in chaos_threads:
            t.join(timeout=30)
        st = svc.stats()
        unresolved = sum(1 for _, _, _, f in futs if not f.done())
        if svc.tracer is not None:
            # the chaos load serves on the local backend, which has no
            # lockstep rounds; a small simulator-backed fail -> decode leg
            # under the SAME (still-installed) tracer puts per-processor
            # round tracks into the artifact alongside the op spans
            from repro.api import CodedSystem

            with CodedSystem(spec, backend="simulator") as sim:
                sim.fail([1, K + 1])
                rep = sim.decode(tenants[0]["cw"])
                assert np.array_equal(rep, tenants[0]["cw"][[1, K + 1]])
        return {
            "submitted": len(futs),
            "ok": ok,
            "loud_failures": loud,
            "mismatches": mismatch,
            "unresolved": unresolved,
            "submit_errors": len(submit_errors),
            "failovers": st["service"]["failovers"],
            "peak_depth": peak["depth"],
            "qps": len(futs) / wall,
            "all_accounted": (mismatch == 0 and unresolved == 0
                              and len(futs) + len(submit_errors)
                              == ok + loud + len(submit_errors)),
            "K": K, "R": R, "W": W,
        }
    finally:
        svc.close(timeout=300)


# ---------------------------------------------------------------------------
# gated rows
# ---------------------------------------------------------------------------

def rows() -> list[str]:
    out = []

    c = closed_loop()
    shape = f"K{c['K']}_R{c['R']}_W{c['W']}"
    out.append(f"serve/closed_qps_{shape},{c['qps']:.1f},"
               f"backend=local;dimensionless=1;ops={c['ops']};"
               f"service_ratio={c['service_ratio']:.2f}")
    out.append(f"serve/closed_lat50_us_{shape},{c['p50_us']:.0f},"
               f"backend=local;qps={c['qps']:.1f}")
    out.append(f"serve/closed_lat99_us_{shape},{c['p99_us']:.0f},"
               f"backend=local;qps={c['qps']:.1f}")
    out.append(f"serve/closed_lat999_us_{shape},{c['p999_us']:.0f},"
               f"backend=local;qps={c['qps']:.1f}")
    out.append(f"serve/coalesce_hot_{shape},{c['hot_ratio']:.2f},"
               f"backend=local;dimensionless=1;"
               f"service_ratio={c['service_ratio']:.2f}")

    o = open_loop()
    oshape = f"K{o['K']}_R{o['R']}_W{o['W']}"
    out.append(f"serve/open_lat99_us_{oshape},{o['p99_us']:.0f},"
               f"backend=local;offered_qps={o['offered_qps']:.0f};"
               f"achieved_qps={o['achieved_qps']:.1f};"
               f"rejected={o['rejected']}")

    ch = chaos_under_load()
    cshape = f"K{ch['K']}_R{ch['R']}_W{ch['W']}"
    out.append(f"serve/chaos_ok_{cshape},"
               f"{1.0 if ch['all_accounted'] else 0.0:.1f},"
               f"backend=local;dimensionless=1;submitted={ch['submitted']};"
               f"ok={ch['ok']};loud={ch['loud_failures']};"
               f"mismatch={ch['mismatches']};unresolved={ch['unresolved']};"
               f"failovers={ch['failovers']};peak_depth={ch['peak_depth']};"
               f"qps={ch['qps']:.0f}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--clients", type=int, default=12)
    ap.add_argument("--ops", type=int, default=960,
                    help="total closed-loop ops across all clients")
    ap.add_argument("--volumes", type=int, default=6)
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--rate", type=float, default=300.0,
                    help="open-loop offered arrival rate (QPS)")
    ap.add_argument("--duration", type=float, default=2.0)
    ap.add_argument("--chaos", action="store_true",
                    help="also run the chaos-under-load leg")
    ap.add_argument("--chaos-ops", type=int, default=2400)
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="write the chaos leg's Chrome trace-event JSON "
                         "here (implies --chaos)")
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()

    c = closed_loop(n_clients=args.clients,
                    ops_per_client=max(1, args.ops // args.clients),
                    n_vol=args.volumes, n_tenants=args.tenants,
                    seed=args.seed)
    print(f"closed-loop: {c['ops']} ops @ {c['qps']:.0f} QPS sustained; "
          f"p50={c['p50_us']:.0f}us p99={c['p99_us']:.0f}us "
          f"p999={c['p999_us']:.0f}us; hot-volume coalescing "
          f"{c['hot_ratio']:.2f}x (service {c['service_ratio']:.2f}x)")
    o = open_loop(rate=args.rate, duration=args.duration,
                  n_vol=args.volumes, n_tenants=args.tenants,
                  seed=args.seed + 1)
    print(f"open-loop  : offered {o['offered_qps']:.0f} QPS, achieved "
          f"{o['achieved_qps']:.0f}; {o['submitted']} admitted, "
          f"{o['rejected']} rejected LOUDLY; p99={o['p99_us']:.0f}us")
    if args.chaos or args.trace:
        ch = chaos_under_load(n_ops=args.chaos_ops, seed=args.seed + 2,
                              trace_path=args.trace)
        if args.trace:
            print(f"trace      : chaos timeline -> {args.trace}")
        print(f"chaos      : {ch['submitted']} ops under live kills "
              f"(peak queue depth {ch['peak_depth']}, "
              f"{ch['failovers']} failovers): {ch['ok']} bitwise-ok, "
              f"{ch['loud_failures']} loud failures, "
              f"{ch['mismatches']} mismatches, "
              f"{ch['unresolved']} silent drops -> "
              f"{'PASS' if ch['all_accounted'] else 'FAIL'}")
        if not ch["all_accounted"]:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
