"""Rebuild cost vs erasure count — re-materializing the full codeword.

Rebuild = decode all currently-failed symbols among the survivors + heal;
its cost scales with |E| (batches of repair columns), which is exactly the
trade a decentralized store cares about: how much more expensive is losing
8 shards than 1 before redundancy is restored?  Two families of rows:

  rebuild/rebuild_local_*  — wall time of `CodedSystem.rebuild` (fail the
                             pattern, recompute via the cached DecodePlan
                             kernel path, heal) on the same (K, R, W) at
                             growing |E|; derived carries the per-lost-
                             symbol cost and the matching decode-only us
  rebuild/rebuild_model_*  — the closed-form network cost of the rebuild's
                             repair schedule (`recover.decode_cost`, exact
                             C1/C2) at the same shapes
"""
from __future__ import annotations

import time

import numpy as np

from repro.api import CodedSystem, CodeSpec
from repro.core.field import FERMAT
from repro.recover import Decoder, decode_cost


def _time(fn, reps: int = 5) -> float:
    fn()  # warm (compile / plan-cache fill)
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def rows() -> list[str]:
    rng = np.random.default_rng(29)
    out = []
    K, R, W = 32, 8, 4096
    spec = CodeSpec(kind="rs", K=K, R=R, W=W)
    x = FERMAT.rand((K, W), rng)
    system = CodedSystem(spec, backend="local")
    cw = system.codeword(x)
    for n_erased in (1, 4, 8):
        erased = tuple(range(0, 2 * n_erased, 2))  # data shards

        def rebuild_once():
            system.fail(erased)
            healed = system.rebuild(cw)
            return healed

        dec = Decoder.plan(spec, erased=erased, backend="local")
        v = cw[list(dec.kept)]
        us_reb = _time(rebuild_once)
        us_dec = _time(lambda: dec.run(v))
        out.append(
            f"rebuild/rebuild_local_K{K}_R{R}_E{n_erased}_W{W},{us_reb:.0f},"
            f"backend=local;decode_us={us_dec:.0f};"
            f"per_symbol_us={us_reb / n_erased:.0f}")

        c = dec.cost()  # decode_cost with the spec's W folded into C2
        model_us = c.total(Decoder.ALPHA, Decoder.BETA_BITS) * 1e6
        raw = decode_cost(K, n_erased, spec.p)
        out.append(
            f"rebuild/rebuild_model_K{K}_R{R}_E{n_erased},{model_us:.1f},"
            f"backend=model;C1={raw.C1};C2={raw.C2}")
    system.close()
    return out
