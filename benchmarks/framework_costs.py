"""Thm. 1/2/7/9: end-to-end decentralized-encoding costs, universal vs the
RS-specific (Cauchy-like) method, across (K, R) and p."""
from __future__ import annotations

import time

import numpy as np

from repro.core import FERMAT, decentralized_encode
from repro.core.cauchy import StructuredGRS

ALPHA, BETA_BITS = 1e-5, 1e-9 * 17


def rows() -> list[str]:
    f = FERMAT
    rng = np.random.default_rng(2)
    out = []
    for (K, R, p) in [(64, 16, 1), (256, 32, 1), (256, 64, 1), (512, 64, 1),
                      (64, 16, 2), (16, 64, 1)]:
        x = f.rand((K, 1), rng)
        sgrs = StructuredGRS.build(f, K, R)
        A = sgrs.grs.A_direct()
        t0 = time.perf_counter()
        _, net_u = decentralized_encode(f, A, x, p=p)
        us_u = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        _, net_r = decentralized_encode(f, A, x, p=p, method="rs", sgrs=sgrs)
        us_r = (time.perf_counter() - t0) * 1e6
        cu, cr = net_u.cost(ALPHA, BETA_BITS), net_r.cost(ALPHA, BETA_BITS)
        out.append(
            f"framework/universal_K{K}_R{R}_p{p},{us_u:.1f},"
            f"C1={net_u.C1};C2={net_u.C2};C={cu:.2e}")
        out.append(
            f"framework/rs_K{K}_R{R}_p{p},{us_r:.1f},"
            f"C1={net_r.C1};C2={net_r.C2};C={cr:.2e};"
            f"C2_gain_vs_universal={net_u.C2 - net_r.C2}")
    return out
