"""Sec. II comparison vs Jeong et al. [21] multi-reduce and a centralized
gather-encode-scatter strawman.  The paper claims multi-reduce spends
(R - 2*sqrt(R) - 1) * beta*log2(q)*W more than the proposed framework."""
from __future__ import annotations

import math
import time

import numpy as np

from repro.core import FERMAT, decentralized_encode
from repro.core.cost_model import gather_encode_scatter, multireduce_jeong

ALPHA, BETA_BITS = 1e-5, 1e-9 * 17


def rows() -> list[str]:
    f = FERMAT
    rng = np.random.default_rng(1)
    out = []
    for (K, R) in [(16, 4), (64, 16), (256, 16), (1024, 64)]:
        A = f.rand((K, R), rng)
        x = f.rand((K, 1), rng)
        t0 = time.perf_counter()
        _, net = decentralized_encode(f, A, x, p=1)
        us = (time.perf_counter() - t0) * 1e6
        ours = net.cost(ALPHA, BETA_BITS)
        mr = multireduce_jeong(K, R, 1)
        gs = gather_encode_scatter(K, R, 1)
        claim_gap = max(0.0, R - 2 * math.sqrt(R) - 1)
        out.append(
            f"multireduce/K{K}_R{R},{us:.1f},"
            f"ours_C1={net.C1};ours_C2={net.C2};"
            f"multireduce_C2={mr.C2};gather_scatter_C2={gs.C2};"
            f"paper_claim_extra_C2={claim_gap:.1f};"
            f"measured_extra_C2={mr.C2 - net.C2}")
    return out
