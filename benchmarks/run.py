"""Benchmark driver — one section per paper table/claim.

Prints ``name,us_per_call,derived`` CSV.  Sections:
  table1/*       — Table I: universal / DFT / Vandermonde A2A costs vs theory
  multireduce/*  — Sec. II comparison vs Jeong et al. [21] + strawman
  framework/*    — Thm. 1/2/7/9 end-to-end decentralized encoding costs
  kernel/*       — Pallas gf_matmul micro-bench (interpret mode)
  mesh_encode/*  — lowered-HLO collective bytes, universal vs RS (subprocess)
  roofline/*     — dry-run roofline cells, if results/dryrun exists
"""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import framework_costs, kernel_bench, multireduce_compare, table1_costs

    for mod in (table1_costs, multireduce_compare, framework_costs, kernel_bench):
        for row in mod.rows():
            print(row, flush=True)

    # mesh bench needs its own process (8 forced host devices)
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parent.parent / "src")
    env.pop("XLA_FLAGS", None)
    for script, prefix in [("mesh_encode_bench.py", "mesh_encode/"),
                           ("mesh_a2a_scale.py", "mesh_a2a/")]:
        proc = subprocess.run(
            [sys.executable, str(Path(__file__).resolve().parent / script)],
            capture_output=True, text=True, env=env, timeout=1200)
        for line in proc.stdout.splitlines():
            if line.startswith(prefix):
                print(line, flush=True)
        if proc.returncode != 0:
            print(f"{prefix}FAILED,0,rc={proc.returncode}", flush=True)

    from benchmarks import roofline

    if Path("results/dryrun").exists():
        for row in roofline.rows():
            print(row, flush=True)


if __name__ == "__main__":
    main()
