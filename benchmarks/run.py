"""Benchmark driver — one section per paper table/claim.

Prints ``name,us_per_call,derived`` CSV; ``--json PATH`` additionally
writes the machine-readable ``{name: us_per_call}`` map (the CI artifact —
e.g. ``--json BENCH_recover.json`` with ``--sections recover``).  Sections:

  table1/*       — Table I: universal / DFT / Vandermonde A2A costs vs theory
  multireduce/*  — Sec. II comparison vs Jeong et al. [21] + strawman
  framework/*    — Thm. 1/2/7/9 end-to-end decentralized encoding costs
  kernel/*       — Pallas gf_matmul micro-bench (interpret mode)
  recover/*      — decode vs encode: DecodePlan kernel hot path + closed-form
                   network costs (the repair half of the pipeline)
  mesh_encode/*  — lowered-HLO collective bytes, universal vs RS (subprocess)
  mesh_a2a/*     — mesh A2A scaling (subprocess)
  roofline/*     — dry-run roofline cells, if results/dryrun exists

``--sections table1 recover ...`` restricts the run to the named sections.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from pathlib import Path

_REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_REPO / "src"))
sys.path.insert(0, str(_REPO))  # `benchmarks` namespace package, any cwd


def _emit(row: str, acc: dict[str, float]) -> None:
    print(row, flush=True)
    parts = row.split(",")
    if len(parts) >= 2:
        try:
            acc[parts[0]] = float(parts[1])
        except ValueError:
            pass


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write {name: us_per_call} JSON to PATH")
    ap.add_argument("--sections", nargs="+", default=None,
                    help="run only the named sections (default: all)")
    args = ap.parse_args()

    from benchmarks import (framework_costs, kernel_bench,
                            multireduce_compare, recover_bench, table1_costs)

    inproc = {
        "table1": table1_costs,
        "multireduce": multireduce_compare,
        "framework": framework_costs,
        "kernel": kernel_bench,
        "recover": recover_bench,
    }
    subproc = {
        "mesh_encode": ("mesh_encode_bench.py", "mesh_encode/"),
        "mesh_a2a": ("mesh_a2a_scale.py", "mesh_a2a/"),
    }
    wanted = args.sections
    if wanted is not None:
        unknown = set(wanted) - set(inproc) - set(subproc) - {"roofline"}
        if unknown:
            raise SystemExit(f"unknown sections: {sorted(unknown)} "
                             f"(have {sorted(inproc) + sorted(subproc) + ['roofline']})")

    def on(name: str) -> bool:
        return wanted is None or name in wanted

    acc: dict[str, float] = {}
    failed: list[str] = []
    print("name,us_per_call,derived")
    for name, mod in inproc.items():
        if on(name):
            for row in mod.rows():
                _emit(row, acc)

    # mesh benches need their own process (8 forced host devices)
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parent.parent / "src")
    env.pop("XLA_FLAGS", None)
    for name, (script, prefix) in subproc.items():
        if not on(name):
            continue
        proc = subprocess.run(
            [sys.executable, str(Path(__file__).resolve().parent / script)],
            capture_output=True, text=True, env=env, timeout=1200)
        for line in proc.stdout.splitlines():
            if line.startswith(prefix):
                _emit(line, acc)
        if proc.returncode != 0:
            # failure is visible in the CSV and fails the run; it is NOT
            # recorded in the JSON artifact as a fake 0us measurement
            print(f"{prefix}FAILED,0,rc={proc.returncode}", flush=True)
            failed.append(name)

    if on("roofline"):
        if (_REPO / "results" / "dryrun").exists():
            from benchmarks import roofline

            for row in roofline.rows():
                _emit(row, acc)
        elif wanted is not None:
            # explicitly requested but unrunnable: fail loudly, don't write
            # an empty artifact
            raise SystemExit("--sections roofline needs results/dryrun "
                             "(run repro.launch.dryrun first)")

    if args.json:
        Path(args.json).write_text(json.dumps(acc, indent=2, sort_keys=True))
        print(f"wrote {len(acc)} entries to {args.json}", file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmark subprocesses failed: {failed}")


if __name__ == "__main__":
    main()
