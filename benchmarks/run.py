"""Benchmark driver — one section per paper table/claim.

Prints ``name,us_per_call,derived`` CSV; ``--json PATH`` additionally
writes the machine-readable artifact, stamped with the producing commit
(``_meta.git_sha``) and a UTC timestamp so archived artifacts stay
traceable.  Keys starting with ``_`` are metadata, never gated rows.
Each JSON entry records the value
AND the benchmark's shape parameters (parsed from the ``K16``/``R4``/
``E2``/``W4096``/``p1`` tokens of the row name plus any ``backend=`` in
the derived column), so baselines stay comparable across edits::

    {"recover/decode_local_K16_R4_E4_W4096":
        {"us_per_call": 812.0,
         "params": {"K": 16, "R": 4, "E": 4, "W": 4096, "backend": "local"},
         "derived": "encode_us=..."}}

``--check BASELINE`` gates the run against a committed baseline
(``benchmarks/baselines/baseline.json``) and exits nonzero on regression:
for every baseline entry whose section was run, the shape params must
match exactly (shape drift without a baseline refresh is an error), and
the value must satisfy the entry's bound — absolute ``min``/``max`` when
present (e.g. the NTT speedup ratio's ``min: 1.5``), otherwise relative:
at most ``us_per_call * (1 + tolerance)`` with ``tolerance`` taken from
the entry or ``--tolerance`` (default 0.25).  Entries with
``"better": "higher"`` invert the relative direction.  Renames don't
silently escape the gate: a baseline entry with no measured row FAILS the
run (remove it from the baseline explicitly), and a measured row in a
gated section with no baseline entry warns loudly that it is running
ungated.  The JSON artifact is still written before the gate fires, so CI
uploads it for trend inspection even on a failing run.

Sections:

  table1/*       — Table I: universal / DFT / Vandermonde A2A costs vs theory
  multireduce/*  — Sec. II comparison vs Jeong et al. [21] + strawman
  framework/*    — Thm. 1/2/7/9 end-to-end decentralized encoding costs
  kernel/*       — Pallas gf_matmul micro-bench (interpret mode)
  recover/*      — decode vs encode: DecodePlan kernel hot path + closed-form
                   network costs (the repair half of the pipeline)
  rebuild/*      — rebuild cost vs erasure count |E|: CodedSystem.rebuild
                   wall time + closed-form repair-schedule cost
  stream/*       — streamed vs single-shot plan execution + NTT fast path
                   vs dense local encode (benchmarks/stream_bench.py)
  serve/*        — multi-tenant serving: closed/open-loop load over Zipf
                   volumes, cross-session coalescing ratio, chaos-under-load
                   correctness (benchmarks/serve_bench.py)
  coded/*        — coded computation under failure: gradient-coded train
                   step time vs injected straggler count (gated ratio +
                   bitwise-recovery flag) and the Lagrange-coded matmul
                   dropout sweep (benchmarks/coded_train_bench.py)
  topo/*         — hierarchical topology: per-placement inter-tier traffic,
                   affinity-vs-flat crossover ratio, tier-model exactness
                   (benchmarks/topo_bench.py; deterministic, tightly gated)
  mesh_encode/*  — lowered-HLO collective bytes, universal vs RS (subprocess)
  mesh_a2a/*     — mesh A2A scaling (subprocess)
  mesh/*         — the stable (HLO-census, no wall clock) rows of BOTH mesh
                   subprocess benches, folded into one gated section; the
                   section name "mesh" runs both scripts
  roofline/*     — coding-kernel fraction-of-roofline cells (NTT + dense
                   local encode vs the host's memcpy ceiling, fed by the
                   metrics registry) + dry-run cells if results/dryrun
                   exists

``--sections table1 recover ...`` restricts the run to the named sections.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import subprocess
import sys
from datetime import datetime, timezone
from pathlib import Path

_REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_REPO / "src"))
sys.path.insert(0, str(_REPO))  # `benchmarks` namespace package, any cwd

_PARAM_RE = re.compile(r"(?:^|_)([KRWEp])(\d+)(?=_|$|,)")
_BACKEND_RE = re.compile(r"(?:^|;)backend=([a-zA-Z_]+)")


def _git_sha() -> str:
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"], cwd=_REPO,
                             capture_output=True, text=True, timeout=10)
        return out.stdout.strip() or "unknown"
    except Exception:  # noqa: BLE001 — artifact metadata must never fail a run
        return "unknown"


def _params_from(name: str, derived: str) -> dict:
    """Shape parameters encoded in a row: K/R/E/W/p name tokens + backend."""
    tail = name.split("/", 1)[-1]
    params: dict = {k: int(v) for k, v in _PARAM_RE.findall(tail)}
    m = _BACKEND_RE.search(derived)
    if m:
        params["backend"] = m.group(1)
    return params


def _emit(row: str, acc: dict[str, dict]) -> None:
    print(row, flush=True)
    parts = row.split(",", 2)
    if len(parts) >= 2:
        try:
            us = float(parts[1])
        except ValueError:
            return
        derived = parts[2] if len(parts) > 2 else ""
        acc[parts[0]] = {"us_per_call": us,
                         "params": _params_from(parts[0], derived),
                         "derived": derived}


def _check_baseline(acc: dict[str, dict], base: dict[str, dict],
                    tolerance: float, ran_sections: set[str] | None
                    ) -> tuple[list[str], list[str]]:
    """Compare measured entries to the baseline.

    Returns (problems, warnings): `problems` fail the gate — including a
    baseline entry with no matching measured row (a renamed/dropped
    benchmark must not silently stop being gated); `warnings` flag the
    converse, measured rows in a gated section that have no baseline entry
    and therefore run UNGATED until the baseline is refreshed.
    """
    problems: list[str] = []
    gated_sections = {n.split("/", 1)[0] for n in base
                      if not n.startswith("_")}
    warnings = [
        f"{name}: measured but not in the baseline — NOT gated (add it to "
        "the baseline, or restore the old row name)"
        for name in sorted(acc)
        if name not in base and name.split("/", 1)[0] in gated_sections
    ]
    for name, b in sorted(base.items()):
        if name.startswith("_"):  # artifact metadata, not a gated row
            continue
        section = name.split("/", 1)[0]
        if ran_sections is not None and section not in ran_sections:
            continue
        cur = acc.get(name)
        if cur is None:
            problems.append(
                f"{name}: in baseline but not measured — a renamed or "
                "dropped benchmark must be removed from the baseline "
                "explicitly")
            continue
        bp, cp = b.get("params"), cur.get("params")
        if bp and cp and bp != cp:
            problems.append(
                f"{name}: shape params drifted (baseline {bp}, got {cp}) — "
                "regenerate the baseline if the change is intentional")
            continue
        val = cur["us_per_call"]
        if "min" in b and val < b["min"]:
            problems.append(f"{name}: {val:.2f} below required min {b['min']}")
        if "max" in b and val > b["max"]:
            problems.append(f"{name}: {val:.2f} above allowed max {b['max']}")
        if "min" in b or "max" in b or "us_per_call" not in b:
            continue
        tol = float(b.get("tolerance", tolerance))
        ref = float(b["us_per_call"])
        if b.get("better") == "higher":
            if val < ref * (1 - tol):
                problems.append(
                    f"{name}: {val:.2f} regressed below {ref:.2f} "
                    f"* (1 - {tol}) = {ref * (1 - tol):.2f}")
        elif val > ref * (1 + tol):
            problems.append(
                f"{name}: {val:.2f}us regressed above {ref:.2f}us "
                f"* (1 + {tol}) = {ref * (1 + tol):.2f}us")
    return problems, warnings


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the {name: {us_per_call, params}} artifact")
    ap.add_argument("--sections", nargs="+", default=None,
                    help="run only the named sections (default: all)")
    ap.add_argument("--check", metavar="BASELINE", default=None,
                    help="gate against a baseline JSON; nonzero exit on "
                         "regression")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="default relative tolerance for --check entries "
                         "without their own (default 0.25)")
    args = ap.parse_args()

    from benchmarks import (coded_train_bench, framework_costs, kernel_bench,
                            multireduce_compare, rebuild_bench, recover_bench,
                            serve_bench, stream_bench, table1_costs,
                            topo_bench)

    inproc = {
        "table1": table1_costs,
        "multireduce": multireduce_compare,
        "framework": framework_costs,
        "kernel": kernel_bench,
        "recover": recover_bench,
        "rebuild": rebuild_bench,
        "stream": stream_bench,
        "serve": serve_bench,
        "coded": coded_train_bench,
        "topo": topo_bench,
    }
    # each script also prints stable mesh/* rows, gated as one "mesh" section
    subproc = {
        "mesh_encode": ("mesh_encode_bench.py", ("mesh_encode/", "mesh/")),
        "mesh_a2a": ("mesh_a2a_scale.py", ("mesh_a2a/", "mesh/")),
    }
    wanted = args.sections
    if wanted is not None:
        known = set(inproc) | set(subproc) | {"roofline", "mesh"}
        unknown = set(wanted) - known
        if unknown:
            raise SystemExit(f"unknown sections: {sorted(unknown)} "
                             f"(have {sorted(known)})")

    def on(name: str) -> bool:
        return wanted is None or name in wanted

    acc: dict[str, dict] = {}
    failed: list[str] = []
    print("name,us_per_call,derived")
    for name, mod in inproc.items():
        if on(name):
            for row in mod.rows():
                _emit(row, acc)

    # mesh benches need their own process (8 forced host devices)
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parent.parent / "src")
    env.pop("XLA_FLAGS", None)
    for name, (script, prefixes) in subproc.items():
        if not (on(name) or on("mesh")):
            continue
        proc = subprocess.run(
            [sys.executable, str(Path(__file__).resolve().parent / script)],
            capture_output=True, text=True, env=env, timeout=1200)
        for line in proc.stdout.splitlines():
            if line.startswith(prefixes):
                _emit(line, acc)
        if proc.returncode != 0:
            # failure is visible in the CSV and fails the run; it is NOT
            # recorded in the JSON artifact as a fake 0us measurement
            print(f"{prefixes[0]}FAILED,0,rc={proc.returncode}", flush=True)
            failed.append(name)

    if on("roofline"):
        from benchmarks import roofline

        # coding-kernel cells run anywhere (local backend, metrics-fed);
        # dry-run cells ride along only when their artifacts exist
        for row in roofline.coding_rows():
            _emit(row, acc)
        if (_REPO / "results" / "dryrun").exists():
            for row in roofline.rows():
                _emit(row, acc)

    if args.json:
        artifact = dict(acc)
        artifact["_meta"] = {
            "git_sha": _git_sha(),
            "timestamp": datetime.now(timezone.utc).isoformat(
                timespec="seconds"),
        }
        Path(args.json).write_text(
            json.dumps(artifact, indent=2, sort_keys=True))
        print(f"wrote {len(acc)} entries to {args.json}", file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmark subprocesses failed: {failed}")
    if args.check:
        ran = None if wanted is None else set(wanted)
        base = json.loads(Path(args.check).read_text())
        problems, warnings = _check_baseline(acc, base, args.tolerance, ran)
        if warnings:
            print("PERF GATE WARNINGS (rows running UNGATED):",
                  file=sys.stderr)
            for w in warnings:
                print(f"  {w}", file=sys.stderr)
        if problems:
            print("PERF REGRESSION GATE FAILED:", file=sys.stderr)
            for p in problems:
                print(f"  {p}", file=sys.stderr)
            raise SystemExit(1)
        print(f"perf gate OK against {args.check} "
              f"({len(warnings)} ungated-row warnings)", file=sys.stderr)


if __name__ == "__main__":
    main()
