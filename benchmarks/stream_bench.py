"""Streamed vs single-shot throughput of the plan execution layer.

Three row families (all local backend — the kernel hot path):

  stream/encode_single_*   — whole-W `plan.run` wall time across
                             W in {2^12 .. 2^18} (NTT fast-path spec)
  stream/encode_stream_*   — same payload through `plan.run_stream`
                             (VMEM-sized chunks, cached chunk callables,
                             double-buffered pipeline); derived carries
                             the single-shot time and the ratio
  stream/decode_*          — the same comparison for `DecodePlan`
  stream/ntt_speedup_*     — NTT fast path vs the dense `encode_blocks`
                             field matmul at W = 2^16; us_per_call IS the
                             dimensionless speedup ratio (gated >= 1.5 by
                             the committed baseline)

Dense legs are measured once (they are the slow side by construction);
NTT/stream legs are averaged over reps.
"""
from __future__ import annotations

import time

import numpy as np

from repro.api import CodeSpec, Encoder
from repro.core.field import FERMAT
from repro.recover import Decoder


def _time(fn, reps: int = 3, warm: bool = True) -> float:
    """Best-of-reps wall time (min is far more stable than mean under CI
    runner contention; the baseline gate compares these)."""
    if warm:
        fn()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _consume(gen) -> None:
    for _ in gen:
        pass


def rows() -> list[str]:
    rng = np.random.default_rng(11)
    out = []

    # ---- streamed vs single-shot encode sweep (NTT path, rs) -------------
    K, R = 256, 64
    spec = CodeSpec(kind="rs", K=K, R=R)
    plan = Encoder.plan(spec, backend="local")
    assert plan.local_impl == "ntt"
    for logw in range(12, 19, 2):
        W = 2 ** logw
        x = FERMAT.rand((K, W), rng)
        reps = 2 if W <= 1 << 16 else 1
        us_1 = _time(lambda: plan.run(x), reps)
        us_s = _time(lambda: _consume(plan.run_stream(x)), reps)
        out.append(
            f"stream/encode_single_rs_K{K}_R{R}_W{W},{us_1:.0f},"
            f"backend=local;impl={plan.local_impl}")
        out.append(
            f"stream/encode_stream_rs_K{K}_R{R}_W{W},{us_s:.0f},"
            f"backend=local;single_us={us_1:.0f};"
            f"ratio={us_1 / max(us_s, 1e-9):.2f}")

    # ---- streamed vs single-shot decode (kernel path) --------------------
    Kd, Rd, Ed, Wd = 32, 8, 8, 1 << 16
    spec_d = CodeSpec(kind="rs", K=Kd, R=Rd, W=Wd)
    xd = FERMAT.rand((Kd, Wd), rng)
    encd = Encoder.plan(spec_d, backend="local")
    cw = np.concatenate([xd % FERMAT.q, encd.run(xd)])
    dec = Decoder.plan(spec_d, erased=tuple(range(Ed)), backend="local")
    v = cw[list(dec.kept)]
    us_1 = _time(lambda: dec.run(v), 2)
    us_s = _time(lambda: _consume(dec.run_stream(v)), 2)
    out.append(
        f"stream/decode_single_rs_K{Kd}_R{Rd}_E{Ed}_W{Wd},{us_1:.0f},"
        f"backend=local")
    out.append(
        f"stream/decode_stream_rs_K{Kd}_R{Rd}_E{Ed}_W{Wd},{us_s:.0f},"
        f"backend=local;single_us={us_1:.0f};"
        f"ratio={us_1 / max(us_s, 1e-9):.2f}")

    # ---- NTT fast path vs dense field matmul at W = 2^16 -----------------
    # the planner's two local implementations on identical payloads; the
    # speedup row is the acceptance gate (>= 1.5x for power-of-two K)
    import jax.numpy as jnp

    from repro.kernels.ops import encode_blocks

    Wf = 1 << 16
    for kind, Kf, Rf in [("rs", 128, 32), ("dft", 128, 128)]:
        spec_f = CodeSpec(kind=kind, K=Kf, R=Rf)
        pf = Encoder.plan(spec_f, backend="local")
        assert pf.local_impl == "ntt"
        xf = FERMAT.rand((Kf, Wf), rng)
        x32 = jnp.asarray(xf % FERMAT.q, jnp.uint32)
        A32 = jnp.asarray(pf.A, jnp.uint32)
        us_ntt = _time(lambda: pf.run(xf), 2)
        us_dense = _time(
            lambda: np.asarray(encode_blocks(x32, A32)), reps=1)
        ratio = us_dense / max(us_ntt, 1e-9)
        out.append(
            f"stream/encode_ntt_{kind}_K{Kf}_R{Rf}_W{Wf},{us_ntt:.0f},"
            f"backend=local;dense_us={us_dense:.0f}")
        out.append(
            f"stream/ntt_speedup_{kind}_K{Kf}_R{Rf}_W{Wf},{ratio:.2f},"
            f"backend=local;dimensionless=1;ntt_us={us_ntt:.0f};"
            f"dense_us={us_dense:.0f}")
    return out
