"""Masterless Lagrange coded computing (Remark 9): 5 data shards, 16
workers, straggler- and dropout-tolerant polynomial evaluation."""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.coding import LagrangeComputer
from repro.core.field import FERMAT

if __name__ == "__main__":
    f = FERMAT
    lcc = LagrangeComputer.build(f, K=5, N=16)
    x = f.rand((5, 4), np.random.default_rng(0))

    def poly(v):  # f(v) = v^2 + 3v + 1, degree 2
        return f.add(f.add(f.mul(v, v), f.mul(3, v)), 1)

    print(lcc.system().describe())  # the CodedSystem session behind encode
    coded = lcc.encode(x)           # paper Sec. VI / Remark 9 encode
    results = poly(coded)           # every worker computes f on its shard
    T = lcc.recovery_threshold(2)
    alive = np.random.default_rng(1).choice(16, T, replace=False)
    print(f"workers alive: {sorted(alive.tolist())} (need {T}/16)")
    decoded = lcc.decode(2, np.sort(alive), results[np.sort(alive)])
    assert np.array_equal(decoded, poly(x))
    print("OK: f(x_k) recovered exactly for all shards from", T, "of 16 workers")
