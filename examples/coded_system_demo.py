"""The session API in three lines: open a coded system, survive failures,
serve traffic.

    system = CodedSystem(CodeSpec(kind="rs", K=16, R=4), backend="local")
    system.fail([2, 17])
    x2 = system.read(cw)          # degraded read, auto-replanned

Walks one `CodedSystem` through its lifecycle — healthy encode, failures,
degraded reads (bitwise-exact), repair of exactly the lost symbols, full
`rebuild` back to health, and batched future-based submission — and
cross-checks the simulator oracle against the local kernel backend at
every step.  Then the multi-tenant layer: a `CodedService` pooling two
tenants' sessions behind one shared coding queue — cross-session
coalescing, per-tenant admission quotas, a degraded tenant, and the
per-tenant serving stats.
"""
import sys
import threading
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.api import CodedSystem, CodeSpec
from repro.core.field import FERMAT
from repro.launch.service import CodedService, TenantQuota

if __name__ == "__main__":
    K, R, W = 16, 4, 256
    x = FERMAT.rand((K, W), np.random.default_rng(0))

    system = CodedSystem(CodeSpec(kind="rs", K=K, R=R, W=W), backend="local")
    oracle = CodedSystem(CodeSpec(kind="rs", K=K, R=R, W=W),
                         backend="simulator")

    cw = system.codeword(x)                      # [x | parity], (K+R, W)
    assert np.array_equal(cw, oracle.codeword(x)), "backends disagree"
    print(f"healthy: encoded {K} shards + {R} parity "
          f"(local kernel == simulator bitwise)")

    lost = [2, 7, K + 1]                         # two data shards + a parity
    system.fail(lost)
    oracle.fail(lost)
    print(f"failed  : {list(system.failed)} "
          f"(kept survivors: {list(system.kept)})")

    x2 = system.read(cw)                         # degraded read
    assert np.array_equal(x2, x % FERMAT.q)
    assert np.array_equal(x2, oracle.read(cw))
    repaired = system.decode(cw)                 # just the lost symbols
    assert np.array_equal(repaired, cw[sorted(lost)])
    print(f"degraded: full read + {len(lost)}-symbol repair bitwise-exact; "
          f"decode model cost {oracle.stats()['decode']['model_us']:.1f} us")

    healed = system.rebuild(cw)                  # re-materialize + heal()
    assert np.array_equal(healed, cw)
    assert np.array_equal(healed, oracle.rebuild(cw))
    assert system.failed == () == oracle.failed
    print("rebuilt : all lost symbols recomputed, codeword fully healed "
          "(local == simulator bitwise)")

    fut = system.submit("encode", x)             # batched queue path
    assert np.array_equal(fut.result(timeout=60), cw[K:])
    system.close()
    print("healed  : encode again via system.submit — parity unchanged")
    print()
    print(system.describe())

    # -- traced pass: the observability layer -----------------------------
    #
    # trace= captures every layer onto ONE Chrome trace-event timeline —
    # per-processor simulator round tracks, fail/kill instants, kernel
    # spans — loadable in ui.perfetto.dev.  Alongside it, the drift
    # ledger cross-checks every simulator-backed run against the
    # closed-form cost model, bit for bit.
    import tempfile

    trace_path = str(Path(tempfile.gettempdir()) / "coded_system_trace.json")
    traced = CodedSystem(CodeSpec(kind="rs", K=K, R=R, W=W),
                         backend="simulator", trace=trace_path)
    cw3 = traced.codeword(x)                     # rounds land on the tracer
    traced.fail([3, K + 2])                      # fail instants, per proc
    assert np.array_equal(traced.read(cw3), x % FERMAT.q)
    healed3 = traced.rebuild(cw3)                # repair rounds + heal
    assert np.array_equal(healed3, cw3)
    rounds = traced.tracer.events(cat="sim.round")
    st = traced.stats()
    traced.close()                               # writes the trace JSON
    print()
    print(f"traced  : fail -> read -> heal captured as {len(rounds)} round "
          f"events on per-processor tracks -> {trace_path}")
    print(f"          drift ledger: {st['drift']['exact']}/"
          f"{st['drift']['runs']} simulator runs exact vs the closed-form "
          "cost model")

    # -- hierarchical topology: place the code on a 5x4 fleet -------------
    #
    # A Topology tells the simulator which processors share a host; the
    # affinity policy packs each prepare-and-shoot group onto one host so
    # the heavy phase-one traffic stays intra-host, while the flat
    # round-robin strawman pushes every round onto the network.  Outputs
    # are bitwise-identical either way (Remark 1) — only the per-tier
    # split of the SAME (C1, C2) moves, and the measured split matches
    # the closed form exactly.
    from repro.api import TieredLinkModel, Topology

    print()
    link = TieredLinkModel.from_ratio(4.0)       # inter links 4x pricier
    tiered = {}
    for policy in ("affinity", "flat"):
        sys_t = CodedSystem(CodeSpec(kind="rs", K=K, R=R, W=W),
                            backend="simulator",
                            topology=Topology(hosts=5, devices_per_host=4),
                            placement=policy, link=link)
        assert np.array_equal(sys_t.codeword(x), cw)   # placement-invariant
        tiers = sys_t.stats()["encode"]["tiers"]
        model = {t: (c.C1, c.C2) for t, c in tiers["model"].items()}
        assert tiers["measured"] == model, "per-tier model must be exact"
        tiered[policy] = tiers
        print(f"topo    : {policy:8s} intra C2={model['intra'][1]:6d} "
              f"inter C2={model['inter'][1]:6d} "
              f"-> {tiers['model_us']:.1f} us at 4x inter cost")
    assert (tiered["affinity"]["model"]["inter"].C2
            < tiered["flat"]["model"]["inter"].C2)
    print("topo    : affinity keeps phase-1 traffic on-host — "
          "same codeword, cheaper network")

    # -- the multi-tenant layer: two tenants, one service -----------------
    #
    # A CodedService pools CodedSystem sessions behind ONE shared coding
    # queue: requests that share a plan — same (spec, backend, A-digest) —
    # coalesce into a single batched execution even when they come from
    # DIFFERENT tenants' sessions, while each future resolves to its own
    # rows.  Admission is quota-bounded per tenant; nothing is silently
    # dropped.
    print()
    spec = CodeSpec(kind="rs", K=K, R=R, W=W)
    with CodedService(backend="local") as svc:
        # acme pays for more capacity: deeper in-flight quota, 2x fair
        # share under contention
        svc.set_quota("acme", TenantQuota(max_inflight_ops=128, weight=2.0))

        futs = []
        lock = threading.Lock()

        def tenant_client(tenant: str, seed: int) -> None:
            r = np.random.default_rng(seed)
            for _ in range(8):
                xt = FERMAT.rand((K, W), r)
                f = svc.submit(tenant, spec, "encode", xt, tag="hot-volume")
                with lock:
                    futs.append((xt, f))

        clients = [threading.Thread(target=tenant_client, args=(t, i))
                   for i, t in enumerate(["acme", "zeta"])]
        for c in clients:
            c.start()
        for c in clients:
            c.join()
        check = CodedSystem(spec, backend="local")
        for xt, f in futs:
            assert np.array_equal(f.result(timeout=60),
                                  check.codeword(xt)[K:])
        ratio = svc.stats()["service"]["coalescing_ratio"]
        print(f"service : {len(futs)} encodes from 2 tenants coalesced "
              f"{ratio:.2f}x across sessions, every future bitwise-exact")

        # zeta's volume degrades; its session's erasure state steers every
        # decode the service routes there — acme is unaffected
        zeta = svc.session("zeta", spec)
        zeta.fail([1, K + 2])
        cw2 = check.codeword(x)
        got = svc.submit("zeta", spec, "decode", cw2).result(timeout=60)
        assert np.array_equal(got, cw2[[1, K + 2]])
        print(f"service : zeta degraded {list(zeta.failed)} — repair "
              "through the shared queue, bitwise-exact")
        print()
        print(svc.describe())
