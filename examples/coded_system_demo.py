"""The session API in three lines: open a coded system, survive failures,
serve traffic.

    system = CodedSystem(CodeSpec(kind="rs", K=16, R=4), backend="local")
    system.fail([2, 17])
    x2 = system.read(cw)          # degraded read, auto-replanned

Walks one `CodedSystem` through its lifecycle — healthy encode, failures,
degraded reads (bitwise-exact), repair of exactly the lost symbols, full
`rebuild` back to health, and batched future-based submission — and
cross-checks the simulator oracle against the local kernel backend at
every step.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.api import CodedSystem, CodeSpec
from repro.core.field import FERMAT

if __name__ == "__main__":
    K, R, W = 16, 4, 256
    x = FERMAT.rand((K, W), np.random.default_rng(0))

    system = CodedSystem(CodeSpec(kind="rs", K=K, R=R, W=W), backend="local")
    oracle = CodedSystem(CodeSpec(kind="rs", K=K, R=R, W=W),
                         backend="simulator")

    cw = system.codeword(x)                      # [x | parity], (K+R, W)
    assert np.array_equal(cw, oracle.codeword(x)), "backends disagree"
    print(f"healthy: encoded {K} shards + {R} parity "
          f"(local kernel == simulator bitwise)")

    lost = [2, 7, K + 1]                         # two data shards + a parity
    system.fail(lost)
    oracle.fail(lost)
    print(f"failed  : {list(system.failed)} "
          f"(kept survivors: {list(system.kept)})")

    x2 = system.read(cw)                         # degraded read
    assert np.array_equal(x2, x % FERMAT.q)
    assert np.array_equal(x2, oracle.read(cw))
    repaired = system.decode(cw)                 # just the lost symbols
    assert np.array_equal(repaired, cw[sorted(lost)])
    print(f"degraded: full read + {len(lost)}-symbol repair bitwise-exact; "
          f"decode model cost {oracle.stats()['decode']['model_us']:.1f} us")

    healed = system.rebuild(cw)                  # re-materialize + heal()
    assert np.array_equal(healed, cw)
    assert np.array_equal(healed, oracle.rebuild(cw))
    assert system.failed == () == oracle.failed
    print("rebuilt : all lost symbols recomputed, codeword fully healed "
          "(local == simulator bitwise)")

    fut = system.submit("encode", x)             # batched queue path
    assert np.array_equal(fut.result(timeout=60), cw[K:])
    system.close()
    print("healed  : encode again via system.submit — parity unchanged")
    print()
    print(system.describe())
