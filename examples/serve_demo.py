"""Batched greedy serving demo (prefill + KV-cached decode)."""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

if __name__ == "__main__":
    sys.argv = ["serve_demo", "--arch", "mamba2_780m", "--batch", "4",
                "--prompt-len", "12", "--gen-len", "24"]
    from repro.launch.serve import main

    main()
