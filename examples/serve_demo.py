"""Batched greedy serving demo (prefill + KV-cached decode), with the coded
parameter-shard self-check (unified encoding API) gating startup and the
batched coding queue coalescing concurrent encode/decode requests into
streamed plan executions (`--queue-demo`)."""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

if __name__ == "__main__":
    sys.argv = ["serve_demo", "--arch", "mamba2_780m", "--batch", "4",
                "--prompt-len", "12", "--gen-len", "24", "--coded-selfcheck",
                "--queue-demo", "8"]
    from repro.launch.serve import main

    main()
