"""The paper's own workload: decentralized encoding of a systematic
Reed-Solomon code — universal vs specific scheduling, planned through the
unified `Encoder.plan(spec).run(x)` API, with both the Table-I model cost
and the simulator-measured C = alpha*C1 + beta*log2(q)*C2 reported."""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.api import CodeSpec, Encoder

if __name__ == "__main__":
    K, R, W = 256, 64, 8  # 256 sources, 64 parity sinks, 8-symbol payloads
    spec = CodeSpec(kind="rs", K=K, R=R, W=W)
    f = spec.field
    print(f"decentralized encoding: K={K} sources, R={R} sinks, W={W}, "
          f"F_{f.q}")
    x = f.rand((K, W), np.random.default_rng(0))

    plan_u = Encoder.plan(spec, backend="simulator", method="universal")
    plan_r = Encoder.plan(spec, backend="simulator", method="rs")
    y_u, y_r = plan_u.run(x), plan_r.run(x)
    assert np.array_equal(y_u, y_r)
    assert np.array_equal(y_u, f.matmul(plan_u.A.T, x))
    print(f"auto-selected method for this spec: "
          f"{Encoder.plan(spec, backend='simulator').method}")

    alpha, beta_bits = Encoder.ALPHA, Encoder.BETA_BITS
    for name, plan in [("universal (prepare-and-shoot)", plan_u),
                       ("RS-specific (2x draw-and-loose)", plan_r)]:
        net = plan.sim_net
        print(f"  {name:32s} C1={net.C1:3d} rounds  C2={net.C2:4d} elems  "
              f"C={net.cost(alpha, beta_bits) * 1e6:.1f} us (measured on the "
              f"round network)")
    net_u, net_r = plan_u.sim_net, plan_r.sim_net
    print(f"  C2 reduction from the paper's specific algorithm: "
          f"{net_u.C2 - net_r.C2} field elements "
          f"({100 * (1 - net_r.C2 / net_u.C2):.0f}%)")
