"""The paper's own workload: decentralized encoding of a systematic
Reed-Solomon code — universal vs specific scheduling, with the linear-model
cost C = alpha*C1 + beta*log2(q)*C2 reported for both."""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.core import FERMAT, decentralized_encode
from repro.core.cauchy import StructuredGRS

if __name__ == "__main__":
    f = FERMAT
    rng = np.random.default_rng(0)
    K, R, W = 256, 64, 8  # 256 sources, 64 parity sinks, 8-symbol payloads
    print(f"decentralized encoding: K={K} sources, R={R} sinks, W={W}, "
          f"F_{f.q}")
    sgrs = StructuredGRS.build(f, K, R)
    A = sgrs.grs.A_direct()
    x = f.rand((K, W), rng)

    y_u, net_u = decentralized_encode(f, A, x, p=1)
    y_r, net_r = decentralized_encode(f, A, x, p=1, method="rs", sgrs=sgrs)
    assert np.array_equal(y_u, y_r) and np.array_equal(y_u, f.matmul(A.T, x))

    alpha, beta_bits = 1e-5, 17e-9
    for name, net in [("universal (prepare-and-shoot)", net_u),
                      ("RS-specific (2x draw-and-loose)", net_r)]:
        print(f"  {name:32s} C1={net.C1:3d} rounds  C2={net.C2:4d} elems  "
              f"C={net.cost(alpha, beta_bits) * 1e6:.1f} us (model)")
    print(f"  C2 reduction from the paper's specific algorithm: "
          f"{net_u.C2 - net_r.C2} field elements "
          f"({100 * (1 - net_r.C2 / net_u.C2):.0f}%)")
