"""The paper's own workload: decentralized encoding of a systematic
Reed-Solomon code — a `CodedSystem` session for the encode + degraded
read, with the universal-vs-specific schedule comparison planned through
the still-public `Encoder.plan` layer underneath (both the Table-I model
cost and the simulator-measured C = alpha*C1 + beta*log2(q)*C2)."""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.api import CodedSystem, CodeSpec, Encoder, LinkModel

if __name__ == "__main__":
    K, R, W = 256, 64, 8  # 256 sources, 64 parity sinks, 8-symbol payloads
    spec = CodeSpec(kind="rs", K=K, R=R, W=W)
    f = spec.field
    print(f"decentralized encoding: K={K} sources, R={R} sinks, W={W}, "
          f"F_{f.q}")
    x = f.rand((K, W), np.random.default_rng(0))

    # the session API: encode, lose R processors, read through the failure
    system = CodedSystem(spec, backend="simulator", link=LinkModel())
    cw = system.codeword(x)
    assert np.array_equal(cw[K:], f.matmul(system.encode_plan.A.T, x))
    system.fail(range(R))              # the R worst-case data erasures
    assert np.array_equal(system.read(cw), x % f.q)
    print(f"auto-selected method for this spec: {system.encode_plan.method}"
          f" (degraded read through {R} failures verified)")
    system.heal()

    # planner layer: pin each schedule and compare measured network costs
    plan_u = Encoder.plan(spec, backend="simulator", method="universal")
    plan_r = Encoder.plan(spec, backend="simulator", method="rs")
    y_u, y_r = plan_u.run(x), plan_r.run(x)
    assert np.array_equal(y_u, y_r) and np.array_equal(y_u, cw[K:])

    alpha, beta_bits = Encoder.ALPHA, Encoder.BETA_BITS
    for name, plan in [("universal (prepare-and-shoot)", plan_u),
                       ("RS-specific (2x draw-and-loose)", plan_r)]:
        st = plan.last_stats  # this thread's last measured run
        print(f"  {name:32s} C1={st.C1:3d} rounds  C2={st.C2:4d} elems  "
              f"C={st.total(alpha, beta_bits) * 1e6:.1f} us (measured on "
              f"the round network)")
    c2_u, c2_r = plan_u.last_stats.C2, plan_r.last_stats.C2
    print(f"  C2 reduction from the paper's specific algorithm: "
          f"{c2_u - c2_r} field elements ({100 * (1 - c2_r / c2_u):.0f}%)")
