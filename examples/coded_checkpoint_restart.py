"""Fault-tolerance demo: train, kill 4 of 16 state shards mid-run, restore
from Reed-Solomon parity (the paper's decentralized encoding output), and
verify training continues bit-identically to an uninterrupted run."""
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax

from repro.ckpt import CodedCheckpointer
from repro.configs import get_config
from repro.data import SyntheticLM
from repro.train import init_state, make_train_setup, make_train_step


def run(steps, ckpt=None, fail_at=None, fail_shards=frozenset()):
    cfg = get_config("qwen3_1_7b").smoke()
    opt, _ = make_train_setup(cfg, total_steps=steps, peak_lr=5e-3)
    state = init_state(cfg, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(cfg, opt))
    data = SyntheticLM(cfg.vocab, 64, 8)
    losses = []
    for i in range(steps):
        state, m = step(state, data.device_batch(i))
        losses.append(float(m["loss"]))
        if ckpt and (i + 1) % 10 == 0:
            ckpt.save(i + 1, jax.device_get(state))
        if ckpt and fail_at == i:
            print(f"  !! shards {sorted(fail_shards)} lost at step {i}; "
                  f"reconstructing from RS parity...")
            s = ckpt.latest_step()
            state = ckpt.restore(s, state, failed_shards=fail_shards)
            # rewind to the checkpoint step and replay (deterministic data)
            return losses[:s] + run_from(state, step, data, s, steps)
    return losses


def run_from(state, step, data, start, steps):
    losses = []
    for i in range(start, steps):
        state, m = step(state, data.device_batch(i))
        losses.append(float(m["loss"]))
    return losses


if __name__ == "__main__":
    with tempfile.TemporaryDirectory() as td:
        print("baseline run (no failures)...")
        base = run(30)
        print("run with 4/16 shard failures at step 17...")
        ck = CodedCheckpointer(td, n_shards=16, n_parity=4)
        recov = run(30, ckpt=ck, fail_at=17, fail_shards={2, 5, 11, 14})
        drift = max(abs(a - b) for a, b in zip(base, recov))
        print(f"max loss drift vs uninterrupted run: {drift:.2e}")
        assert drift < 1e-5, "coded restore must be exact"
        print("OK: training recovered bit-identically from 4 lost shards")
