"""Quickstart: train a small LM end-to-end with coded checkpointing.

    PYTHONPATH=src python examples/quickstart.py            # ~2 min on CPU
    PYTHONPATH=src python examples/quickstart.py --hundred-m # ~100M params

Drives the same launcher used in production (repro.launch.train); the only
difference on a TPU pod is --production (16x16 mesh shardings).
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

if __name__ == "__main__":
    hundred_m = "--hundred-m" in sys.argv
    argv = ["quickstart", "--arch", "qwen3_1_7b", "--steps", "60",
            "--peak-lr", "5e-3", "--batch", "8", "--seq-len", "128",
            "--ckpt-dir", "/tmp/repro_quickstart_ckpt", "--ckpt-every", "30",
            "--ckpt-shards", "8", "--ckpt-parity", "2"]
    if hundred_m:
        # ~100M params: widen the reduced config (trains for real; slower)
        argv += ["--d-model", "512", "--n-layers", "8", "--steps", "200"]
    sys.argv = argv
    from repro.launch.train import main

    main()
