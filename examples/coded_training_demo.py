"""Training that keeps its step while workers straggle, and inference
that keeps its answers while workers die.

    coder = GradientCoder(n_workers=4, s=1)
    step = make_straggler_train_step(cfg, opt, coder)
    state, m = step(state, batch, alive)   # any <= s stragglers: exact

Walks the two coded-computation workloads end to end:

  1. Straggler-tolerant training — the global batch is cut across 4
     data-parallel workers per the fractional-repetition assignment
     (groups of s+1 sharing parts); each step decodes around an injected
     straggler mask and the recovered gradient is BITWISE-equal to the
     all-alive step, under random and bursty `StragglerInjector` patterns
     driven by the simulator's `FaultInjector`.
  2. Coded inference — a layer matmul Y = X @ W runs Lagrange-coded
     through a `CodedSystem` (`CodedMatmul`): K data shards + R parity
     workers; any <= R dropouts decode around via the recover/ stack,
     bitwise-exactly.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.coding import CodedMatmul, GradientCoder
from repro.configs import get_config
from repro.core.field import FERMAT
from repro.data import SyntheticLM
from repro.train import (StragglerInjector, init_state,
                         make_straggler_train_step, make_train_setup)

if __name__ == "__main__":
    # -- 1. straggler-tolerant training ----------------------------------
    cfg = get_config("qwen3_1_7b").smoke()
    opt, _ = make_train_setup(cfg, total_steps=20, peak_lr=3e-3)
    state = init_state(cfg, jax.random.PRNGKey(0), opt)
    data = SyntheticLM(cfg.vocab, seq_len=32, global_batch=8)

    coder = GradientCoder(n_workers=4, s=1)
    step = make_straggler_train_step(cfg, opt, coder)
    print(f"gradient coding: {coder.n_workers} workers in "
          f"{coder.n_groups} groups, s={coder.s} stragglers tolerated")

    # bitwise recovery: every <= s straggler pattern lands the exact
    # all-alive parameters
    batch = data.device_batch(0)
    ref, _ = step(state, batch)
    for dead in ([0], [1], [3]):
        alive = np.ones(4, bool)
        alive[dead] = False
        got, m = step(state, batch, alive)
        same = all(np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(jax.tree.leaves(got.params),
                                   jax.tree.leaves(ref.params)))
        assert same, dead
        print(f"  straggler {dead}: recovered gradient bitwise == all-alive "
              f"(loss {float(m['loss']):.4f})")

    # a short run under FaultInjector-driven patterns
    for mode in ("random", "bursty"):
        st, steps, straggled = state, 10, 0
        inj = StragglerInjector.build(mode, coder, steps, rate=0.6, seed=1)
        for t in range(steps):
            st, m = step(st, data.device_batch(t), inj.mask(t))
            straggled += m["stragglers"]
        print(f"  {mode:6s}: {steps} steps, {straggled} worker-steps "
              f"straggled ({len(inj.plan)} planned), "
              f"final loss {float(m['loss']):.4f}")

    # > s in one group is refused loudly, before the device step
    alive = np.ones(4, bool)
    alive[[0, 1]] = False  # group 0 wiped out
    try:
        step(state, batch, alive)
        raise SystemExit("should have raised")
    except RuntimeError as exc:
        print(f"  > s stragglers in a group: {exc}")

    # -- 2. coded inference (Lagrange-coded matmul) -----------------------
    print()
    rng = np.random.default_rng(0)
    K, R, b = 8, 4, 4
    X = FERMAT.rand((K * b, 64), rng)   # a layer's (quantized) activations
    W = FERMAT.rand((64, 32), rng)      # its weight shard
    truth = FERMAT.matmul(X, W)
    with CodedMatmul(K, R) as cm:
        print(f"coded matmul: K={K} data shards + R={R} parity workers "
              f"(backend={cm.backend})")
        for dead in ([], [3], [0, 9], [1, 5, 8, 11]):
            Y = cm(X, W, dead=dead)
            assert np.array_equal(Y, truth)
            print(f"  dropouts {dead or 'none'}: Y = X @ W recovered "
                  "bitwise-exactly")
    print()
    print("coded computation demo OK")
