"""Unified coding API: one session handle over the encode and decode
stacks, one planner layer, one backend registry.

The three-line scenario — open a coded system, survive failures, serve
traffic:

    from repro.api import CodeSpec, CodedSystem

    system = CodedSystem(CodeSpec(kind="rs", K=16, R=4), backend="local")
    cw = system.codeword(x)      # [x | parity] systematic codeword
    system.fail([2, 17]); x2 = system.read(cw); system.heal()

Architecture (each layer public, each composing the one below):

    CodedSystem (api.system)   — session: erasure state, auto-replanned
                                 degraded reads, streamed/batched/queued
                                 submission, stats
    Encoder / Decoder planners — plan-then-execute: host tables + schedule
    (api.planner,                selection resolved once, cached by spec
     recover.planner)            (x erasure pattern for decode)
    Backend registry           — `Backend` protocol + `register_backend`;
    (api.registry,               capability checks at plan time; built-ins
     api.backends)               simulator / mesh / local
    kernels / core             — Pallas/jnp GF kernels, NTT fast path,
                                 shard_map bodies, the round simulator

Plans execute on any registered backend with bitwise-identical results;
`plan.run_stream`/`run_batched` stream them (api.stream).  Host-side
tables are cached per spec and shared between the encode and decode
stacks; `cache_clear()` below clears both sides coherently.
"""
from ..topo import (
    Placement,
    TieredCost,
    TieredLinkModel,
    Topology,
    place,
    tiered_encode_cost,
)
from .planner import ALPHA_DEFAULT, BETA_BITS_DEFAULT, EncodePlan, Encoder, method_costs
from .registry import (
    Backend,
    BackendCapabilityError,
    RunStats,
    available_backends,
    get_backend,
    register_backend,
    unregister_backend,
)
from .spec import CodeSpec
from .stream import StreamStats, default_chunk_w
from .system import CodedSystem, LinkModel

__all__ = [
    "CodeSpec", "CodedSystem", "LinkModel",
    "Encoder", "EncodePlan", "method_costs",
    "Backend", "BackendCapabilityError", "RunStats",
    "register_backend", "unregister_backend", "get_backend",
    "available_backends",
    "StreamStats", "default_chunk_w",
    "Topology", "TieredLinkModel", "TieredCost",
    "Placement", "place", "tiered_encode_cost",
    "cache_clear", "cache_info",
    "ALPHA_DEFAULT", "BETA_BITS_DEFAULT",
]


def cache_clear() -> None:
    """Clear Encoder plans, Decoder plans, and the shared host-table cache
    together.  Clearing only the encode side would leave cached decode
    plans holding references into the dropped host tables — this is the
    one coordinated entry point (Encoder.cache_clear does the same)."""
    Encoder.cache_clear()


def cache_info() -> dict:
    """Combined cache statistics of both stacks:
    {"encode": Encoder.cache_info(), "decode": Decoder.cache_info()}."""
    from ..recover.planner import Decoder

    return {"encode": Encoder.cache_info(), "decode": Decoder.cache_info()}
