"""Unified encoding API: one planner over simulator, mesh, and kernel
backends.

    from repro.api import CodeSpec, Encoder

    spec = CodeSpec(kind="rs", K=16, R=4)
    plan = Encoder.plan(spec, backend="simulator")   # auto-selects algorithm
    parity = plan.run(x)                             # (R, W) sink values

The same plan semantics execute on three backends — `"simulator"`
(RoundNetwork lockstep, measured C1/C2), `"mesh"` (shard_map/ppermute,
devices as processors), `"local"` (Pallas/jnp kernel) — with bitwise-equal
sink values.  Host-side tables are cached per spec; see `planner` for the
cache contract and `spec` for the CodeSpec fields.
"""
from .planner import ALPHA_DEFAULT, BETA_BITS_DEFAULT, Encoder, EncodePlan, method_costs
from .spec import CodeSpec
from .stream import StreamStats, default_chunk_w

__all__ = [
    "CodeSpec", "Encoder", "EncodePlan", "method_costs",
    "StreamStats", "default_chunk_w",
    "ALPHA_DEFAULT", "BETA_BITS_DEFAULT",
]
