"""Streaming execution under `EncodePlan.run` / `DecodePlan.run`.

The cost model charges every all-to-all encode per symbol of payload width
W, so the throughput regime is *streaming*: large payloads arrive (or are
produced) in pieces, and the executor should amortize planning, jit
dispatch, and host<->device transfers across them instead of re-paying
them per whole-W call.  This module is the engine behind
`plan.run_stream(chunks)` and `plan.run_batched(xs)` on both planners:

* the W (payload) axis is split into VMEM-sized chunks
  (`default_chunk_w`: the (K, w) uint32 tile fits a fixed byte budget,
  rounded to full 128-lane registers);
* each (spec, backend, chunk-shape) gets ONE cached jitted callable —
  the plan holds a single traced function and jit's shape cache keys the
  per-width executables, so a long stream never re-traces (a ragged last
  chunk costs exactly one extra compile);
* on the local and mesh backends the pipeline is double-buffered: chunk
  k+1's host->device transfer is enqueued while chunk k's compute is in
  flight, and chunk k's result is only materialized afterwards;
* the simulator backend keeps lockstep semantics per chunk and records
  EXACT per-chunk C1/C2 on `plan.stream_stats` (a fresh `RoundNetwork`
  per chunk — C1 is per-chunk rounds, C2 scales with the chunk width).

Buffer donation: on accelerator backends the chunk input buffer is donated
to the jitted callable when the output aliases its shape (square
transforms, mesh schedules); on CPU donation is unsupported and skipped.

Bitwise contract (tested across all backends and both planners):

    np.concatenate(list(plan.run_stream(chunks)), axis=1)
        == plan.run(np.concatenate(chunks, axis=1))
"""
from __future__ import annotations

from dataclasses import dataclass, field as dc_field
from typing import Callable, Iterable, Iterator

import numpy as np

from ..obs.metrics import REGISTRY as _METRICS
from ..obs.trace import get_tracer

DEFAULT_VMEM_BUDGET_BYTES = 4 << 20  # (K, w) uint32 payload tile budget
_LANES = 128                         # TPU register lane width

_CHUNKS = _METRICS.counter("stream_chunks_total",
                           "chunks executed through run_stream")
_CHUNK_ELEMS = _METRICS.counter(
    "stream_elems_total", "payload field elements streamed (K * w summed)")


def default_chunk_w(K: int, *, itemsize: int = 4,
                    budget_bytes: int = DEFAULT_VMEM_BUDGET_BYTES) -> int:
    """Largest multiple of 128 lanes such that a (K, w) tile fits the
    budget (at least one full lane group)."""
    return max(_LANES, budget_bytes // (K * itemsize) // _LANES * _LANES)


@dataclass
class StreamStats:
    """Per-chunk accounting of one `run_stream` pass (simulator backend
    additionally fills the exact C1/C2 of each chunk's lockstep run)."""

    widths: list[int] = dc_field(default_factory=list)
    C1: list[int] = dc_field(default_factory=list)
    C2: list[int] = dc_field(default_factory=list)

    @property
    def chunks(self) -> int:
        return len(self.widths)

    @property
    def W(self) -> int:
        return sum(self.widths)

    def totals(self) -> tuple[int, int]:
        """(sum C1, sum C2) across chunks — the cost of the streamed run
        as the round network actually measured it."""
        return sum(self.C1), sum(self.C2)


def iter_chunks(payload, K: int, chunk_w: int | None) -> Iterator[np.ndarray]:
    """Normalize a payload into (K, w) chunks.

    A single (K, W) array is split into `chunk_w`-wide pieces; an iterable
    of arrays is streamed as given, each piece re-split only if it exceeds
    `chunk_w`.  Chunks must all carry the plan's K rows.  Zero-width
    pieces yield nothing (a stream of no data has no chunks).
    """
    if isinstance(payload, np.ndarray) or hasattr(payload, "shape"):
        pieces: Iterable = (payload,)
    else:
        pieces = payload
    cw = chunk_w or default_chunk_w(K)
    for piece in pieces:
        piece = np.asarray(piece)
        if piece.ndim != 2 or piece.shape[0] != K:
            raise ValueError(
                f"stream chunks must be (K={K}, w) arrays, got {piece.shape}")
        for c0 in range(0, piece.shape[1], cw):
            yield piece[:, c0 : c0 + cw]


def split_chunks(payload, chunk_w: int) -> Iterator[np.ndarray]:
    """Split a (rows, W) array or an iterable of (rows, w_i) pieces into
    chunks of width <= `chunk_w`, preserving whatever leading dim the
    pieces carry (the caller validates it — unlike `iter_chunks` this is
    row-count-agnostic, for streams that carry full codeword rows).
    Zero-width pieces yield nothing."""
    pieces: Iterable = ((payload,) if hasattr(payload, "shape") else payload)
    for piece in pieces:
        piece = np.asarray(piece)
        if piece.ndim != 2:
            raise ValueError(
                f"stream chunks must be 2-D (rows, w) arrays, got "
                f"{piece.shape}")
        for c0 in range(0, piece.shape[1], chunk_w):
            yield piece[:, c0 : c0 + chunk_w]


def run_paired_stream(plan, chunks: Iterator[np.ndarray], slice_fn: Callable,
                      *, chunk_w: int) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    """Drive `plan.run_stream` over `slice_fn(chunk)` while pairing every
    output block 1:1 with the chunk it came from — the passthrough side of
    a rebuild rides along with the repaired rows, still through the
    double-buffered device pipeline.

    `chunks` must already be split to width <= `chunk_w` (use
    `split_chunks` with the same value) so `run_stream` never re-splits a
    piece and the pairing stays aligned; the pipeline's one-chunk
    read-ahead means at most two chunks are held at once.
    """
    from collections import deque

    pending: deque = deque()

    def _feed():
        for c in chunks:
            pending.append(c)
            yield slice_fn(c)

    for y in plan.run_stream(_feed(), chunk_w=chunk_w):
        yield pending.popleft(), y


def _pipelined(chunks: Iterator[np.ndarray], to_device: Callable,
               dev_fn: Callable, finalize: Callable,
               tracer=None) -> Iterator[np.ndarray]:
    """Double-buffered device pipeline.

    For each chunk: dispatch compute on the resident buffer, enqueue the
    NEXT chunk's host->device transfer, and only then materialize the
    in-flight result — so on an async backend the k+1 transfer overlaps
    the k compute, and the jitted callable's buffers turn over without a
    host sync between chunks.

    With a `tracer`, the three pipeline stages of every chunk become
    spans on a "stream"/"pipeline" track (h2d / dispatch / materialize);
    the untraced loop is the byte-identical fast path.
    """
    if tracer is None:
        cur = None
        for c in chunks:
            if cur is None:
                cur = to_device(c)
                continue
            y = dev_fn(cur)          # async dispatch of chunk k
            cur = to_device(c)       # H2D of chunk k+1 overlaps the compute
            yield finalize(y)        # block on chunk k only now
        if cur is not None:
            yield finalize(dev_fn(cur))
        return

    def _span(name, k):
        return tracer.span(name, pid="stream", tid="pipeline",
                           cat="stream", args={"chunk": k})

    cur = None
    k = 0          # index of the chunk resident on device
    n = 0          # index of the chunk being transferred
    for c in chunks:
        if cur is None:
            with _span("h2d", n):
                cur = to_device(c)
            n += 1
            continue
        with _span("dispatch", k):
            y = dev_fn(cur)
        with _span("h2d", n):
            cur = to_device(c)
        with _span("materialize", k):
            out = finalize(y)
        yield out
        k += 1
        n += 1
    if cur is not None:
        with _span("dispatch", k):
            y = dev_fn(cur)
        with _span("materialize", k):
            out = finalize(y)
        yield out


def run_stream(plan, payload, *, chunk_w: int | None = None
               ) -> Iterator[np.ndarray]:
    """Generator of per-chunk outputs for `plan` (encode or decode).

    Dispatch follows the plan's registered backend capabilities: a
    network-measuring backend (simulator) runs lockstep per chunk and
    records exact per-chunk C1/C2 on `plan.stream_stats`; a
    `supports_stream` backend (local/mesh) supplies the double-buffered
    device pipeline via the plan's `_stream_device_fn()` adapter; any
    other registered backend streams by plain per-chunk `encode`/`decode`
    calls — no pipelining, but the bitwise contract still holds.
    """
    from .registry import get_backend

    K = plan.spec.K

    def _counted(cs):
        for c in cs:
            _CHUNKS.inc(1, op=plan.op, backend=plan.backend)
            _CHUNK_ELEMS.inc(K * c.shape[1], op=plan.op,
                             backend=plan.backend)
            yield c

    chunks = _counted(iter_chunks(payload, K, chunk_w))
    backend = get_backend(plan.backend)
    if backend.measures_network:
        stats = StreamStats()
        plan.stream_stats = stats
        for c in chunks:
            y, net = plan._stream_sim_chunk(c)
            stats.widths.append(c.shape[1])
            stats.C1.append(net.C1)
            stats.C2.append(net.C2)
            plan._record_net(net, op=plan.op, width=c.shape[1])
            yield y
        return
    if backend.supports_stream:
        to_device, dev_fn, finalize = plan._stream_device_fn()
        yield from _pipelined(chunks, to_device, dev_fn, finalize,
                              tracer=get_tracer())
        return
    run_chunk = backend.encode if plan.op == "encode" else backend.decode
    for c in chunks:
        yield run_chunk(plan, c)


def run_batched(plan, xs, *, chunk_w: int | None = None) -> list[np.ndarray]:
    """Coalesce a batch of payloads into one streamed execution.

    xs: list of (K,) or (K, W_i) arrays (W_i may differ per request).
    The payloads are concatenated on the W axis, run through `run_stream`
    (so concurrent requests share chunk callables and the transfer/compute
    pipeline), and the outputs are split back per request.
    """
    K = plan.spec.K
    norm: list[np.ndarray] = []
    squeeze: list[bool] = []
    for x in xs:
        x = np.asarray(x)
        if x.shape[0] != K:
            raise ValueError(f"payload leading dim must be K={K}, got {x.shape}")
        squeeze.append(x.ndim == 1)
        norm.append(x[:, None] if x.ndim == 1 else x)
    if not norm:
        return []
    widths = [x.shape[1] for x in norm]
    big = np.concatenate(norm, axis=1)
    if big.shape[1] == 0:
        y = plan.run(big)  # zero-width batch: keep run()'s (rows, 0) shape
    else:
        y = np.concatenate(list(run_stream(plan, big, chunk_w=chunk_w)),
                           axis=1)
    out: list[np.ndarray] = []
    col = 0
    for w, sq in zip(widths, squeeze):
        piece = y[:, col : col + w]
        out.append(piece[:, 0] if sq else piece)
        col += w
    return out


def maybe_donate_jit(fn: Callable, *, donate: bool) -> Callable:
    """jit(fn), donating the payload buffer when the backend supports it
    (donation is a no-op with a warning on CPU, so it is gated off there)."""
    import jax

    if donate and jax.default_backend() != "cpu":
        return jax.jit(fn, donate_argnums=(0,))
    return jax.jit(fn)
