"""The three interchangeable executors behind `EncodePlan.run`.

    simulator — the round-based `RoundNetwork` lockstep engine (exact numpy
                oracle; also yields measured C1/C2 on `plan.sim_net`)
    mesh      — devices-as-processors `shard_map`/`ppermute` execution (one
                device per source, sinks overlaid on devices 0..R-1)
    local     — single-device `kernels.ops.encode_blocks` (Pallas/jnp field
                matmul; no communication schedule at all)

All three return the same sink values bitwise: sink r holds x^T A[:, r] over
F_q.  Inputs/outputs are normalized to numpy int64 (K, W) -> (R, W).
"""
from __future__ import annotations

from functools import partial

import numpy as np

from ..core.dft_a2a import dft_a2a
from ..core.framework import decentralized_encode
from ..core.simulator import RoundNetwork


def run_simulator(plan, x: np.ndarray) -> np.ndarray:
    """Execute the plan on the paper's p-port round network; the network
    (with measured C1/C2) is kept on `plan.sim_net` for inspection."""
    spec, f = plan.spec, plan.field
    x = f.arr(x)
    if spec.kind == "dft":
        net = RoundNetwork(spec.K, spec.p)
        out: dict[int, np.ndarray] = {}
        net.run(dft_a2a(f, {k: x[k] for k in range(spec.K)},
                        list(range(spec.K)), spec.p, spec.P, out))
        y = np.stack([out[k] for k in range(spec.K)])
    else:
        method = "rs" if plan.method == "rs" else "universal"
        y, net = decentralized_encode(f, plan.A, x, p=spec.p, method=method,
                                      sgrs=plan.sgrs)
    plan.sim_net = net
    return np.asarray(y, np.int64)


def local_encode_callable(plan):
    """The plan's single jitted local-encode executable (K, w) uint32 ->
    (R, w) uint32, cached on the plan for its lifetime.

    The planner auto-selects the O(K log K) NTT fast path
    (`kernels.ntt_encode`) for dft and structured rs/lagrange specs when
    their point sets are radix-2 single cosets (in particular, K a power
    of two); otherwise this is the dense `encode_blocks` field matmul.
    Both are exact mod-q arithmetic, so the choice is bitwise-invisible.
    jit's shape cache makes one executable per chunk width.
    """
    if plan._local_fn is None:
        import jax.numpy as jnp

        from .stream import maybe_donate_jit

        params = plan.tables.ntt_params()
        if params is not None:
            from ..kernels.ntt_encode import ntt_encode

            fn = maybe_donate_jit(lambda x: ntt_encode(x, params),
                                  donate=plan.spec.K == plan.spec.R)
        else:
            from ..kernels.ops import encode_blocks

            A = jnp.asarray(plan.A, jnp.uint32)
            fn = maybe_donate_jit(lambda x: encode_blocks(x, A),
                                  donate=False)
        plan._local_fn = fn
    return plan._local_fn


def run_local(plan, x: np.ndarray) -> np.ndarray:
    """Single-device encode on the kernel path (no network): the cached
    jitted NTT fast path or dense field matmul, per the planner."""
    import jax.numpy as jnp

    x32 = jnp.asarray(np.asarray(x) % plan.field.q, jnp.uint32)
    y = local_encode_callable(plan)(x32)
    return np.asarray(y, np.int64)


def _require_devices(n: int):
    import jax

    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh backend needs >= {n} devices, found {len(devs)} "
            "(hint: XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return devs[:n]


def build_mesh_callable(plan):
    """Jitted global-array function (K, W) uint32 -> (K, W) uint32 running
    the plan's schedule under shard_map on the first K devices.  Device k
    holds source k; after the call devices 0..R-1 hold the sink values."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P

    from ..core.parity import mesh_parity_encode
    from ..core.shardmap_exec import mesh_dft, shard_map

    spec = plan.spec
    devs = _require_devices(spec.K)
    mesh = Mesh(np.array(devs), ("enc",))

    if spec.kind == "dft":
        t = plan.tables.dft_mesh_tables()

        @partial(shard_map, mesh=mesh,
                 in_specs=(P("enc"), P("enc"), P("enc")), out_specs=P("enc"))
        def step(xb, ca, cb):
            return mesh_dft(xb[0], ca[0], cb[0], t, "enc")[None]

        args = (jnp.asarray(t.ca.T), jnp.asarray(t.cb.T))
        return jax.jit(lambda xg: step(xg, *args))

    if spec.K % spec.R != 0:
        raise NotImplementedError(
            f"mesh backend covers the R | K grid (Sec. III-A); got "
            f"K={spec.K}, R={spec.R}")
    t = plan.tables.mesh_tables(plan.method)
    arrs = t.device_arrays()
    keys = list(arrs)

    @partial(shard_map, mesh=mesh,
             in_specs=(P("enc"),) + tuple(P("enc") for _ in keys),
             out_specs=P("enc"))
    def step(xb, *tb):
        rows = {k: v[0] for k, v in zip(keys, tb)}
        return mesh_parity_encode(xb[0], rows, t, "enc")[None]

    args = tuple(jnp.asarray(arrs[k]) for k in keys)
    return jax.jit(lambda xg: step(xg, *args))


def run_mesh(plan, x: np.ndarray) -> np.ndarray:
    import jax.numpy as jnp

    spec = plan.spec
    fn = plan.mesh_callable()
    y = np.asarray(fn(jnp.asarray(np.asarray(x) % plan.field.q, jnp.uint32)),
                   np.int64)
    return y if spec.kind == "dft" else y[: spec.R]


RUNNERS = {"simulator": run_simulator, "local": run_local, "mesh": run_mesh}
BACKENDS = tuple(RUNNERS)
