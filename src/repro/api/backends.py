"""The three interchangeable executors behind `EncodePlan.run`.

    simulator — the round-based `RoundNetwork` lockstep engine (exact numpy
                oracle; also yields measured C1/C2 on `plan.sim_net`)
    mesh      — devices-as-processors `shard_map`/`ppermute` execution (one
                device per source, sinks overlaid on devices 0..R-1)
    local     — single-device `kernels.ops.encode_blocks` (Pallas/jnp field
                matmul; no communication schedule at all)

All three return the same sink values bitwise: sink r holds x^T A[:, r] over
F_q.  Inputs/outputs are normalized to numpy int64 (K, W) -> (R, W).
"""
from __future__ import annotations

from functools import partial

import numpy as np

from ..core.dft_a2a import dft_a2a
from ..core.framework import decentralized_encode
from ..core.simulator import RoundNetwork


def run_simulator(plan, x: np.ndarray) -> np.ndarray:
    """Execute the plan on the paper's p-port round network; the network
    (with measured C1/C2) is kept on `plan.sim_net` for inspection."""
    spec, f = plan.spec, plan.field
    x = f.arr(x)
    if spec.kind == "dft":
        net = RoundNetwork(spec.K, spec.p)
        out: dict[int, np.ndarray] = {}
        net.run(dft_a2a(f, {k: x[k] for k in range(spec.K)},
                        list(range(spec.K)), spec.p, spec.P, out))
        y = np.stack([out[k] for k in range(spec.K)])
    else:
        method = "rs" if plan.method == "rs" else "universal"
        y, net = decentralized_encode(f, plan.A, x, p=spec.p, method=method,
                                      sgrs=plan.sgrs)
    plan.sim_net = net
    return np.asarray(y, np.int64)


def run_local(plan, x: np.ndarray) -> np.ndarray:
    """Single-device encode on the Pallas/jnp kernel path (no network)."""
    import jax.numpy as jnp

    from ..kernels.ops import encode_blocks

    x32 = jnp.asarray(np.asarray(x) % plan.field.q, jnp.uint32)
    y = encode_blocks(x32, jnp.asarray(plan.A, jnp.uint32))
    return np.asarray(y, np.int64)


def _require_devices(n: int):
    import jax

    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh backend needs >= {n} devices, found {len(devs)} "
            "(hint: XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return devs[:n]


def build_mesh_callable(plan):
    """Jitted global-array function (K, W) uint32 -> (K, W) uint32 running
    the plan's schedule under shard_map on the first K devices.  Device k
    holds source k; after the call devices 0..R-1 hold the sink values."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P

    from ..core.parity import mesh_parity_encode
    from ..core.shardmap_exec import mesh_dft, shard_map

    spec = plan.spec
    devs = _require_devices(spec.K)
    mesh = Mesh(np.array(devs), ("enc",))

    if spec.kind == "dft":
        t = plan.tables.dft_mesh_tables()

        @partial(shard_map, mesh=mesh,
                 in_specs=(P("enc"), P("enc"), P("enc")), out_specs=P("enc"))
        def step(xb, ca, cb):
            return mesh_dft(xb[0], ca[0], cb[0], t, "enc")[None]

        args = (jnp.asarray(t.ca.T), jnp.asarray(t.cb.T))
        return jax.jit(lambda xg: step(xg, *args))

    if spec.K % spec.R != 0:
        raise NotImplementedError(
            f"mesh backend covers the R | K grid (Sec. III-A); got "
            f"K={spec.K}, R={spec.R}")
    t = plan.tables.mesh_tables(plan.method)
    arrs = t.device_arrays()
    keys = list(arrs)

    @partial(shard_map, mesh=mesh,
             in_specs=(P("enc"),) + tuple(P("enc") for _ in keys),
             out_specs=P("enc"))
    def step(xb, *tb):
        rows = {k: v[0] for k, v in zip(keys, tb)}
        return mesh_parity_encode(xb[0], rows, t, "enc")[None]

    args = tuple(jnp.asarray(arrs[k]) for k in keys)
    return jax.jit(lambda xg: step(xg, *args))


def run_mesh(plan, x: np.ndarray) -> np.ndarray:
    import jax.numpy as jnp

    spec = plan.spec
    fn = plan.mesh_callable()
    y = np.asarray(fn(jnp.asarray(np.asarray(x) % plan.field.q, jnp.uint32)),
                   np.int64)
    return y if spec.kind == "dft" else y[: spec.R]


RUNNERS = {"simulator": run_simulator, "local": run_local, "mesh": run_mesh}
BACKENDS = tuple(RUNNERS)
