"""The three built-in executors behind `EncodePlan.run`, registered on the
`api.registry` Backend protocol.

    simulator — the round-based `RoundNetwork` lockstep engine (exact numpy
                oracle; measured C1/C2 recorded thread-locally on
                `plan.last_stats` / `plan.sim_net`)
    mesh      — devices-as-processors `shard_map`/`ppermute` execution (one
                device per source, sinks overlaid on devices 0..R-1)
    local     — single-device `kernels.ops.encode_blocks` (Pallas/jnp field
                matmul; no communication schedule at all)

All three return the same sink values bitwise: sink r holds x^T A[:, r] over
F_q.  Inputs/outputs are normalized to numpy int64 (K, W) -> (R, W).  The
decode halves of the same three backends live in `recover.backends`; the
`Backend` objects below bind both, so one registry serves both planners.
"""
from __future__ import annotations

from functools import partial

import numpy as np

from ..core import schedule
from ..core.field import FERMAT_Q
from ..core.simulator import RoundNetwork
from ..obs.trace import kernel_span
from .registry import Backend, BackendCapabilityError, register_backend


def run_simulator(plan, x: np.ndarray) -> tuple[np.ndarray, RoundNetwork]:
    """Execute the plan on the paper's p-port round network; returns
    (sink values, the network with its measured C1/C2).

    All four kinds run through one path: the plan's schedule IR
    (`plan.schedule_ir()` — the canonical builder output, or the
    `tier_commute`-rewritten program for `commute=True` plans) executed
    generically by `core.schedule.execute`, which emits the exact same
    rounds the retired per-kind generator dispatch produced."""
    spec, f = plan.spec, plan.field
    x = f.arr(x)
    pl = getattr(plan, "placement", None)
    ir = plan.schedule_ir()
    net = RoundNetwork(ir.n_procs, spec.p, placement=pl)
    y = schedule.execute(ir, f, x, net)
    return np.asarray(y, np.int64), net


def local_encode_callable(plan):
    """The plan's single jitted local-encode executable (K, w) uint32 ->
    (R, w) uint32, cached on the plan for its lifetime.

    The planner auto-selects the O(K log K) NTT fast path
    (`kernels.ntt_encode`) for dft and structured rs/lagrange specs when
    their point sets are radix-2 single cosets (in particular, K a power
    of two); otherwise this is the dense `encode_blocks` field matmul.
    Both are exact mod-q arithmetic, so the choice is bitwise-invisible.
    jit's shape cache makes one executable per chunk width.
    """
    if plan._local_fn is None:
        import jax.numpy as jnp

        from .stream import maybe_donate_jit

        params = plan.tables.ntt_params()
        if params is not None:
            from ..kernels.ntt_encode import ntt_encode

            fn = maybe_donate_jit(lambda x: ntt_encode(x, params),
                                  donate=plan.spec.K == plan.spec.R)
        else:
            from ..kernels.ops import encode_blocks

            A = jnp.asarray(plan.A, jnp.uint32)
            fn = maybe_donate_jit(lambda x: encode_blocks(x, A),
                                  donate=False)
        plan._local_fn = fn
    return plan._local_fn


def run_local(plan, x: np.ndarray) -> np.ndarray:
    """Single-device encode on the kernel path (no network): the cached
    jitted NTT fast path or dense field matmul, per the planner."""
    import jax.numpy as jnp

    x32 = jnp.asarray(np.asarray(x) % plan.field.q, jnp.uint32)
    with kernel_span(f"local_encode.{plan.local_impl}",
                     kind=plan.spec.kind, K=plan.spec.K,
                     w=int(x32.shape[1])):
        y = local_encode_callable(plan)(x32)
    return np.asarray(y, np.int64)


def _require_devices(n: int):
    import jax

    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh backend needs >= {n} devices, found {len(devs)} "
            "(hint: XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return devs[:n]


def _mesh_axes(plan, devs):
    """(Mesh, axis_name, PartitionSpec) for the plan: the flat K-device
    "enc" axis, or — when the plan carries a multi-host topology whose
    host count divides K — a (hosts x K/hosts) grid in host-major device
    order with a `TieredAxis` axis name, so every schedule round lowers
    onto its own tier's ppermute leg (see `core.shardmap_exec`).  Shard
    layout is identical either way (device k still holds source k), so
    outputs are bitwise-equal to the flat mesh."""
    from jax.sharding import Mesh, PartitionSpec as P

    from ..core.shardmap_exec import TieredAxis

    topo = getattr(plan, "topology", None)
    K = plan.spec.K
    if topo is not None and 1 < topo.hosts <= K and K % topo.hosts == 0:
        axis = TieredAxis(topo.hosts, K // topo.hosts)
        mesh = Mesh(np.array(devs).reshape(axis.hosts, axis.dph), axis.axes)
        return mesh, axis, P(axis.axes)
    return Mesh(np.array(devs), ("enc",)), "enc", P("enc")


def build_mesh_callable(plan):
    """Jitted global-array function (K, W) uint32 -> (K, W) uint32 running
    the plan's schedule under shard_map on the first K devices.  Device k
    holds source k; after the call devices 0..R-1 hold the sink values."""
    import jax
    import jax.numpy as jnp

    from ..core.parity import mesh_parity_encode
    from ..core.shardmap_exec import mesh_dft, shard_map

    spec = plan.spec
    devs = _require_devices(spec.K)
    mesh, axis, pspec = _mesh_axes(plan, devs)

    if spec.kind == "dft":
        t = plan.tables.dft_mesh_tables()

        @partial(shard_map, mesh=mesh,
                 in_specs=(pspec, pspec, pspec), out_specs=pspec)
        def step(xb, ca, cb):
            return mesh_dft(xb[0], ca[0], cb[0], t, axis)[None]

        args = (jnp.asarray(t.ca.T), jnp.asarray(t.cb.T))
        return jax.jit(lambda xg: step(xg, *args))

    if spec.K % spec.R != 0:
        raise NotImplementedError(
            f"mesh backend covers the R | K grid (Sec. III-A); got "
            f"K={spec.K}, R={spec.R}")

    if getattr(plan, "commute", False):
        # a tier_commute-rewritten schedule no longer matches the
        # hand-built table fast path: lower its IR generically (per-round
        # ppermute legs + combine layers, see core.shardmap_exec)
        from ..core.shardmap_exec import (build_ir_mesh_program,
                                          mesh_ir_encode)

        ir = plan.schedule_ir()
        dev_of = list(range(spec.K)) + list(range(spec.R))  # sink K+r -> r
        prog = build_ir_mesh_program(ir, dev_of)
        arrs = prog.device_arrays()
        keys = list(arrs)

        @partial(shard_map, mesh=mesh,
                 in_specs=(pspec,) + tuple(pspec for _ in keys),
                 out_specs=pspec)
        def ir_step(xb, *tb):
            rows = {k: v[0] for k, v in zip(keys, tb)}
            return mesh_ir_encode(xb[0], rows, prog, axis)[None]

        ir_args = tuple(jnp.asarray(arrs[k]) for k in keys)
        return jax.jit(lambda xg: ir_step(xg, *ir_args))

    t = plan.tables.mesh_tables(plan.method)
    arrs = t.device_arrays()
    keys = list(arrs)

    @partial(shard_map, mesh=mesh,
             in_specs=(pspec,) + tuple(pspec for _ in keys),
             out_specs=pspec)
    def step(xb, *tb):
        rows = {k: v[0] for k, v in zip(keys, tb)}
        return mesh_parity_encode(xb[0], rows, t, axis)[None]

    args = tuple(jnp.asarray(arrs[k]) for k in keys)
    return jax.jit(lambda xg: step(xg, *args))


def run_mesh(plan, x: np.ndarray) -> np.ndarray:
    import jax.numpy as jnp

    spec = plan.spec
    fn = plan.mesh_callable()
    xd = jnp.asarray(np.asarray(x) % plan.field.q, jnp.uint32)
    with kernel_span("mesh_encode", kind=spec.kind, K=spec.K,
                     w=int(xd.shape[1])):
        y = np.asarray(fn(xd), np.int64)
    return y if spec.kind == "dft" else y[: spec.R]


# ---------------------------------------------------------------------------
# the built-in Backend registrations (encode halves above, decode halves in
# recover.backends — imported lazily to keep the api <-> recover import DAG
# acyclic)
# ---------------------------------------------------------------------------


@register_backend("simulator")
class SimulatorBackend(Backend):
    """Exact lockstep oracle on the paper's p-port round network.  Runs any
    prime modulus; the only backend that measures network cost (exact C1/C2
    recorded thread-locally on `plan.last_stats`/`plan.sim_net`)."""

    measures_network = True

    def encode(self, plan, x):
        y, net = run_simulator(plan, x)
        plan._record_net(net, op="encode", width=x.shape[1])
        return y

    def decode(self, plan, v):
        from ..recover.backends import run_simulator as run_dec

        y, net = run_dec(plan, v)
        plan._record_net(net, op="decode", width=v.shape[1])
        return y


@register_backend("local")
class LocalBackend(Backend):
    """Single-device kernel path (NTT fast path / dense Pallas/jnp field
    matmul).  No communication schedule; uint32 Fermat arithmetic only."""

    supports_stream = True
    field_note = f"the uint32 kernels are Fermat-only, q={FERMAT_Q}"

    def supports_field(self, q: int) -> bool:
        return q == FERMAT_Q

    def encode(self, plan, x):
        return run_local(plan, x)

    def decode(self, plan, v):
        from ..recover.backends import run_local as run_dec

        return run_dec(plan, v)


@register_backend("mesh")
class MeshBackend(Backend):
    """Devices-as-processors shard_map/ppermute execution: one jax device
    per source/survivor.  Fermat-only; encode additionally needs the
    R | K framework grid (Sec. III-A) for non-dft kinds."""

    supports_stream = True
    field_note = f"the uint32 kernels are Fermat-only, q={FERMAT_Q}"

    def supports_field(self, q: int) -> bool:
        return q == FERMAT_Q

    def device_requirement(self, spec) -> int:
        return spec.K

    def validate(self, spec, op: str = "encode") -> None:
        # structural mismatch first: it holds on any device count
        if op == "encode" and spec.kind != "dft" and spec.K % spec.R != 0:
            raise BackendCapabilityError(
                f"mesh encode covers the R | K framework grid (Sec. III-A); "
                f"got K={spec.K}, R={spec.R} — use backend='simulator' or "
                "'local' for this spec")
        super().validate(spec, op)

    def encode(self, plan, x):
        return run_mesh(plan, x)

    def decode(self, plan, v):
        from ..recover.backends import run_mesh as run_dec

        return run_dec(plan, v)
