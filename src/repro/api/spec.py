"""CodeSpec: the *what* of a decentralized encode, decoupled from the *how*.

A spec pins down the code family, system shape and communication model:

    kind : "universal"  — any generator block A (K x R); A is either derived
                          deterministically from `seed` or passed explicitly
                          to `Encoder.plan(..., A=...)`
           "rs"         — systematic Reed-Solomon [I | A] from a
                          StructuredGRS construction (Sec. VI)
           "lagrange"   — the u = v = 1 GRS case (Remark 9); with an explicit
                          A, arbitrary interpolation points are allowed
           "dft"        — the K x K permuted-DFT transform (Sec. V-A); R == K
    K, R : sources / sinks (paper's N = K + R)
    p    : ports per processor per round
    W    : payload width in field elements (cost modeling only — `.run`
           accepts any width; host tables never depend on W)
    q    : field modulus (Fermat prime 65537 by default — the only modulus
           the jnp/Pallas uint32 backends support)
    P    : radix of the structured-points / DFT factorizations

Specs are frozen and hashable: they are the cache key for host-side tables
and plans (see `repro.api.planner`).
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np

from ..core.field import FERMAT, FERMAT_Q, Field

KINDS = ("universal", "rs", "lagrange", "dft")


@dataclass(frozen=True)
class CodeSpec:
    kind: str
    K: int
    R: int
    p: int = 1
    W: int = 1
    q: int = FERMAT_Q
    P: int = 2
    seed: int | None = None  # kind="universal": deterministic random A

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown kind {self.kind!r}; expected one of {KINDS}")
        if self.K < 1 or self.R < 1:
            raise ValueError("K and R must be >= 1")
        if self.p < 1:
            raise ValueError("p >= 1 ports required")
        if self.W < 1:
            raise ValueError("W >= 1 required")
        if self.kind == "dft":
            if self.R != self.K:
                raise ValueError("dft is a K x K transform: set R == K")
            Z = 1
            while Z < self.K:
                Z *= self.P
            if Z != self.K:
                raise ValueError(f"dft needs K a power of P={self.P}")
            if (self.q - 1) % self.K != 0:
                raise ValueError("dft needs K | q-1")

    @property
    def field(self) -> Field:
        return FERMAT if self.q == FERMAT_Q else Field(self.q)

    @property
    def N(self) -> int:
        """Total processors in the paper's system model."""
        return self.K + self.R

    def table_key(self) -> tuple:
        """Cache key for host-side tables: everything except the payload
        width W (tables and schedules are W-independent, Remark 2)."""
        return (self.kind, self.K, self.R, self.p, self.q, self.P, self.seed)

    def with_W(self, W: int) -> "CodeSpec":
        return replace(self, W=W)

    def structured(self) -> bool:
        """Whether the spec's matrix comes from a structured construction
        (enabling the RS/Lagrange-specific all-to-all schedules)."""
        return self.kind in ("rs", "lagrange")

    def default_matrix(self, field: Field | None = None) -> np.ndarray:
        """The (K, R) generator block implied by the spec alone (no explicit
        A): structured GRS / Lagrange A, permuted-DFT matrix, or the
        seed-derived uniform random block for kind="universal"."""
        field = field or self.field
        if self.kind == "dft":
            from ..core.matrices import permuted_dft_matrix

            return permuted_dft_matrix(field, self.K, self.P)
        if self.structured():
            from ..core.cauchy import StructuredGRS

            sgrs = StructuredGRS.build(field, self.K, self.R, P=self.P,
                                       lagrange=self.kind == "lagrange")
            return sgrs.grs.A_direct()
        if self.seed is None:
            raise ValueError(
                "kind='universal' needs either spec.seed or an explicit A "
                "passed to Encoder.plan(..., A=...)")
        return field.rand((self.K, self.R), np.random.default_rng(self.seed))
