"""Backend protocol + registry: the single dispatch surface behind BOTH
planners (`Encoder`/`EncodePlan` and `recover.Decoder`/`DecodePlan`).

A *backend* is an executor for planned encodes/decodes.  The three
built-ins (registered in `api.backends`) are interchangeable and
bitwise-identical:

    simulator — the paper's p-port round network (exact numpy oracle;
                measured C1/C2 on `plan.last_stats` / `plan.sim_net`)
    mesh      — devices-as-processors shard_map/ppermute execution
    local     — single-device Pallas/jnp kernels (NTT fast path / dense
                field matmul; no communication schedule)

Third-party / experimental executors plug in without touching core:

    from repro.api import Backend, register_backend

    @register_backend("mybackend")
    class MyBackend(Backend):
        def encode(self, plan, x):      # (K, w) -> (R, w) int64 mod q
            ...
        def decode(self, plan, v):      # (K, w) -> (|E|, w) int64 mod q
            ...

    plan = Encoder.plan(spec, backend="mybackend")

Capabilities are *declared* up front — `supports_stream`,
`measures_network`, `supports_field(q)`, `device_requirement(spec)` — and
checked once at plan time (`Backend.validate`), so an unsupported
(spec, backend) pair fails with a `BackendCapabilityError` naming the
mismatch instead of a deep kernel assert mid-run.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING

from ..core.cost_model import LinearCost
from ..obs import drift as _drift
from ..obs.metrics import REGISTRY as _METRICS

if TYPE_CHECKING:
    from .spec import CodeSpec

# the registry families every network-measuring run publishes into
# (module-level handles: zero name lookup on the hot path)
_RUNS = _METRICS.counter("coded_runs_total",
                         "plan executions on network-measuring backends")
_ROUNDS = _METRICS.counter("sim_rounds_total",
                           "simulator rounds executed (sum of C1)")
_C2_ELEMS = _METRICS.counter("sim_c2_elems_total",
                             "simulator max-message traffic (sum of C2)")


class BackendCapabilityError(ValueError):
    """The (spec, backend) pair is unsupported: raised at plan time by
    `Backend.validate` with the capability that failed (field modulus,
    device count, grid shape), never from inside a kernel."""


@dataclass(frozen=True)
class RunStats:
    """Measured network cost of ONE plan execution (simulator backend):
    exact C1 (rounds) and C2 (field elements per port) of that run."""

    C1: int
    C2: int
    backend: str = "simulator"
    op: str = "encode"

    def total(self, alpha: float, beta_bits: float) -> float:
        """Evaluate the linear link-cost model on the measured counts —
        same contract (and implementation) as `LinearCost.total`."""
        return LinearCost(self.C1, self.C2).total(alpha, beta_bits)


class Backend:
    """Protocol for a plan executor.  Subclass, implement `encode` /
    `decode`, and register under a name (see module docstring).

    Declared capabilities (override as needed):

      supports_stream   — the backend provides a device pipeline for
                          `plan.run_stream` (built-ins: local/mesh).
                          Backends without it still stream correctly via
                          per-chunk `encode`/`decode` calls.
      measures_network  — runs yield exact (C1, C2) network stats,
                          recorded thread-locally on `plan.last_stats`.
      supports_field(q) — which moduli the executor handles (the uint32
                          jnp/Pallas kernels are Fermat-only).
      device_requirement(spec) — minimum jax device count to execute
                          plans of `spec` (mesh: one device per source).
    """

    name: str = "?"
    supports_stream: bool = False
    measures_network: bool = False
    # optional one-line reason shown in the unsupported-field error
    # (set by backends whose supports_field is restrictive)
    field_note: str | None = None

    def supports_field(self, q: int) -> bool:
        return True

    def device_requirement(self, spec: "CodeSpec") -> int:
        return 0

    def validate(self, spec: "CodeSpec", op: str = "encode") -> None:
        """Plan-time capability gate; raises `BackendCapabilityError`."""
        if not self.supports_field(spec.q):
            note = f" ({self.field_note})" if self.field_note else ""
            raise BackendCapabilityError(
                f"backend {self.name!r} does not support q={spec.q} for "
                f"{op} of kind={spec.kind!r}{note}; backend='simulator' "
                "runs any prime modulus")
        need = self.device_requirement(spec)
        if need:
            import jax

            have = len(jax.devices())
            if have < need:
                raise BackendCapabilityError(
                    f"backend {self.name!r} needs >= {need} devices for "
                    f"K={spec.K}, found {have} (hint: "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=N)")

    # -- execution ----------------------------------------------------------
    def encode(self, plan, x):
        """Execute an `EncodePlan`: (K, w) payload -> (R, w) sink values,
        int64 mod q, bitwise-equal to x^T A."""
        raise BackendCapabilityError(
            f"backend {self.name!r} does not implement encode")

    def decode(self, plan, v):
        """Execute a `DecodePlan`: (K, w) survivor symbols (ordered like
        `plan.kept`) -> (|E|, w) repaired symbols, int64 mod q."""
        raise BackendCapabilityError(
            f"backend {self.name!r} does not implement decode")


_REGISTRY: dict[str, Backend] = {}


def register_backend(name: str, backend: Backend | type | None = None, *,
                     overwrite: bool = False):
    """Register an executor under `name` (usable as a class decorator).

    `backend` may be a `Backend` subclass (instantiated here) or an
    instance.  Re-registering a taken name raises unless `overwrite=True`
    (third-party code must not silently shadow the built-ins).
    """

    def _register(obj):
        be = obj() if isinstance(obj, type) else obj
        if name in _REGISTRY and not overwrite:
            raise ValueError(
                f"backend {name!r} is already registered "
                "(pass overwrite=True to replace it)")
        be.name = name
        _REGISTRY[name] = be
        return obj

    return _register if backend is None else _register(backend)


def unregister_backend(name: str) -> None:
    """Remove a registered backend (no-op if absent).  Plans already
    created for it keep their `backend` name and will fail on next run."""
    _REGISTRY.pop(name, None)


def available_backends() -> tuple[str, ...]:
    """Names of all registered backends (built-ins first)."""
    return tuple(_REGISTRY)


def get_backend(name: str) -> Backend:
    """The registered executor, or ValueError naming the known ones."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; registered backends: "
            f"{tuple(_REGISTRY)}") from None


class PlanStats:
    """Thread-local run statistics, mixed into both plan classes.

    Plans are cached and shared across callers *and threads*; writing
    measured stats onto the plan object directly would let concurrent
    `run()` calls clobber each other (the old `plan.sim_net` race).
    Instead every run records into a `threading.local`, so each thread
    reads the stats of ITS OWN last run on this plan:

        last_stats   — `RunStats` of the last run on this thread
                       (simulator backend; None otherwise)
        sim_net      — the full `RoundNetwork` of that run (round-by-round
                       inspection; None on kernel backends)
        stream_stats — `StreamStats` of the last `run_stream` consumed on
                       this thread

    THREAD-LOCAL CONTRACT: these properties answer only for the calling
    thread.  A thread that has not run this plan reads `None` — never
    another thread's stats, no matter how recently that other thread ran
    (so a queue worker's measurements are invisible to the submitting
    thread; use the obs registry / drift ledger for cross-thread
    aggregates).  This is a guarantee, not a limitation: it is what makes
    `plan.last_stats` race-free on shared cached plans, and it is pinned
    by a regression test (`test_obs.py::test_plan_stats_cross_thread`).

    Every `_record_net` additionally publishes into the process-wide
    `obs.metrics.REGISTRY` (run/round/traffic counters) and — when the
    caller passes the run's payload `width` — checks the measured (C1, C2)
    against the closed-form cost model via `obs.drift.LEDGER`.
    """

    @property
    def last_stats(self) -> RunStats | None:
        return getattr(self._tls, "stats", None)

    @property
    def sim_net(self):
        return getattr(self._tls, "net", None)

    @property
    def stream_stats(self):
        return getattr(self._tls, "stream_stats", None)

    @stream_stats.setter
    def stream_stats(self, value) -> None:
        self._tls.stream_stats = value

    def _record_net(self, net, op: str, width: int | None = None) -> None:
        self._tls.net = net
        self._tls.stats = RunStats(net.C1, net.C2, backend=self.backend,
                                   op=op)
        kind = self.spec.kind
        _RUNS.inc(1, backend=self.backend, op=op, kind=kind)
        _ROUNDS.inc(net.C1, backend=self.backend, op=op, kind=kind)
        _C2_ELEMS.inc(net.C2, backend=self.backend, op=op, kind=kind)
        if width is not None:
            _drift.record_run(self, net, op, width)
