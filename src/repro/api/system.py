"""CodedSystem: one session handle over the encode AND decode stacks.

The paper treats encoding and repair as two faces of one decentralized
system — decode is scheduled *as* an all-to-all encode among survivors —
and applications continually move between healthy encodes and degraded
reads.  `CodedSystem` owns both planners, the shared host-table cache and
the live erasure state, so "open a coded system, survive failures, serve
traffic" is three lines:

    from repro.api import CodeSpec, CodedSystem

    system = CodedSystem(CodeSpec(kind="rs", K=16, R=4), backend="local")
    cw = system.codeword(x)        # [x | parity] systematic codeword (N, W)
    system.fail([2, 17])           # processors 2 and 17 go dark
    x2 = system.read(cw)           # degraded read — auto-replanned decode
    cw = system.rebuild(cw)        # re-materialize lost symbols + heal()

Underneath, `Encoder.plan` / `Decoder.plan` remain the public planner
layer this composes: `system.encode_plan` and `system.decode_plan` expose
the live plans, decode plans are re-planned automatically whenever the
erasure pattern changes (and cached per pattern via the Decoder's LRU),
and every execution runs on the registered `Backend` the session was
opened with.  `system.submit(...)` returns futures through a lazily
started `CodingQueue` that coalesces concurrent requests into batched
streamed executions.

Payload conventions (mirroring the planners):

  * `encode(x)` takes the (K, W) data block, returns (R, W) parity.
  * `decode(v)` / `read(v)` accept EITHER the full (N, W) codeword
    row-stack (rows at failed positions are ignored) OR the (K, W)
    survivor symbols ordered like `system.kept` — the leading dimension
    disambiguates (N = K + R > K always).
  * 1-D inputs are treated as W = 1 and squeezed on return.

Thread safety: erasure-state transitions (`fail`/`heal`) and queue
lifecycle are lock-protected; per-run measured stats are thread-local on
the plans (`plan.last_stats`), surfaced through `system.stats()`.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Any, Iterable, Iterator

import numpy as np

from .planner import ALPHA_DEFAULT, BETA_BITS_DEFAULT, EncodePlan, Encoder
from .registry import get_backend
from .spec import CodeSpec


@dataclass(frozen=True)
class LinkModel:
    """The paper's linear link-cost model C = alpha*C1 + beta_bits*C2.

    alpha     — per-round latency in seconds (Table I's alpha)
    beta_bits — seconds per field element per port, i.e. beta * ceil(log2 q)

    Used by the system for cost reporting (`stats()`/`describe()`); the
    defaults are the constants the demos and benchmarks report with.
    """

    alpha: float = ALPHA_DEFAULT
    beta_bits: float = BETA_BITS_DEFAULT

    def __post_init__(self):
        if self.alpha < 0 or self.beta_bits < 0:
            raise ValueError(
                f"LinkModel needs alpha >= 0 and beta_bits >= 0, got "
                f"alpha={self.alpha!r}, beta_bits={self.beta_bits!r}")

    def us(self, cost: Any) -> float:
        """Model microseconds of an analytic `LinearCost` or a measured
        `RunStats` (anything with `.total(alpha, beta_bits)` — a
        `topo.TieredCost` collapses to its flat sum here)."""
        return cost.total(self.alpha, self.beta_bits) * 1e6


class CodedSystem:
    """Session handle: spec + backend + live erasure state (see module
    docstring for the three-line scenario).

    Parameters
    ----------
    spec    : the `CodeSpec` (what code, what system shape)
    backend : registered backend name; capability-checked at construction
              (unsupported pairs raise `BackendCapabilityError` here, not
              mid-run)
    method  : encode schedule ("auto" = Table-I cost-model argmin; under a
              topology + `TieredLinkModel` the argmin prices each method's
              per-tier split)
    A       : explicit generator block (kind="universal"/"lagrange")
    link    : `LinkModel` (or `repro.topo.TieredLinkModel`) for cost
              reporting and auto selection
    topology: a `repro.topo.Topology` or explicit `Placement` — the
              simulator then measures exact per-tier C1/C2 (surfaced in
              `stats()["encode"]["tiers"]` and the drift ledger), and the
              mesh backend runs the (hosts x K/hosts) hierarchical grid
              when hosts divides K.  A bare topology must have
              >= the spec's processor count slots on the simulator
              backend (the mesh grid only needs the host count).
    placement: the policy a bare `topology` is placed with — "affinity"
              (pack each A2A group onto one host; default) or "flat"
              (topology-oblivious round-robin)
    commute : apply the `RoundIR.tier_commute` schedule rewrite under the
              resolved placement (required): commuting reduce rounds are
              re-synthesized host-aware so inter-host rounds strictly
              shrink (or the schedule stays canonical).  See
              `Encoder.plan(commute=...)`.
    chunk_w : default streaming chunk width for `*_stream`/queue paths
    queue   : an externally-owned `CodingQueue` to route `submit` futures
              through instead of a lazily-opened private one.  This is the
              pool-safe lifecycle `launch.service.CodedService` uses: many
              pooled sessions share ONE queue, so same-plan requests from
              different sessions coalesce into one batched execution, and
              `close()` never closes a queue the session does not own.
              Must be on the same backend as the session.
    trace   : observability tracer — True (collect, read
              `system.tracer`), an `obs.trace.Tracer`, or a path (trace
              JSON written there on `close()`).  Installed process-wide
              for the session's lifetime, so simulator rounds, stream
              pipeline stages, and kernel launches under this session
              all land on one timeline.
    """

    def __init__(self, spec: CodeSpec, backend: str = "simulator", *,
                 method: str = "auto", A: np.ndarray | None = None,
                 link: Any = None, chunk_w: int | None = None,
                 topology: Any = None, placement: str = "affinity",
                 commute: bool = False, queue: Any = None, trace=None):
        self.spec = spec
        self.backend = backend
        self.link = link or LinkModel()
        self.chunk_w = chunk_w
        self._A = A
        self.topology = None
        self._placement = None
        if topology is not None:
            from ..topo import Placement, Topology, n_procs, place

            if isinstance(topology, Placement):
                self._placement, self.topology = topology, topology.topology
            elif isinstance(topology, Topology):
                self.topology = topology
                if topology.n_slots >= n_procs(spec):
                    self._placement = place(spec, topology, placement)
                # else: Encoder.plan rejects it for network-measuring
                # backends; mesh/local only need the host count
            else:
                raise TypeError(
                    f"topology must be a Topology or Placement, "
                    f"got {type(topology).__name__}")
        from ..obs import trace as _trace_mod

        self.tracer, self._trace_path = _trace_mod.resolve(trace)
        if self.tracer is not None:
            _trace_mod.install(self.tracer)
        if queue is not None and queue.backend != backend:
            raise ValueError(
                f"shared queue runs backend {queue.backend!r} but the "
                f"session was opened on {backend!r} — a queued submission "
                "would silently execute on the wrong backend")
        self._shared_queue = queue
        # eager plan: all capability checks + host-table builds happen now
        if commute and self._placement is None:
            raise ValueError(
                "commute=True needs a placed topology (pass a Topology "
                "with enough slots, or an explicit Placement) — the "
                "tier_commute rewrite is placement-aware")
        self._enc: EncodePlan = Encoder.plan(
            spec, backend=backend, method=method, A=A,
            topology=self._placement if self._placement is not None
            else self.topology,
            link=self.link if topology is not None else None,
            commute=commute)
        self._failed: set[int] = set()
        self._dplan: Any = None          # decode plan for current pattern
        self._queue: Any = None
        self._lock = threading.RLock()

    # -- plans --------------------------------------------------------------
    @property
    def encode_plan(self) -> EncodePlan:
        """The live `EncodePlan` (the still-public planner layer)."""
        return self._enc

    @property
    def placement(self):
        """The resolved `repro.topo.Placement` (None without a topology or
        when the topology has fewer slots than processors)."""
        return self._placement

    @property
    def decode_plan(self):
        """The `DecodePlan` for the CURRENT erasure pattern — re-planned
        on pattern change, cached per pattern (Decoder LRU + this handle).
        Raises `UndecodableError` for information-losing patterns
        (possible only for the non-MDS dft codeword)."""
        with self._lock:
            pattern = tuple(sorted(self._failed))
            if self._dplan is None or self._dplan.erased != pattern:
                from ..recover import Decoder

                self._dplan = Decoder.plan(self.spec, erased=pattern,
                                           backend=self.backend, A=self._A)
            return self._dplan

    # -- erasure state ------------------------------------------------------
    @property
    def failed(self) -> tuple[int, ...]:
        """Sorted codeword positions currently failed (data k < K, parity
        K + r)."""
        with self._lock:
            return tuple(sorted(self._failed))

    @property
    def kept(self) -> tuple[int, ...]:
        """The K survivor positions reads consume, in input-row order
        (simply 0..K-1 while the system is healthy)."""
        if not self.failed:
            return tuple(range(self.spec.K))
        return self.decode_plan.kept

    def fail(self, procs) -> "CodedSystem":
        """Mark processors failed (int or iterable of codeword positions).
        Cumulative; at most R total — beyond that no code can help, so the
        transition is refused rather than discovered at read time."""
        if isinstance(procs, (int, np.integer)):
            procs = (procs,)
        procs = {int(e) for e in procs}
        bad = [e for e in procs if not 0 <= e < self.spec.N]
        if bad:
            raise ValueError(
                f"positions {bad} outside the codeword [0, {self.spec.N})")
        with self._lock:
            new = self._failed | procs
            if len(new) > self.spec.R:
                raise ValueError(
                    f"{len(new)} failures exceed the code's R="
                    f"{self.spec.R} (currently failed: "
                    f"{sorted(self._failed)})")
            self._failed = new
        return self

    def heal(self, procs=None) -> "CodedSystem":
        """Mark processors recovered (default: all of them).  Positions
        are validated like `fail`'s — a typo'd heal must not silently
        leave the system degraded."""
        with self._lock:
            if procs is None:
                self._failed.clear()
                return self
            if isinstance(procs, (int, np.integer)):
                procs = (procs,)
            procs = {int(e) for e in procs}
            bad = [e for e in procs if not 0 <= e < self.spec.N]
            if bad:
                raise ValueError(
                    f"positions {bad} outside the codeword "
                    f"[0, {self.spec.N})")
            self._failed -= procs
        return self

    # -- encode -------------------------------------------------------------
    def encode(self, x) -> np.ndarray:
        """Encode data x (K,)/(K, W) -> parity (R,)/(R, W)."""
        return self._enc.run(x)

    def codeword(self, x) -> np.ndarray:
        """The full systematic codeword [x | parity]: (K, W) -> (N, W)."""
        x = np.asarray(x)
        parity = self._enc.run(x)
        data = (x % self.spec.q).astype(np.int64)
        return np.concatenate([data, parity], axis=0)

    def encode_stream(self, payload, *, chunk_w: int | None = None
                      ) -> Iterator[np.ndarray]:
        """Streamed encode: generator of (R, w) parity blocks (see
        `EncodePlan.run_stream`)."""
        return self._enc.run_stream(payload, chunk_w=chunk_w or self.chunk_w)

    def encode_batched(self, xs, *, chunk_w: int | None = None
                       ) -> list[np.ndarray]:
        """Encode a batch of payloads in one coalesced streamed run."""
        return self._enc.run_batched(xs, chunk_w=chunk_w or self.chunk_w)

    # -- decode / degraded read ---------------------------------------------
    def _survivor_view(self, v, plan) -> np.ndarray:
        """Normalize (N, ...) codeword rows or (K, ...) kept-ordered
        survivor symbols to the (K, ...) form `plan` consumes.  The plan
        is passed in (not re-resolved from the live erasure state) so one
        operation slices and executes against ONE pattern even if a
        concurrent `fail`/`heal` lands mid-flight."""
        v = np.asarray(v)
        if v.shape[0] == self.spec.N:
            return v[list(plan.kept)]
        if v.shape[0] == self.spec.K:
            return v
        raise ValueError(
            f"expected the full (N={self.spec.N}, ...) codeword or the "
            f"(K={self.spec.K}, ...) survivor symbols of system.kept, got "
            f"leading dim {v.shape[0]}")

    def decode(self, v) -> np.ndarray:
        """Recompute the symbols at the failed positions from survivors:
        returns (|failed|,)/(|failed|, W) rows ordered like
        `system.failed` (empty while healthy)."""
        plan = self.decode_plan  # pinned: one pattern for slice + run
        return plan.run(self._survivor_view(v, plan))

    def read(self, v) -> np.ndarray:
        """Degraded read: the full original data (K,)/(K, W) from the
        survivors.  Healthy systems read the data rows directly; with
        failures this runs the cached decode plan's data path."""
        v = np.asarray(v)
        if not self.failed:
            if v.shape[0] not in (self.spec.N, self.spec.K):
                raise ValueError(
                    f"expected (N={self.spec.N}, ...) or (K={self.spec.K},"
                    f" ...) rows, got leading dim {v.shape[0]}")
            return (v[: self.spec.K] % self.spec.q).astype(np.int64)
        plan = self.decode_plan  # pinned: one pattern for slice + data
        return plan.data(self._survivor_view(v, plan))

    def decode_stream(self, payload, *, chunk_w: int | None = None
                      ) -> Iterator[np.ndarray]:
        """Streamed repair: generator of (|failed|, w) blocks.  `payload`
        is a (N, W)/(K, W) array or an iterable of such chunks (each
        sliced to survivors as needed).  The erasure pattern is pinned
        when the stream is created; later `fail`/`heal` calls do not
        affect chunks already in flight."""
        plan = self.decode_plan
        pieces: Iterable = ((payload,) if hasattr(payload, "shape")
                            else payload)

        def _sliced():
            for piece in pieces:
                yield self._survivor_view(piece, plan)

        return plan.run_stream(_sliced(), chunk_w=chunk_w or self.chunk_w)

    # -- rebuild: re-materialize the full codeword, then heal ---------------
    def _complement_plan(self, plan):
        """Decode plan for every position OUTSIDE `plan.kept` — the failed
        positions plus the unkept survivors (exactly N - K = R targets).
        A (K, W) kept-ordered payload has no rows for any of them, so a
        rebuild from survivors-only input recomputes them all.  Always
        decodable when `plan` itself was: the kept set is a basis."""
        comp = tuple(i for i in range(self.spec.N)
                     if i not in set(plan.kept))
        from ..recover import Decoder

        return Decoder.plan(self.spec, erased=comp, backend=self.backend,
                            A=self._A)

    def rebuild(self, v) -> np.ndarray:
        """Recompute ALL currently-failed symbols from the survivors,
        `heal()` the session, and return the fully healed (N,)/(N, W)
        codeword — the decentralized re-materialization step that restores
        full redundancy after failures (decode-as-encode among survivors;
        bitwise-identical across backends).

        `v` is the full (N, ...) codeword (rows at failed positions
        ignored) or the (K, ...) survivor symbols ordered like
        `system.kept` — with K rows the unkept survivor rows are
        recomputed too (complement-pattern decode).  Only the pattern
        pinned at entry is healed: a concurrent `fail` landing mid-rebuild
        stays failed."""
        plan = self.decode_plan  # pin ONE pattern for slice + run + heal
        v = np.asarray(v)
        squeeze = v.ndim == 1
        healed = self._rebuild_block(v[:, None] if squeeze else v, plan)
        self.heal(plan.erased)
        return healed[:, 0] if squeeze else healed

    def _rebuild_block(self, v: np.ndarray, plan) -> np.ndarray:
        """One (N, w) healed block from an (N, w)/(K, w) survivor block
        (the non-streamed body of `rebuild`; pattern pinned by `plan`)."""
        N, K, q = self.spec.N, self.spec.K, self.spec.q
        if v.shape[0] == N:
            healed = (v % q).astype(np.int64)
            if plan.erased:
                healed[list(plan.erased)] = plan.run(v[list(plan.kept)])
            return healed
        if v.shape[0] == K:
            comp = self._complement_plan(plan)
            healed = np.empty((N, v.shape[1]), np.int64)
            healed[list(comp.kept)] = (v % q).astype(np.int64)
            healed[list(comp.erased)] = comp.run(v)
            return healed
        raise ValueError(
            f"expected the full (N={N}, ...) codeword or the (K={K}, ...) "
            f"survivor symbols of system.kept, got leading dim {v.shape[0]}")

    def rebuild_stream(self, payload, *, chunk_w: int | None = None
                       ) -> Iterator[np.ndarray]:
        """Streamed rebuild: generator of fully-healed (N, w) codeword
        chunks.  `payload` is a (N, W)/(K, W) array or an iterable of such
        chunks; the repaired rows run through the plan's streaming engine
        (double-buffered on kernel backends) while the survivor rows ride
        along as passthrough.  The erasure pattern is pinned at creation;
        the session is healed (of that pattern) once the stream is
        exhausted — `CodedCheckpointer.scrub` drives this off survivor
        memmaps to rebuild shards in place."""
        from . import stream as stream_mod

        plan = self.decode_plan  # pin ONE pattern for the whole stream
        cw = (chunk_w or self.chunk_w
              or stream_mod.default_chunk_w(self.spec.K))
        N, K, q = self.spec.N, self.spec.K, self.spec.q

        def _gen():
            import itertools

            split = stream_mod.split_chunks(payload, cw)
            first = next(split, None)
            if first is None:
                self.heal(plan.erased)
                return
            rows = first.shape[0]
            chunks = itertools.chain((first,), split)
            if rows == N:
                dplan = plan
                kept_idx = list(plan.kept)
                fill = list(plan.erased)

                def slice_fn(c):
                    return c[kept_idx]

                def assemble(c, y):
                    healed = (c % q).astype(np.int64)
                    if fill:
                        healed[fill] = y
                    return healed
            elif rows == K:
                dplan = self._complement_plan(plan)
                kept_idx, comp_idx = list(dplan.kept), list(dplan.erased)

                def slice_fn(c):
                    return c

                def assemble(c, y):
                    healed = np.empty((N, c.shape[1]), np.int64)
                    healed[kept_idx] = (c % q).astype(np.int64)
                    healed[comp_idx] = y
                    return healed
            else:
                raise ValueError(
                    f"rebuild_stream chunks must carry N={N} codeword rows "
                    f"or the K={K} kept survivor rows, got {rows}")
            for c, y in stream_mod.run_paired_stream(dplan, chunks, slice_fn,
                                                     chunk_w=cw):
                yield assemble(c, y)
            self.heal(plan.erased)

        return _gen()

    # -- batched submission (coding queue) ----------------------------------
    def _ensure_queue(self):
        if self._shared_queue is not None:
            return self._shared_queue
        with self._lock:
            if self._queue is None:
                from ..launch.coding_queue import CodingQueue

                self._queue = CodingQueue(backend=self.backend,
                                          chunk_w=self.chunk_w)
            return self._queue

    def submit(self, op: str, payload, *, meta=None):
        """Submit an "encode", "decode", or "rebuild" request; returns a
        `concurrent.futures.Future`.  Requests are coalesced with other
        in-flight submissions sharing the same plan into single batched
        streamed executions (`launch.coding_queue.CodingQueue`).

        Decode/rebuild submissions pin the erasure pattern at submit time,
        with *failover*: if a later `fail()` invalidates the pinned
        pattern before the request is executed (the new pattern is a
        strict superset), the queue transparently replans against the
        superset — survivors that died after submission are never
        consumed, instead of silently serving their stale symbols.  A
        decode future still resolves to the rows of the pattern it was
        submitted for; a rebuild future resolves to the fully healed
        (N, W) codeword (the session is NOT auto-healed — call `heal()` /
        `rebuild()` once the result is re-materialized).  Failover needs
        the full (N, ...) payload to re-slice; rebuild requires it
        outright, and a (K, ...) decode payload whose pattern is
        invalidated fails its future rather than decode stale rows."""
        if op == "encode":
            return self._ensure_queue().submit_encode(self.spec, payload,
                                                      A=self._A, meta=meta)
        if op in ("decode", "rebuild"):
            plan = self.decode_plan  # pin ONE pattern for slice + queue
            v = np.asarray(payload)
            if v.shape[0] != self.spec.N and (op == "rebuild"
                                              or v.shape[0] != self.spec.K):
                raise ValueError(
                    f"{op} payload must carry the full N={self.spec.N} "
                    "codeword rows"
                    + ("" if op == "rebuild"
                       else f" (or the K={self.spec.K} kept survivor rows)")
                    + f", got leading dim {v.shape[0]}")
            queue = self._ensure_queue()
            submit = (queue.submit_decode if op == "decode"
                      else queue.submit_rebuild)
            return submit(self.spec, plan.erased, v, A=self._A,
                          pattern_ref=self._live_pattern, meta=meta)
        raise ValueError(
            f"op must be 'encode', 'decode' or 'rebuild', got {op!r}")

    def _live_pattern(self) -> tuple[int, ...]:
        """The CURRENT erasure pattern — handed to queued decode/rebuild
        requests so the worker can detect a pinned pattern invalidated by
        a later `fail()` and replan against the superset."""
        return self.failed

    def submit_encode(self, x):
        return self.submit("encode", x)

    def submit_decode(self, v):
        return self.submit("decode", v)

    def submit_rebuild(self, v):
        return self.submit("rebuild", v)

    # -- lifecycle / introspection ------------------------------------------
    def close(self) -> None:
        """Drain and stop the session's OWN coding queue (no-op if never
        started).  A shared queue handed in at construction is left
        running — it belongs to the pool (`CodedService`) that created it,
        and other sessions are still submitting through it.  The session
        stays usable — a later `submit` lazily opens a fresh queue; direct
        `encode`/`read`/... never involve the queue."""
        with self._lock:
            queue, self._queue = self._queue, None
        if queue is not None:
            queue.close()
        if self.tracer is not None:
            from ..obs import trace as _trace_mod

            _trace_mod.uninstall(self.tracer)
            if self._trace_path is not None:
                self.tracer.save(self._trace_path)
                self._trace_path = None  # idempotent close()

    def __enter__(self) -> "CodedSystem":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def stats(self) -> dict:
        """One coherent snapshot: erasure state, per-plan model costs and
        this thread's last measured run stats, queue coalescing counters,
        and the shared cache statistics."""
        enc = self._enc
        out: dict = {
            "spec": self.spec,
            "backend": self.backend,
            "failed": self.failed,
            "encode": {
                "method": enc.method,
                "cost": enc.cost(),
                "model_us": self.link.us(enc.cost()),
                "last": enc.last_stats,
            },
        }
        tc = enc.tiered_cost()
        if tc is not None or self._placement is not None:
            tiers: dict = {"placement": self._placement.policy
                           if self._placement else None}
            if tc is not None:
                tiers["model"] = {"intra": tc.intra, "inter": tc.inter}
                tiers["model_us"] = self.link.us(tc)
            net = enc.sim_net
            if net is not None and getattr(net, "placement", None) is not None:
                tiers["measured"] = net.by_tier()
            out["encode"]["tiers"] = tiers
        if self.failed:
            from ..recover import UndecodableError

            try:
                plan = self.decode_plan
            except UndecodableError as exc:
                # introspection must not crash on an information-losing
                # pattern (possible for the non-MDS dft codeword) — report
                # the degraded-but-undecodable state instead
                out["decode"] = {"decodable": False, "erased": self.failed,
                                 "error": str(exc)}
            else:
                out["decode"] = {
                    "decodable": True,
                    "erased": plan.erased,
                    "kept": plan.kept,
                    "cost": plan.cost(),
                    "model_us": self.link.us(plan.cost()),
                    "last": plan.last_stats,
                }
        with self._lock:
            q = self._shared_queue or self._queue
            if q is not None:
                # snapshot, not the live object: the worker thread keeps
                # mutating QueueStats after this call returns (a shared
                # queue's stats are pool-wide, not session-scoped)
                from ..launch.coding_queue import QueueStats

                live = q.stats
                out["queue"] = QueueStats(live.requests, live.batches,
                                          list(live.coalesced),
                                          live.failovers)
        from . import cache_info

        out["cache"] = cache_info()
        from ..obs.drift import LEDGER
        from ..obs.metrics import REGISTRY

        out["metrics"] = REGISTRY.snapshot()
        if get_backend(self.backend).measures_network:
            out["drift"] = LEDGER.snapshot()
        return out

    def describe(self) -> str:
        s = self.spec
        be = get_backend(self.backend)
        lines = [
            f"CodedSystem[{s.kind}] K={s.K} R={s.R} p={s.p} W={s.W} "
            f"q={s.q} backend={self.backend}",
            f"  failed  : {list(self.failed) or 'none'}",
            f"  caps    : stream={'device-pipelined' if be.supports_stream else 'per-chunk'}, "
            f"network-measuring={be.measures_network}",
        ]
        from ..topo import TieredLinkModel

        if isinstance(self.link, TieredLinkModel):
            lines.append(
                f"  link    : intra a={self.link.alpha_intra:g} "
                f"b={self.link.beta_bits_intra:g} | inter "
                f"a={self.link.alpha_inter:g} "
                f"b={self.link.beta_bits_inter:g}")
        lines += ["  " + ln for ln in self._enc.describe().splitlines()]
        if self.failed:
            from ..recover import UndecodableError

            try:
                dlines = self.decode_plan.describe().splitlines()
            except UndecodableError:
                dlines = [f"decode  : UNDECODABLE — erased "
                          f"{list(self.failed)} is information-losing for "
                          f"this (non-MDS) code"]
            lines += ["  " + ln for ln in dlines]
        if get_backend(self.backend).measures_network:
            from ..obs.drift import LEDGER

            lines += ["  " + ln for ln in LEDGER.describe().splitlines()]
        return "\n".join(lines)
