"""Encoder: one planner over the simulator, mesh, and kernel backends.

    spec = CodeSpec(kind="rs", K=16, R=4)
    plan = Encoder.plan(spec, backend="simulator")   # method="auto"
    y = plan.run(x)                                  # (R, W) sink values

`plan()` does all host-side work once — generator matrix / StructuredGRS
construction, cost-model algorithm selection, mesh table precompute — and
caches it keyed by the spec, so the hot path (`plan.run`) never rebuilds
tables.  Two cache levels:

  * table cache: `CodeSpec.table_key()` (spec minus payload width W) ->
    `HostTables`.  Shared across backends and W variants; this is what used
    to be rebuilt on every `shardmap_exec.build_*_tables` /
    `framework.decentralized_encode` call.
  * plan cache: (spec, backend, method, A-digest) -> `EncodePlan`, so mesh
    plans also keep their compiled shard_map executable across calls.

`method="auto"` picks the argmin of the Table-I linear cost
C = alpha*C1 + beta_bits*C2 (C2 already scaled by the spec's payload width
W) over the schedules available for the spec (universal prepare-and-shoot
always; the RS/Lagrange-specific draw-and-loose factorization when the code
is structured).
"""
from __future__ import annotations

import hashlib
import threading
from dataclasses import dataclass, field as dc_field
from typing import Any, Callable

import numpy as np

from ..core import cost_model
from ..core.cauchy import StructuredGRS, cost_cauchy
from ..core.cost_model import LinearCost
from ..core.dft_a2a import cost_dft
from ..core.field import Field
from ..topo import (Placement, TieredCost, TieredLinkModel, Topology,
                    n_procs as topo_n_procs, place, tiered_encode_cost)
from .backends import build_mesh_callable
from .registry import PlanStats, get_backend
from .spec import CodeSpec

# default link model used for auto selection and describe(): ~10us latency,
# 17 bits/ns-class links (the constants the demos/benchmarks report with)
ALPHA_DEFAULT = 1e-5
BETA_BITS_DEFAULT = 17e-9


# ---------------------------------------------------------------------------
# host-side tables (cached per spec, W-independent)
# ---------------------------------------------------------------------------

@dataclass
class HostTables:
    """Everything host-side a plan needs: the generator block, the structured
    code (when any), and lazily-built mesh schedules per method."""

    spec: CodeSpec
    field: Field
    A: np.ndarray                      # (K, R) generator block
    sgrs: StructuredGRS | None
    _mesh: dict[str, Any] = dc_field(default_factory=dict)
    _ntt: Any = "unset"                # lazy NTTEncodeParams | None
    _ir: dict = dc_field(default_factory=dict)  # method -> RoundIR

    def encode_ir(self, method: str):
        """The canonical (placement-free) `core.schedule.RoundIR` of the
        full framework encode for `method`, built and `validate()`d once
        per table set — every backend lowers from this one program."""
        if method not in self._ir:
            from ..core.schedule import build_encode_ir

            self._ir[method] = build_encode_ir(
                self.spec, method=method, A=self.A,
                sgrs=self.sgrs).validate()
        return self._ir[method]

    def ntt_params(self):
        """NTT fast-path constants for the local backend (None when the
        spec has no radix-2 single-coset structure), built once."""
        if self._ntt == "unset":
            from ..kernels.ntt_encode import NTTEncodeParams

            self._ntt = NTTEncodeParams.build(self.spec, self.sgrs)
        return self._ntt

    def mesh_tables(self, method: str):
        """ParityTables for the framework grid, built once per method."""
        if method not in self._mesh:
            from ..core.parity import build_encode_tables

            self._mesh[method] = build_encode_tables(
                self.field, self.A, p=self.spec.p, method=method,
                sgrs=self.sgrs)
        return self._mesh[method]

    def dft_mesh_tables(self):
        if "dft" not in self._mesh:
            from ..core.shardmap_exec import build_dft_tables

            self._mesh["dft"] = build_dft_tables(self.field, self.spec.K,
                                                 self.spec.K)
        return self._mesh["dft"]


_TABLES: dict[tuple, HostTables] = {}
_PLANS: dict[tuple, "EncodePlan"] = {}
_STATS = {"table_hits": 0, "table_misses": 0,
          "plan_hits": 0, "plan_misses": 0}


def _digest(A: np.ndarray | None) -> str | None:
    if A is None:
        return None
    A = np.ascontiguousarray(np.asarray(A, np.int64))
    return hashlib.sha1(repr(A.shape).encode() + A.tobytes()).hexdigest()


def _host_tables(spec: CodeSpec, A: np.ndarray | None, digest: str | None) -> HostTables:
    key = spec.table_key() + (digest,)
    hit = _TABLES.get(key)
    if hit is not None:
        _STATS["table_hits"] += 1
        return hit
    _STATS["table_misses"] += 1
    f = spec.field
    sgrs = None
    if A is not None:
        A = f.arr(A)
        if A.shape != (spec.K, spec.R):
            raise ValueError(f"A must be ({spec.K}, {spec.R}), got {A.shape}")
        if spec.kind in ("dft", "rs"):
            raise ValueError(
                f"kind={spec.kind!r} derives its matrix from the spec; drop "
                "A (use kind='universal' or 'lagrange' for explicit matrices)")
    else:
        if spec.structured():
            sgrs = StructuredGRS.build(f, spec.K, spec.R, P=spec.P,
                                       lagrange=spec.kind == "lagrange")
            A = sgrs.grs.A_direct()
        else:
            A = spec.default_matrix(f)
    tables = HostTables(spec, f, A, sgrs)
    _TABLES[key] = tables
    return tables


# ---------------------------------------------------------------------------
# method selection (Table I cost model)
# ---------------------------------------------------------------------------

def method_costs(spec: CodeSpec, sgrs: StructuredGRS | None) -> dict[str, LinearCost]:
    """Analytic (C1, C2) of the full framework encode per available method.

    C2 is already scaled by the spec's payload width W (matching the
    measured `RoundNetwork.C2` of a W-wide run) — evaluate totals with
    `cost.total(alpha, beta_bits)` at W=1, not with W again."""
    if spec.kind == "dft":
        c1, c2 = cost_dft(spec.K, spec.P, spec.p)
        return {"dft": LinearCost(c1, c2 * spec.W)}
    out = {
        "universal": cost_model.framework(
            spec.K, spec.R, spec.p,
            cost_model.universal(min(spec.K, spec.R), spec.p), spec.W)
    }
    if sgrs is not None:
        a2a = LinearCost(*cost_cauchy(sgrs, 0, spec.p))
        out["rs"] = cost_model.framework(spec.K, spec.R, spec.p, a2a, spec.W)
    return out


def _ir_tiered_cost(tables: "HostTables", method: str,
                    placement: Placement) -> TieredCost | None:
    """Per-tier cost derived from the canonical schedule IR — the fallback
    pricing for placement profiles with no closed form (e.g. the K < R
    broadcast phase on a host boundary)."""
    try:
        a = tables.encode_ir(method).attribute(placement)
    except Exception:  # noqa: BLE001 — pricing fallback must never raise
        return None
    W = tables.spec.W
    return TieredCost(LinearCost(a["intra"][0], a["intra"][1] * W),
                      LinearCost(a["inter"][0], a["inter"][1] * W))


def _resolve_method(spec: CodeSpec, tables: "HostTables | None", method: str,
                    placement: Placement | None = None, link=None
                    ) -> tuple[str, dict[str, LinearCost]]:
    sgrs = tables.sgrs if tables is not None else None
    costs = method_costs(spec, sgrs)
    if method == "auto":
        # argmin of the linear cost (W already folded into each C2);
        # specific schedule wins exact ties.  Under a placement and a
        # tiered link model, each method is priced by its per-tier split
        # (IR-derived when the closed form doesn't apply, flat as a last
        # resort) — topology can flip the choice when one schedule keeps
        # more traffic intra.
        if placement is not None and isinstance(link, TieredLinkModel):
            def _score(m: str) -> float:
                tc = tiered_encode_cost(spec, m, placement, sgrs=sgrs)
                if tc is None and tables is not None:
                    tc = _ir_tiered_cost(tables, m, placement)
                return link.us(tc if tc is not None else costs[m])
        elif link is not None:
            def _score(m: str) -> float:
                return link.us(costs[m])
        else:
            def _score(m: str) -> float:
                return costs[m].total(ALPHA_DEFAULT, BETA_BITS_DEFAULT)
        chosen = min(costs, key=lambda m: (_score(m), m == "universal"))
        return chosen, costs
    if method not in costs:
        raise ValueError(
            f"method {method!r} unavailable for {spec.kind!r} spec "
            f"(have {tuple(costs)})")
    return method, costs


# ---------------------------------------------------------------------------
# EncodePlan
# ---------------------------------------------------------------------------

@dataclass
class EncodePlan(PlanStats):
    """An executable encode: spec + resolved method + backend + host tables.

    Obtained from `Encoder.plan`; cached, so hold on to it (or re-call
    `Encoder.plan` — both hit the cache) and call `.run` per payload.

    Plans are shared across callers AND threads; per-run measurements
    (`last_stats`, `sim_net`, `stream_stats` — see `registry.PlanStats`)
    are thread-local, so every thread reads the stats of its own last run.
    """

    op = "encode"  # stream/backend dispatch discriminator (not a field)

    spec: CodeSpec
    backend: str
    method: str
    tables: HostTables
    costs: dict[str, LinearCost]
    # hierarchical-topology context (see repro.topo): placement drives the
    # simulator's per-tier accounting, topology the hierarchical mesh grid,
    # link the tiered pricing in describe()/auto selection
    placement: Placement | None = None
    topology: Topology | None = None
    link: Any = None
    # run the tier_commute rewrite pass over the schedule IR (requires a
    # placement; simulator backend executes the rewritten program)
    commute: bool = False
    _mesh_fn: Callable | None = None
    _local_fn: Callable | None = None
    _ir: Any = None                    # lazily-resolved plan-level RoundIR
    # thread-local per-run stats storage (PlanStats reads/writes this)
    _tls: Any = dc_field(default_factory=threading.local, repr=False)

    @property
    def field(self) -> Field:
        return self.tables.field

    @property
    def A(self) -> np.ndarray:
        """The (K, R) generator block (x^T A are the sink values)."""
        return self.tables.A

    @property
    def sgrs(self) -> StructuredGRS | None:
        return self.tables.sgrs

    def run(self, x) -> np.ndarray:
        """Encode payloads x (K,) or (K, W) -> sink values (R,)/(R, W)."""
        x = np.asarray(x)
        if x.shape[0] != self.spec.K:
            raise ValueError(f"x must have leading dim K={self.spec.K}, "
                             f"got {x.shape}")
        squeeze = x.ndim == 1
        y = get_backend(self.backend).encode(self, x[:, None] if squeeze
                                             else x)
        return y[:, 0] if squeeze else y

    def run_stream(self, payload, *, chunk_w: int | None = None):
        """Streamed encode: generator of (R, w) sink blocks.

        `payload` is a (K, W) array (split into VMEM-sized chunks of width
        `chunk_w`, default `stream.default_chunk_w`) or an iterable of
        (K, w_i) chunks (streamed as given, re-split only above chunk_w).
        Concatenating the yielded blocks is bitwise-equal to `run` on the
        concatenated payload.  On the simulator backend,
        `plan.stream_stats` carries exact per-chunk C1/C2.
        """
        from . import stream

        return stream.run_stream(self, payload, chunk_w=chunk_w)

    def run_batched(self, xs, *, chunk_w: int | None = None) -> list[np.ndarray]:
        """Encode a batch of payloads (each (K,) or (K, W_i)) in one
        coalesced streamed execution; returns per-payload sink values."""
        from . import stream

        return stream.run_batched(self, xs, chunk_w=chunk_w)

    @property
    def local_impl(self) -> str:
        """Which kernel the local backend runs: "ntt" (O(K log K) fast
        path) or "dense" (field-matmul `encode_blocks`)."""
        return "ntt" if self.tables.ntt_params() is not None else "dense"

    # -- streaming adapter (see api/stream.py) ------------------------------
    def _stream_sim_chunk(self, x: np.ndarray):
        from .backends import run_simulator

        return run_simulator(self, x)  # (y, RoundNetwork) pair

    def _stream_device_fn(self):
        import jax
        import numpy as _np

        q = self.field.q
        spec = self.spec

        def to_device(c):
            return jax.device_put(
                _np.ascontiguousarray(c % q).astype(_np.uint32))

        if self.backend == "mesh":
            fn = self.mesh_callable()
            if spec.kind == "dft":
                return to_device, fn, lambda y: np.asarray(y, np.int64)
            return to_device, fn, lambda y: np.asarray(
                y, np.int64)[: spec.R]
        from .backends import local_encode_callable

        fn = local_encode_callable(self)
        return to_device, fn, lambda y: np.asarray(y, np.int64)

    def schedule_ir(self):
        """The plan's `core.schedule.RoundIR`: the canonical per-method
        program from the host tables, with `tier_commute(placement)`
        applied when the plan was built with `commute=True`.  Cached for
        the plan's lifetime (tables cache the canonical IR per method)."""
        if self._ir is None:
            ir = self.tables.encode_ir(self.method)
            if self.commute and self.placement is not None:
                ir = ir.tier_commute(self.placement)
            self._ir = ir
        return self._ir

    def cost(self) -> LinearCost:
        """(C1, C2) of the chosen schedule per the Table-I cost model
        (the canonical schedule — a commuted plan's exact counts come from
        `schedule_ir().cost()`, see `obs.drift`)."""
        return self.costs[self.method]

    def tiered_cost(self) -> TieredCost | None:
        """Exact per-tier (intra, inter) split of `cost()` under the plan's
        placement; None without a placement or when the placement has no
        closed form (the simulator's measured `sim_net.by_tier()` still
        applies).  A `commute=True` plan's split comes from its rewritten
        schedule IR — that is the program its runs execute."""
        if self.placement is None:
            return None
        if self.commute:
            a = self.schedule_ir().attribute(self.placement)
            W = self.spec.W
            return TieredCost(
                LinearCost(a["intra"][0], a["intra"][1] * W),
                LinearCost(a["inter"][0], a["inter"][1] * W))
        return tiered_encode_cost(self.spec, self.method, self.placement,
                                  sgrs=self.sgrs)

    def mesh_callable(self):
        """The jitted shard_map executable (mesh backend only): global
        (K, W) uint32 -> (K, W) uint32; kept for the plan's lifetime."""
        if self.backend != "mesh":
            raise ValueError("mesh_callable() is for backend='mesh' plans")
        if self._mesh_fn is None:
            self._mesh_fn = build_mesh_callable(self)
        return self._mesh_fn

    def describe(self) -> str:
        s = self.spec
        c = self.cost()
        model_us = c.total(ALPHA_DEFAULT, BETA_BITS_DEFAULT) * 1e6
        lines = [
            f"EncodePlan[{s.kind}] K={s.K} R={s.R} p={s.p} W={s.W} q={s.q}",
            f"  backend : {self.backend}",
            f"  method  : {self.method} "
            f"(available: {', '.join(sorted(self.costs))})",
            f"  cost    : C1={c.C1} rounds, C2={c.C2} elems/port "
            f"(model C ~ {model_us:.1f} us)",
            f"  tables  : cached, key={s.table_key()}",
            f"  schedule: {self.schedule_ir().summary(self.placement)}",
        ]
        if self.topology is not None:
            t = self.topology
            pol = self.placement.policy if self.placement else "none"
            lines.append(f"  topo    : {t.hosts} hosts x "
                         f"{t.devices_per_host} devices, placement={pol}")
            tc = self.tiered_cost()
            if tc is not None:
                us = (self.link.us(tc)
                      if isinstance(self.link, TieredLinkModel) else None)
                lines.append(
                    f"  tiers   : intra C1={tc.intra.C1} C2={tc.intra.C2} | "
                    f"inter C1={tc.inter.C1} C2={tc.inter.C2}"
                    + (f" (model C ~ {us:.1f} us)" if us is not None else ""))
        if self.backend == "local":
            impl = ("O(K log K) NTT fast path" if self.local_impl == "ntt"
                    else "Pallas/jnp field-matmul kernel")
            lines.append(f"  note    : local backend runs the {impl}; "
                         "no schedule is executed")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------

class Encoder:
    """Namespace for the plan-then-execute API (all classmethods)."""

    ALPHA = ALPHA_DEFAULT
    BETA_BITS = BETA_BITS_DEFAULT

    @classmethod
    def plan(cls, spec: CodeSpec, backend: str = "simulator",
             method: str = "auto", A: np.ndarray | None = None, *,
             topology: Topology | Placement | None = None,
             link=None, commute: bool = False) -> EncodePlan:
        """Plan an encode: resolve the algorithm, build-or-reuse host tables,
        and return the cached executable plan.

        backend : a registered backend name — "simulator" | "mesh" |
                  "local" built in, plus anything added via
                  `api.register_backend` (capability-checked here, at plan
                  time, via `Backend.validate`)
        method  : "auto" (cost-model argmin) | "universal" | "rs" | "dft"
        A       : explicit (K, R) generator block — required for
                  kind="universal" specs without a seed; allowed for
                  kind="lagrange" with arbitrary (unstructured) points, in
                  which case only the universal schedule applies.
        topology: a `repro.topo.Topology` (placed with the affinity policy
                  when it has enough slots) or an explicit `Placement`.
                  The simulator then reports exact per-tier C1/C2
                  (`plan.sim_net.by_tier()`, asserted in the drift
                  ledger); the mesh backend runs a (hosts x K/hosts)
                  hierarchical grid when hosts divides K.
        link    : `LinkModel` or `repro.topo.TieredLinkModel` — prices
                  `method="auto"`; with a placement and a tiered link the
                  argmin runs over the per-tier split.
        commute : apply the `RoundIR.tier_commute` rewrite pass under the
                  resolved placement (required): the commuting reduce
                  rounds are re-synthesized host-aware so inter-host
                  rounds strictly shrink (or the schedule is unchanged).
                  Simulator runs execute the rewritten program; the drift
                  ledger checks it against `schedule_ir().cost()`.
        """
        get_backend(backend).validate(spec, op="encode")
        placement = None
        topo = None
        if topology is not None:
            if isinstance(topology, Placement):
                placement, topo = topology, topology.topology
            elif isinstance(topology, Topology):
                topo = topology
                if topology.n_slots >= topo_n_procs(spec):
                    placement = place(spec, topology, "affinity")
                elif get_backend(backend).measures_network:
                    raise ValueError(
                        f"topology has {topology.n_slots} slots < "
                        f"{topo_n_procs(spec)} processors — pass a larger "
                        "topology (or an explicit Placement) for the "
                        "simulator backend")
            else:
                raise TypeError(
                    f"topology must be a Topology or Placement, "
                    f"got {type(topology).__name__}")
        if commute and placement is None:
            raise ValueError(
                "commute=True requires a placement — pass topology= (a "
                "Topology with enough slots, or an explicit Placement)")
        digest = _digest(A)
        plan_key = (spec, backend, method, digest, placement, topo, link,
                    commute)
        hit = _PLANS.get(plan_key)
        if hit is not None:
            _STATS["plan_hits"] += 1
            return hit
        _STATS["plan_misses"] += 1
        tables = _host_tables(spec, A, digest)
        resolved, costs = _resolve_method(spec, tables, method,
                                          placement, link)
        plan = EncodePlan(spec, backend, resolved, tables, costs,
                          placement=placement, topology=topo, link=link,
                          commute=commute)
        _PLANS[plan_key] = plan
        return plan

    @classmethod
    def auto_method(cls, spec: CodeSpec) -> str:
        """The method `method="auto"` resolves to for this spec."""
        tables = None
        if spec.structured():
            tables = _host_tables(spec, None, None)
        return _resolve_method(spec, tables, "auto")[0]

    @classmethod
    def cache_info(cls) -> dict[str, int]:
        return dict(_STATS, plans=len(_PLANS), tables=len(_TABLES))

    @classmethod
    def cache_clear(cls) -> None:
        """Coordinated clear of ALL plan/table caches — encode plans, the
        shared host-table cache, AND the decode caches (decode tables hold
        references into the encoder's host tables, so clearing only the
        encode side would leave decode plans serving stale tables).  Same
        entry point as `repro.api.cache_clear()`."""
        import sys

        _clear_encoder_state()
        # decode caches exist only once the recover stack was imported;
        # an encode-only process has nothing stale and skips the import
        _rplanner = sys.modules.get(
            __package__.rsplit(".", 1)[0] + ".recover.planner")
        if _rplanner is not None:
            _rplanner._clear_decoder_state()


def _clear_encoder_state() -> None:
    """Drop the encode-side caches only (see `Encoder.cache_clear` for the
    coordinated clear applications should use)."""
    _PLANS.clear()
    _TABLES.clear()
    for k in _STATS:
        _STATS[k] = 0
