"""Batched request queue: coalesce concurrent encode/decode requests into
streamed plan executions.

A serving replica receives many small independent coding requests (encode
these shards, repair that erasure pattern).  Dispatching each one as its
own `plan.run` pays jit dispatch and transfer overhead per request; the
queue instead drains whatever is pending, groups requests that share an
executable plan — same (spec, method/erasure pattern, backend) — and runs
each group as ONE `plan.run_batched` call, so concurrent payloads ride the
same chunk callables and the double-buffered stream pipeline
(api/stream.py).

    q = CodingQueue(backend="local")
    fut = q.submit_encode(spec, x)          # returns concurrent Future
    y = fut.result()
    q.close()

This is the engine behind `repro.api.CodedSystem.submit` — a session lazily
opens one queue on its backend and routes `submit("encode"|"decode", ...)`
futures through it (erasure patterns pinned at submit time); direct
`CodingQueue` use remains supported for callers batching across specs.

Single worker thread; batching is opportunistic (whatever accumulated
since the last drain, bounded by `max_batch_w` payload columns per group).
Correctness is backend-bitwise: results equal per-request `plan.run`.
"""
from __future__ import annotations

import queue
import threading
from concurrent.futures import Future
from dataclasses import dataclass, field as dc_field
from typing import Any

import numpy as np


@dataclass
class _Request:
    key: tuple                 # plan-cache group key (includes the A digest)
    op: str                    # "encode" | "decode"
    spec: Any
    erased: tuple | None
    A: Any                     # explicit generator block (or None)
    payload: np.ndarray
    future: Future


@dataclass
class QueueStats:
    requests: int = 0
    batches: int = 0
    coalesced: list[int] = dc_field(default_factory=list)  # group sizes

    @property
    def max_coalesced(self) -> int:
        return max(self.coalesced, default=0)


class CodingQueue:
    """Coalescing encode/decode front-end over the plan caches."""

    def __init__(self, backend: str = "local", *,
                 chunk_w: int | None = None, max_batch_w: int = 1 << 16):
        # finish jax's (heavily circular) first import on THIS thread:
        # letting the worker and concurrent clients race it can observe a
        # partially initialized jax.numpy (py3.10 import lock granularity)
        import jax.numpy  # noqa: F401

        self.backend = backend
        self.chunk_w = chunk_w
        self.max_batch_w = max_batch_w
        self.stats = QueueStats()
        self._q: "queue.Queue[_Request | None]" = queue.Queue()
        self._closing = False
        self._worker = threading.Thread(target=self._loop, daemon=True)
        self._worker.start()

    # -- client side --------------------------------------------------------
    def submit_encode(self, spec, x, A=None) -> Future:
        """Encode payload x (K,)/(K, W) under `spec`; Future of sinks.
        `A` is the explicit generator block for kind="universal"/"lagrange"
        specs that carry one (same contract as `Encoder.plan`); its digest
        is part of the group key, so same-spec requests with different
        matrices never coalesce into one plan."""
        from ..api.planner import _digest

        return self._submit(_Request(
            ("enc", spec, self.backend, _digest(A)), "encode",
            spec, None, A, np.asarray(x), Future()))

    def submit_decode(self, spec, erased, v, A=None) -> Future:
        """Repair `erased` from survivor symbols v; Future of symbols."""
        from ..api.planner import _digest

        erased = tuple(sorted({int(e) for e in erased}))
        return self._submit(_Request(
            ("dec", spec, erased, self.backend, _digest(A)), "decode",
            spec, erased, A, np.asarray(v), Future()))

    def _submit(self, req: _Request) -> Future:
        if self._closing or self._worker is None:
            raise RuntimeError("queue is closed")
        self._q.put(req)
        return req.future

    def close(self, timeout: float | None = 30.0) -> None:
        """Drain outstanding requests and stop the worker.

        The worker processes everything still queued (even a request that
        raced past `_submit`'s closed check) before exiting, so no
        accepted Future is left unresolved."""
        if self._worker is None:
            return
        self._closing = True
        self._q.put(None)
        self._worker.join(timeout=timeout)
        self._worker = None

    # -- worker side --------------------------------------------------------
    def _drain(self, first: _Request | None) -> tuple[list[_Request], bool]:
        """Everything currently queued, and whether a close() sentinel was
        seen (leftovers BEHIND the sentinel are drained too — they raced
        with close() and must still resolve)."""
        batch = [] if first is None else [first]
        closing = first is None
        while True:
            try:
                nxt = self._q.get_nowait()
            except queue.Empty:
                return batch, closing
            if nxt is None:
                closing = True
            else:
                batch.append(nxt)

    def _loop(self) -> None:
        while True:
            first = self._q.get()
            batch, closing = self._drain(first)
            self.stats.requests += len(batch)  # single-writer: the worker
            groups: dict[tuple, list[_Request]] = {}
            for req in batch:
                groups.setdefault(req.key, []).append(req)
            for reqs in groups.values():
                self._process_group(reqs)
            if closing:
                return

    def _process_group(self, reqs: list[_Request]) -> None:
        from ..api import Encoder
        from ..recover import Decoder

        self.stats.batches += 1
        self.stats.coalesced.append(len(reqs))
        try:
            r0 = reqs[0]
            if r0.op == "encode":
                plan = Encoder.plan(r0.spec, backend=self.backend, A=r0.A)
            else:
                plan = Decoder.plan(r0.spec, erased=r0.erased,
                                    backend=self.backend, A=r0.A)
            # bound the coalesced width per run_batched call
            chunk: list[_Request] = []
            w = 0
            for req in reqs:
                rw = 1 if req.payload.ndim == 1 else req.payload.shape[1]
                if chunk and w + rw > self.max_batch_w:
                    self._run_group(plan, chunk)
                    chunk, w = [], 0
                chunk.append(req)
                w += rw
            if chunk:
                self._run_group(plan, chunk)
        except Exception as exc:  # noqa: BLE001 — propagate per-future
            for req in reqs:
                if not req.future.done():
                    req.future.set_exception(exc)

    def _run_group(self, plan, reqs: list[_Request]) -> None:
        outs = plan.run_batched([r.payload for r in reqs],
                                chunk_w=self.chunk_w)
        for req, out in zip(reqs, outs):
            req.future.set_result(out)
