"""Batched request queue: coalesce concurrent encode/decode/rebuild
requests into streamed plan executions.

A serving replica receives many small independent coding requests (encode
these shards, repair that erasure pattern, re-materialize that codeword).
Dispatching each one as its own `plan.run` pays jit dispatch and transfer
overhead per request; the queue instead drains whatever is pending, groups
requests that share an executable plan — same (spec, method/erasure
pattern, backend) — and runs each group as ONE `plan.run_batched` call, so
concurrent payloads ride the same chunk callables and the double-buffered
stream pipeline (api/stream.py).

    q = CodingQueue(backend="local")
    fut = q.submit_encode(spec, x)          # returns concurrent Future
    y = fut.result()
    q.close()

This is the engine behind `repro.api.CodedSystem.submit` — a session lazily
opens one queue on its backend and routes `submit("encode"|"decode"|
"rebuild", ...)` futures through it; direct `CodingQueue` use remains
supported for callers batching across specs.

Erasure patterns are pinned per request at submit time, with *failover*:
a request submitted with `pattern_ref` (a callable returning the live
pattern — sessions pass theirs) is re-checked when the worker drains it.
If the live pattern has grown into a strict superset of the pinned one —
processors died while the request sat in the queue — the request is
transparently replanned against the superset and its (N, W) payload
re-sliced to the new survivor set, so symbols from dead processors are
never consumed; a decode future still resolves to the rows of its pinned
pattern, a rebuild future to the fully healed codeword.  A (K, W)
survivors-only decode payload cannot be re-sliced: its future fails with a
`RuntimeError` instead of silently decoding stale rows.

Single worker thread; batching is opportunistic (whatever accumulated
since the last drain, bounded by `max_batch_w` payload columns per group).
Correctness is backend-bitwise: results equal per-request `plan.run`.
`close()` drains everything accepted; if the worker fails to drain within
the timeout, every still-pending Future is failed with a `RuntimeError`
and the timeout is raised — accepted futures never dangle unresolved.
"""
from __future__ import annotations

import queue
import threading
from concurrent.futures import Future
from dataclasses import dataclass, field as dc_field
from typing import Any, Callable

import numpy as np

from ..obs.metrics import REGISTRY as _METRICS
from ..obs.trace import get_tracer

_Q_REQS = _METRICS.counter("queue_requests_total",
                           "requests drained by the coding queue")
_Q_BATCHES = _METRICS.counter("queue_batches_total",
                              "coalesced plan-group executions")
_Q_FAILOVERS = _METRICS.counter(
    "queue_failovers_total", "requests replanned onto a superset pattern")
_Q_GROUP = _METRICS.histogram("queue_group_size",
                              "requests coalesced per group execution")


@dataclass
class _Request:
    op: str                    # "encode" | "decode" | "rebuild"
    spec: Any
    erased: tuple | None       # pinned erasure pattern (decode/rebuild)
    A: Any                     # explicit generator block (or None)
    payload: np.ndarray
    future: Future
    digest: str | None = None  # A digest (part of the group key)
    pattern_ref: Callable | None = None  # live-pattern getter (failover)
    effective: tuple | None = None       # pattern resolved at drain time
    meta: Any = None           # opaque caller tag, echoed to the observer
    group_n: int = 1           # size of the coalesced group it executed in
    t_submit: float = 0.0      # tracer timestamp at submit (0 = untraced)


@dataclass
class QueueStats:
    requests: int = 0
    batches: int = 0
    coalesced: list[int] = dc_field(default_factory=list)  # group sizes
    failovers: int = 0         # requests replanned onto a superset pattern

    @property
    def max_coalesced(self) -> int:
        return max(self.coalesced, default=0)


class CodingQueue:
    """Coalescing encode/decode/rebuild front-end over the plan caches."""

    def __init__(self, backend: str = "local", *,
                 chunk_w: int | None = None, max_batch_w: int = 1 << 16,
                 observer: Callable | None = None):
        # finish jax's (heavily circular) first import on THIS thread:
        # letting the worker and concurrent clients race it can observe a
        # partially initialized jax.numpy (py3.10 import lock granularity)
        import jax.numpy  # noqa: F401

        self.backend = backend
        self.chunk_w = chunk_w
        self.max_batch_w = max_batch_w
        # observer(meta, op, group_n, failover) is called on the worker
        # thread as each request resolves (only for requests submitted
        # with a meta tag) — the service layer's per-tenant observability
        # hook; observer exceptions are swallowed, never fail a future
        self.observer = observer
        self.stats = QueueStats()
        self._q: "queue.Queue[_Request | None]" = queue.Queue()
        self._closing = False
        self._pending: set[Future] = set()
        self._plock = threading.Lock()
        self._worker = threading.Thread(target=self._loop, daemon=True)
        self._worker.start()

    # -- client side --------------------------------------------------------
    def submit_encode(self, spec, x, A=None, meta=None) -> Future:
        """Encode payload x (K,)/(K, W) under `spec`; Future of sinks.
        `A` is the explicit generator block for kind="universal"/"lagrange"
        specs that carry one (same contract as `Encoder.plan`); its digest
        is part of the group key, so same-spec requests with different
        matrices never coalesce into one plan."""
        from ..api.planner import _digest

        return self._submit(_Request("encode", spec, None, A, np.asarray(x),
                                     Future(), digest=_digest(A), meta=meta))

    def submit_decode(self, spec, erased, v, A=None,
                      pattern_ref=None, meta=None) -> Future:
        """Repair `erased` from v; Future of the erased symbols (rows
        ordered like the pinned pattern).  `v` carries either the K kept
        survivor rows (classic) or the full (N, W) codeword — the worker
        slices it; the full form is required for failover (`pattern_ref`,
        see module docstring)."""
        from ..api.planner import _digest

        erased = tuple(sorted({int(e) for e in erased}))
        return self._submit(_Request("decode", spec, erased, A,
                                     np.asarray(v), Future(),
                                     digest=_digest(A),
                                     pattern_ref=pattern_ref, meta=meta))

    def submit_rebuild(self, spec, erased, cw, A=None,
                       pattern_ref=None, meta=None) -> Future:
        """Re-materialize the full codeword: Future of the healed (N, W)
        with every position of the (possibly failed-over) pattern
        recomputed.  `cw` must carry the full N codeword rows."""
        from ..api.planner import _digest

        erased = tuple(sorted({int(e) for e in erased}))
        cw = np.asarray(cw)
        if cw.shape[0] != spec.N:
            raise ValueError(
                f"rebuild payload must carry the full N={spec.N} codeword "
                f"rows, got leading dim {cw.shape[0]}")
        return self._submit(_Request("rebuild", spec, erased, A, cw,
                                     Future(), digest=_digest(A),
                                     pattern_ref=pattern_ref, meta=meta))

    def _submit(self, req: _Request) -> Future:
        # the closed check, pending registration and enqueue are ONE
        # critical section with close()'s sentinel put: a submit serialized
        # before close lands ahead of the sentinel (the worker drains it),
        # a submit serialized after raises — a late request can never slip
        # in behind the worker's final drain and hang its future
        tracer = get_tracer()
        if tracer is not None:
            req.t_submit = tracer.now_us()
        with self._plock:
            if self._closing or self._worker is None:
                raise RuntimeError("queue is closed")
            self._pending.add(req.future)
            self._q.put(req)
        return req.future

    @property
    def depth(self) -> int:
        """Requests accepted but not yet resolved (queued or executing)."""
        with self._plock:
            return len(self._pending)

    def close(self, timeout: float | None = 30.0) -> None:
        """Drain outstanding requests and stop the worker.

        The worker processes everything still queued before exiting, so no
        accepted Future is left unresolved; the submit/close boundary is
        locked, so a submit racing with close either lands ahead of the
        shutdown sentinel (and resolves) or deterministically raises
        ``RuntimeError("queue is closed")``.  If the worker does NOT drain
        within `timeout`, every still-pending Future is failed with a
        `RuntimeError` and the same error is raised here — a timed-out
        close is loud, never a silent return with live futures dangling.
        """
        with self._plock:
            worker = self._worker
            if worker is None:
                return
            if not self._closing:
                self._closing = True
                self._q.put(None)
        worker.join(timeout=timeout)
        if worker.is_alive():
            with self._plock:
                stranded = [f for f in self._pending if not f.done()]
                self._pending.clear()
            err = RuntimeError(
                f"CodingQueue.close(): worker did not drain within "
                f"{timeout}s; {len(stranded)} pending request(s) failed")
            for fut in stranded:
                if not fut.done():
                    fut.set_exception(err)
            raise err
        self._worker = None

    # -- worker side --------------------------------------------------------
    def _drain(self, first: _Request | None) -> tuple[list[_Request], bool]:
        """Everything currently queued, and whether a close() sentinel was
        seen (leftovers BEHIND the sentinel are drained too — they raced
        with close() and must still resolve)."""
        batch = [] if first is None else [first]
        closing = first is None
        while True:
            try:
                nxt = self._q.get_nowait()
            except queue.Empty:
                return batch, closing
            if nxt is None:
                closing = True
            else:
                batch.append(nxt)

    def _resolve(self, req: _Request, *, result=None, exc=None) -> None:
        if req.t_submit:
            tracer = get_tracer()
            if tracer is not None:
                # one span per request: submit -> (coalesce+execute) ->
                # resolve, on the queue's per-op track
                tracer.complete(
                    f"op.{req.op}", req.t_submit,
                    tracer.now_us() - req.t_submit, pid="queue",
                    tid=req.op, cat="queue.op",
                    args={"group_n": req.group_n,
                          "kind": req.spec.kind, "K": req.spec.K,
                          "ok": exc is None,
                          "failover": bool(req.op != "encode"
                                           and req.effective is not None
                                           and req.effective != req.erased)})
        if self.observer is not None and req.meta is not None:
            # BEFORE the future resolves: a client unblocked by result()
            # must already see this op in the observer-fed stats
            failover = (req.op != "encode" and req.effective is not None
                        and req.effective != req.erased)
            try:
                self.observer(req.meta, req.op, req.group_n, failover)
            except Exception:  # noqa: BLE001 — observability never fails ops
                pass
        if not req.future.done():
            if exc is not None:
                req.future.set_exception(exc)
            else:
                req.future.set_result(result)
        with self._plock:
            self._pending.discard(req.future)

    def _effective_pattern(self, req: _Request) -> tuple:
        """The pattern this request will execute against, resolved at
        drain time: the pinned pattern, unless `pattern_ref` reports a
        strict superset (new failures landed since submit) — then the
        superset, so the plan never consumes dead survivors."""
        if req.op == "encode" or req.pattern_ref is None:
            return req.erased or ()
        live = tuple(sorted({int(e) for e in req.pattern_ref()}))
        if set(live) > set(req.erased):
            self.stats.failovers += 1
            _Q_FAILOVERS.inc(1, backend=self.backend)
            return live
        return req.erased

    def _group_key(self, req: _Request) -> tuple:
        if req.op == "encode":
            return ("enc", req.spec, self.backend, req.digest)
        # decode and rebuild share the plan (same pattern => same repair
        # matrix) but not the output contract — keep the op in the key
        return (req.op, req.spec, req.effective, self.backend, req.digest)

    def _loop(self) -> None:
        while True:
            first = self._q.get()
            batch, closing = self._drain(first)
            self.stats.requests += len(batch)  # single-writer: the worker
            if batch:
                _Q_REQS.inc(len(batch), backend=self.backend)
            groups: dict[tuple, list[_Request]] = {}
            for req in batch:
                req.effective = self._effective_pattern(req)
                groups.setdefault(self._group_key(req), []).append(req)
            for reqs in groups.values():
                self._process_group(reqs)
            if closing:
                return

    def _slice(self, req: _Request, plan) -> np.ndarray:
        """The (K, ...) survivor view `plan` consumes, re-sliced against
        the EFFECTIVE pattern (failover may have changed plan.kept)."""
        if req.op == "encode":
            return req.payload
        p = req.payload
        if p.shape[0] == req.spec.N:
            return p[list(plan.kept)]
        if p.shape[0] == req.spec.K:
            if req.effective != req.erased:
                raise RuntimeError(
                    f"pattern invalidated mid-flight ({req.erased} -> "
                    f"{req.effective}) but the request carried only the K "
                    "kept survivor rows — resubmit with the full (N, W) "
                    "codeword so the repair can re-slice around the new "
                    "failures")
            return p
        raise ValueError(
            f"payload must carry N={req.spec.N} or K={req.spec.K} rows, "
            f"got {p.shape}")

    def _postprocess(self, req: _Request, plan, out: np.ndarray) -> np.ndarray:
        """Shape the group-plan output into the request's contract."""
        if req.op == "decode":
            if req.effective != req.erased:
                # failover: the plan repaired the superset; the future
                # still resolves to the rows of the pinned pattern
                idx = [plan.erased.index(e) for e in req.erased]
                out = out[idx]
            return out
        if req.op == "rebuild":
            q = req.spec.q
            healed = (req.payload % q).astype(np.int64)
            if plan.erased:
                healed[list(plan.erased)] = out
            return healed
        return out

    def _process_group(self, reqs: list[_Request]) -> None:
        self.stats.batches += 1
        self.stats.coalesced.append(len(reqs))
        _Q_BATCHES.inc(1, backend=self.backend, op=reqs[0].op)
        _Q_GROUP.observe(len(reqs), backend=self.backend, op=reqs[0].op)
        for req in reqs:
            req.group_n = len(reqs)
        tracer = get_tracer()
        if tracer is not None:
            r0 = reqs[0]
            with tracer.span(f"execute.{r0.op}", pid="queue", tid="worker",
                             cat="queue.exec",
                             args={"group_n": len(reqs),
                                   "kind": r0.spec.kind, "K": r0.spec.K,
                                   "R": r0.spec.R}):
                self._execute_group(reqs)
        else:
            self._execute_group(reqs)

    def _execute_group(self, reqs: list[_Request]) -> None:
        from ..api import Encoder
        from ..recover import Decoder

        try:
            r0 = reqs[0]
            if r0.op == "encode":
                plan = Encoder.plan(r0.spec, backend=self.backend, A=r0.A)
            else:
                plan = Decoder.plan(r0.spec, erased=r0.effective,
                                    backend=self.backend, A=r0.A)
            # per-request slicing failures (stale K-row payloads) fail
            # their own future without sinking the rest of the group
            runnable: list[tuple[_Request, np.ndarray]] = []
            for req in reqs:
                try:
                    runnable.append((req, self._slice(req, plan)))
                except Exception as exc:  # noqa: BLE001 — per-future
                    self._resolve(req, exc=exc)
            # bound the coalesced width per run_batched call
            chunk: list[tuple[_Request, np.ndarray]] = []
            w = 0
            for req, v in runnable:
                rw = 1 if v.ndim == 1 else v.shape[1]
                if chunk and w + rw > self.max_batch_w:
                    self._run_group(plan, chunk)
                    chunk, w = [], 0
                chunk.append((req, v))
                w += rw
            if chunk:
                self._run_group(plan, chunk)
        except Exception as exc:  # noqa: BLE001 — propagate per-future
            for req in reqs:
                self._resolve(req, exc=exc)

    def _run_group(self, plan,
                   reqs: list[tuple[_Request, np.ndarray]]) -> None:
        outs = plan.run_batched([v for _, v in reqs], chunk_w=self.chunk_w)
        for (req, _), out in zip(reqs, outs):
            self._resolve(req, result=self._postprocess(req, plan, out))
