"""CodedService: a multi-tenant serving layer over pooled CodedSystems.

One process serving coded storage in production fronts *many* tenants,
each driving *many* volumes — the regime of Dimakis et al.'s decentralized
erasure codes (many sources feeding many storage nodes concurrently).
`CodedService` is that layer: it owns

  * a **session pool** — `CodedSystem` sessions keyed by
    (tenant, spec, backend, A-digest), created on first use, LRU-evicted
    beyond `max_sessions` (only sessions with nothing in flight and no
    live erasure state are evictable — erasure state is truth, not cache);
  * **one shared `CodingQueue`** — every pooled session submits through
    it, so requests that share an executable plan — same (spec, backend,
    A-digest) — coalesce into ONE `run_batched` execution *across
    sessions and tenants* while each future still resolves to its own
    rows;
  * an **admission gate** (`launch.tenancy.AdmissionController`) — global
    and per-tenant ceilings on in-flight ops/bytes with weighted-fair
    scheduling of waiters.  `submit()` blocks under backpressure (bounded,
    optional timeout) or raises `QueueFullError` with ``block=False``;
    nothing is ever silently dropped;
  * **per-tenant / per-tag observability** — `ServiceStats` (queue depth,
    coalescing ratio, p50/p99/p999 latency, failover counts) surfaced
    through `stats()` / `describe()` and `serve.py --service`.

Quickstart::

    from repro.api import CodeSpec
    from repro.launch.service import CodedService

    svc = CodedService(backend="local", max_inflight_ops=512)
    spec = CodeSpec(kind="rs", K=16, R=4)
    fut = svc.submit("tenant-a", spec, "encode", x)     # coalesces with
    fut2 = svc.submit("tenant-b", spec, "encode", x2)   # tenant-b's ops
    parity = fut.result()
    svc.session("tenant-a", spec).fail([2])             # erasure state is
    rep = svc.submit("tenant-a", spec, "decode", cw)    # per-session
    print(svc.describe())
    svc.close()

Failure semantics are the session's: decode/rebuild submissions pin the
session's erasure pattern at submit time and fail over to a superset
pattern if more processors die in the queue (`CodingQueue` failover); a
future resolves bitwise-correct or raises — `close()` drains everything
accepted and accounts for every admitted slot even on a timed-out drain.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

from ..api.planner import _digest
from ..api.spec import CodeSpec
from ..api.system import CodedSystem
from ..obs import trace as _trace
from ..obs.metrics import REGISTRY as _METRICS
from .coding_queue import CodingQueue
from .tenancy import (
    AdmissionController,
    QueueFullError,
    ServiceStats,
    TenantQuota,
)

__all__ = ["CodedService", "QueueFullError", "ServiceStats", "TenantQuota"]

_OPS = ("encode", "decode", "rebuild")

_SVC_OPS = _METRICS.counter("service_ops_total",
                            "tenant operations settled by the service")
_SVC_REJECTED = _METRICS.counter("service_rejected_total",
                                 "submissions refused at admission")
_SVC_LAT = _METRICS.histogram("service_latency_us",
                              "submit-to-settle latency per tenant op")


@dataclass
class _OpMeta:
    """Per-operation tag threaded through the queue and the future's done
    callback — carries everything needed to settle admission and stats."""

    tenant: str
    key: tuple
    tag: str | None
    nbytes: int
    t0: float
    op: str = "?"
    t_trace: float = 0.0   # tracer timestamp at submit (0 = untraced)


class CodedService:
    """Multi-tenant serving front-end (see module docstring).

    Parameters
    ----------
    backend           : registered backend every pooled session runs on
    max_inflight_ops  : global cap on admitted-but-unresolved operations
    max_inflight_bytes: global cap on admitted payload bytes in flight
    default_quota     : `TenantQuota` for tenants without an explicit one
    max_sessions      : session-pool size before idle LRU eviction
    chunk_w/max_batch_w : forwarded to the shared `CodingQueue`
    trace             : observability tracer — True (collect, read
                        `svc.tracer`), an `obs.trace.Tracer`, or a path
                        (trace JSON written there on `close()`).  The
                        tracer is process-installed for the service's
                        lifetime, so every layer underneath (queue,
                        stream pipeline, simulator rounds, kernels)
                        emits onto the same timeline.
    """

    def __init__(self, backend: str = "local", *,
                 max_inflight_ops: int = 1024,
                 max_inflight_bytes: int = 1 << 31,
                 default_quota: TenantQuota | None = None,
                 max_sessions: int = 64,
                 chunk_w: int | None = None,
                 max_batch_w: int = 1 << 16,
                 trace=None):
        self.backend = backend
        self.tracer, self._trace_path = _trace.resolve(trace)
        if self.tracer is not None:
            _trace.install(self.tracer)
        self._admission = AdmissionController(
            max_ops=max_inflight_ops, max_bytes=max_inflight_bytes,
            default_quota=default_quota)
        self._queue = CodingQueue(backend=backend, chunk_w=chunk_w,
                                  max_batch_w=max_batch_w,
                                  observer=self._observe)
        self._sessions: OrderedDict[tuple, CodedSystem] = OrderedDict()
        self._session_inflight: dict[tuple, int] = {}
        self._tenants: dict[str, ServiceStats] = {}
        self._tags: dict[str, ServiceStats] = {}
        self.max_sessions = max_sessions
        self._lock = threading.RLock()
        self._closed = False

    # -- quotas / stats registries ------------------------------------------
    def set_quota(self, tenant: str, quota: TenantQuota) -> None:
        """Install (or replace) `tenant`'s admission quota; waiters are
        re-evaluated immediately, so raising a quota unblocks live load."""
        self._admission.set_quota(tenant, quota)

    def _tenant_stats(self, tenant: str) -> ServiceStats:
        with self._lock:
            st = self._tenants.get(tenant)
            if st is None:
                st = self._tenants[tenant] = ServiceStats(tenant)
            return st

    def _tag_stats(self, tag: str) -> ServiceStats:
        with self._lock:
            st = self._tags.get(tag)
            if st is None:
                st = self._tags[tag] = ServiceStats(tag)
            return st

    # -- session pool --------------------------------------------------------
    def _key(self, tenant: str, spec: CodeSpec, A) -> tuple:
        return (tenant, spec, self.backend, _digest(A))

    def session(self, tenant: str, spec: CodeSpec, *,
                A: np.ndarray | None = None) -> CodedSystem:
        """The pooled `CodedSystem` for (tenant, spec, A) — created on
        first use, shared across that tenant's submissions, carrying the
        volume's live erasure state (`.fail()`/`.heal()` on it steer every
        later decode/rebuild the service routes there)."""
        key = self._key(tenant, spec, A)
        with self._lock:
            if self._closed:
                raise RuntimeError("service is closed")
            sess = self._sessions.get(key)
            if sess is not None:
                self._sessions.move_to_end(key)
                return sess
            sess = CodedSystem(spec, backend=self.backend, A=A,
                               queue=self._queue)
            self._sessions[key] = sess
            self._evict_idle()
            return sess

    def _evict_idle(self) -> None:
        """Drop least-recently-used sessions beyond `max_sessions` (must
        hold the lock).  Only sessions with zero in-flight ops AND no live
        failures are evictable: erasure state is system truth — evicting
        it would silently 'heal' a degraded volume."""
        if len(self._sessions) <= self.max_sessions:
            return
        for key in list(self._sessions):
            if len(self._sessions) <= self.max_sessions:
                return
            if self._session_inflight.get(key, 0) == 0 \
                    and not self._sessions[key].failed:
                # close() is pool-safe: the shared queue is not the
                # session's to stop
                self._sessions.pop(key).close()

    @property
    def sessions(self) -> int:
        with self._lock:
            return len(self._sessions)

    # -- submission ----------------------------------------------------------
    def submit(self, tenant: str, spec: CodeSpec, op: str, payload, *,
               A: np.ndarray | None = None, tag: str | None = None,
               block: bool = True, timeout: float | None = None):
        """Admission-controlled async submission; returns a
        `concurrent.futures.Future`.

        The op first passes the admission gate (blocking under bounded
        backpressure, or raising `QueueFullError` when ``block=False`` /
        on `timeout`), then rides the pooled session's queue path —
        coalescing with every other in-flight request that shares its
        (spec, backend, A-digest) plan, from ANY session or tenant.  `tag`
        additionally aggregates stats under `stats()["tags"]` (e.g. one
        tag per volume).  The future resolves to the op's own rows
        (encode -> parity, decode -> pinned-pattern rows, rebuild ->
        healed codeword) or raises; admission is released exactly when the
        future settles, so in-flight gauges include queue residency.
        """
        if op not in _OPS:
            raise ValueError(f"op must be one of {_OPS}, got {op!r}")
        stats = self._tenant_stats(tenant)
        v = np.asarray(payload)
        nbytes = int(v.nbytes)
        tracer = _trace.get_tracer()
        try:
            if tracer is not None:
                # the admit span makes backpressure *visible*: a long one
                # is time spent blocked on quota, not compute
                with tracer.span("admit", pid="service", tid=tenant,
                                 cat="service.admit",
                                 args={"op": op, "nbytes": nbytes}):
                    self._admission.acquire(tenant, nbytes, block=block,
                                            timeout=timeout)
            else:
                self._admission.acquire(tenant, nbytes, block=block,
                                        timeout=timeout)
        except QueueFullError:
            stats.record_rejected()
            _SVC_REJECTED.inc(1, tenant=tenant, op=op)
            if tag is not None:
                self._tag_stats(tag).record_rejected()
            raise
        try:
            sess = self.session(tenant, spec, A=A)
            meta = _OpMeta(tenant, self._key(tenant, spec, A), tag, nbytes,
                           time.perf_counter(), op=op,
                           t_trace=(tracer.now_us() if tracer is not None
                                    else 0.0))
            with self._lock:
                self._session_inflight[meta.key] = \
                    self._session_inflight.get(meta.key, 0) + 1
            stats.record_submitted(nbytes)
            if tag is not None:
                self._tag_stats(tag).record_submitted(nbytes)
            try:
                fut = sess.submit(op, v, meta=meta)
            except BaseException:
                self._settle(meta, ok=False, record_done=True)
                raise
        except BaseException:
            # admission slot must not leak when the submission never
            # reached the queue (closed queue, bad payload shape, ...)
            self._admission.release(tenant, nbytes)
            raise
        fut.add_done_callback(lambda f, m=meta: self._on_done(m, f))
        return fut

    # -- settlement ----------------------------------------------------------
    def _settle(self, meta: _OpMeta, *, ok: bool,
                record_done: bool) -> None:
        lat_us = (time.perf_counter() - meta.t0) * 1e6
        with self._lock:
            left = self._session_inflight.get(meta.key, 1) - 1
            if left:
                self._session_inflight[meta.key] = left
            else:
                self._session_inflight.pop(meta.key, None)
        if record_done:
            self._tenant_stats(meta.tenant).record_done(lat_us, meta.nbytes,
                                                        ok)
            if meta.tag is not None:
                self._tag_stats(meta.tag).record_done(lat_us, meta.nbytes,
                                                      ok)
            _SVC_OPS.inc(1, tenant=meta.tenant, op=meta.op,
                         status="ok" if ok else "error")
            _SVC_LAT.observe(lat_us, tenant=meta.tenant, op=meta.op)
            if meta.t_trace:
                tracer = _trace.get_tracer()
                if tracer is not None:
                    # per-tenant op-lifetime span: submit -> settle (queue
                    # residency + execution + callback), tagged for the
                    # viewer's detail pane
                    tracer.complete(
                        f"op.{meta.op}", meta.t_trace,
                        tracer.now_us() - meta.t_trace, pid="service",
                        tid=meta.tenant, cat="service.op",
                        args={"tenant": meta.tenant, "tag": meta.tag,
                              "nbytes": meta.nbytes, "ok": ok})

    def _on_done(self, meta: _OpMeta, fut) -> None:
        ok = not fut.cancelled() and fut.exception() is None
        self._settle(meta, ok=ok, record_done=True)
        self._admission.release(meta.tenant, meta.nbytes)

    def _observe(self, meta: _OpMeta, op: str, group_n: int,
                 failover: bool) -> None:
        """CodingQueue observer: per-op coalescing/failover attribution
        (runs on the queue worker as each request resolves)."""
        self._tenant_stats(meta.tenant).record_executed(group_n, failover)
        if meta.tag is not None:
            self._tag_stats(meta.tag).record_executed(group_n, failover)

    # -- introspection / lifecycle -------------------------------------------
    def stats(self) -> dict:
        """{"service": {...}, "tenants": {name: snapshot},
        "tags": {name: snapshot}} — service-level numbers are pool-wide
        (shared-queue coalescing ratio counts every session's requests)."""
        with self._lock:
            tenants = {k: v.snapshot() for k, v in self._tenants.items()}
            tags = {k: v.snapshot() for k, v in self._tags.items()}
            n_sessions = len(self._sessions)
        qs = self._queue.stats
        ops, nbytes = self._admission.inflight()
        return {
            "service": {
                "backend": self.backend,
                "sessions": n_sessions,
                "queue_depth": self._queue.depth,
                "inflight_ops": ops,
                "inflight_bytes": nbytes,
                "waiting": self._admission.waiting,
                "requests": qs.requests,
                "batches": qs.batches,
                "coalescing_ratio": (qs.requests / qs.batches
                                     if qs.batches else float("nan")),
                "failovers": qs.failovers,
            },
            "tenants": tenants,
            "tags": tags,
            "metrics": _METRICS.snapshot(),
        }

    def latencies_us(self, tenant: str | None = None) -> list[float]:
        """The raw completion-latency reservoir — one tenant's, or every
        tenant's merged (for aggregate percentiles in benches)."""
        with self._lock:
            stats = ([self._tenants[tenant]] if tenant is not None
                     else list(self._tenants.values()))
        out: list[float] = []
        for s in stats:
            out.extend(s.latencies_us())
        return out

    @property
    def queue_depth(self) -> int:
        """Requests accepted by the shared queue but not yet resolved."""
        return self._queue.depth

    def describe(self) -> str:
        st = self.stats()
        s = st["service"]
        lines = [
            f"CodedService backend={s['backend']} sessions={s['sessions']} "
            f"queue_depth={s['queue_depth']} inflight={s['inflight_ops']} ops"
            f"/{s['inflight_bytes']} B waiting={s['waiting']}",
            f"  queue   : {s['requests']} requests in {s['batches']} batches "
            f"(coalescing {s['coalescing_ratio']:.2f}x, "
            f"{s['failovers']} failover(s))",
        ]
        for kind in ("tenants", "tags"):
            for name, t in sorted(st[kind].items()):
                lines.append(
                    f"  {kind[:-1]:7s}: {name}: {t['submitted']} submitted / "
                    f"{t['completed']} ok / {t['failed']} failed / "
                    f"{t['rejected']} rejected; inflight={t['inflight_ops']}; "
                    f"coalesce={t['coalescing_ratio']:.2f}x "
                    f"failovers={t['failovers']}; "
                    f"p50={t['p50_us']:.0f}us p99={t['p99_us']:.0f}us "
                    f"p999={t['p999_us']:.0f}us")
        return "\n".join(lines)

    def close(self, timeout: float | None = 30.0) -> None:
        """Drain the shared queue (every accepted future resolves or is
        failed loudly), close every pooled session, and refuse further
        submissions.  Admission slots settle through the futures' done
        callbacks — even a timed-out drain fails the stranded futures,
        which releases their slots."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            sessions = list(self._sessions.values())
            self._sessions.clear()
        try:
            self._queue.close(timeout=timeout)
        finally:
            for sess in sessions:
                sess.close()
            if self.tracer is not None:
                _trace.uninstall(self.tracer)
                if self._trace_path is not None:
                    self.tracer.save(self._trace_path)

    def __enter__(self) -> "CodedService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
