"""Training launcher.

Local mode (default): runs a reduced config on the host devices — the
end-to-end driver used by examples/quickstart.py.  Production mode
(--production) builds the 16x16 (or 2x16x16) mesh shardings exactly as the
dry-run does; on real TPU hardware the same entry point drives the full
model (the only difference between dry-run and launch is .compile() vs
dispatch).

Fault tolerance:
  * coded checkpoints every --ckpt-every steps (async, RS parity across
    --ckpt-shards with --ckpt-parity tolerance) — restart with --resume
  * simulated failure injection (--fail-at step,shard[,shard...]) exercises
    the reconstruct path end-to-end
  * straggler-tolerant gradient coding (--stragglers s): the batch is cut
    across --coded-workers per the fractional-repetition assignment and
    every step decodes around the injected straggler mask
    (--straggler-mode random|bursty|fixed) with bitwise-exact gradients —
    --straggler-selfcheck asserts that against the all-alive step
  * XLA latency-hiding scheduler flags enabled for compute/comm overlap.
"""
from __future__ import annotations

import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--peak-lr", type=float, default=3e-3)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full-config", dest="smoke", action="store_false")
    ap.add_argument("--d-model", type=int, default=0,
                    help="override smoke width (e.g. 512 for a ~100M model)")
    ap.add_argument("--n-layers", type=int, default=0)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-shards", type=int, default=16)
    ap.add_argument("--ckpt-parity", type=int, default=4)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", default=None,
                    help="step,shard[,shard...]: simulate node failures")
    ap.add_argument("--stragglers", type=int, default=0,
                    help="s > 0: gradient-coded step tolerating s "
                         "stragglers per step (requires (s+1) | workers)")
    ap.add_argument("--coded-workers", type=int, default=8,
                    help="data-parallel workers for --stragglers "
                         "(batch must divide evenly)")
    ap.add_argument("--straggler-mode", default="random",
                    choices=["random", "bursty", "fixed"])
    ap.add_argument("--straggler-rate", type=float, default=0.5)
    ap.add_argument("--straggler-seed", type=int, default=0)
    ap.add_argument("--straggler-selfcheck", action="store_true",
                    help="assert bitwise gradient recovery vs the "
                         "all-alive step before training")
    ap.add_argument("--production", action="store_true",
                    help="use the 16x16 production mesh shardings")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    if args.production:
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=512 "
            + os.environ.get("XLA_FLAGS", ""))
    # compute/comm overlap: async collectives + latency-hiding scheduling
    os.environ.setdefault("XLA_FLAGS", "")
    os.environ["XLA_FLAGS"] += " --xla_cpu_use_thunk_runtime=true"

    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from ..ckpt import CodedCheckpointer
    from ..coding import GradientCoder
    from ..configs import get_config
    from ..data import SyntheticLM
    from ..train import (StragglerInjector, init_state,
                         make_straggler_train_step, make_train_setup,
                         make_train_step)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if args.d_model:
        cfg = dataclasses.replace(
            cfg, d_model=args.d_model, d_ff=args.d_model * 3,
            head_dim=max(args.d_model // max(cfg.n_heads, 1), 8))
    if args.n_layers:
        cfg = dataclasses.replace(cfg, n_layers=args.n_layers)

    opt, lr = make_train_setup(cfg, total_steps=args.steps, peak_lr=args.peak_lr)
    state = init_state(cfg, jax.random.PRNGKey(0), opt)
    from ..models.model import param_count
    print(f"arch={cfg.name} params={param_count(state.params):,} "
          f"devices={jax.device_count()}")

    ckpt = None
    if args.ckpt_dir:
        ckpt = CodedCheckpointer(args.ckpt_dir, args.ckpt_shards, args.ckpt_parity)
        if args.resume and ckpt.latest_step() is not None:
            s = ckpt.latest_step()
            state = ckpt.restore(s, state)
            print(f"resumed from coded checkpoint step {s}")

    fail_step, fail_shards = -1, set()
    if args.fail_at:
        parts = [int(x) for x in args.fail_at.split(",")]
        fail_step, fail_shards = parts[0], set(parts[1:])

    data = SyntheticLM(cfg.vocab, args.seq_len, args.batch)
    straggle = None
    if args.stragglers > 0:
        coder = GradientCoder(args.coded_workers, s=args.stragglers)
        if args.batch % coder.n_workers:
            raise SystemExit(f"--batch {args.batch} must be divisible by "
                             f"--coded-workers {coder.n_workers}")
        coded_fn = make_straggler_train_step(cfg, opt, coder)
        straggle = StragglerInjector.build(
            args.straggler_mode, coder, args.steps,
            rate=args.straggler_rate, seed=args.straggler_seed)
        print(f"gradient coding: {coder.n_workers} workers, "
              f"s={coder.s} tolerated, {coder.n_groups} groups, "
              f"{args.straggler_mode} stragglers "
              f"({len(straggle.plan)} worker-step straggles planned)")
        if args.straggler_selfcheck:
            b0 = data.device_batch(0)
            mask = straggle.mask(0)
            if mask.all():  # make the check exercise a real straggle
                mask[:args.stragglers] = False
            s_dead, _ = coded_fn(state, b0, mask)
            s_live, _ = coded_fn(state, b0)
            leaves_a = jax.tree.leaves(s_dead.params)
            leaves_b = jax.tree.leaves(s_live.params)
            assert all(np.array_equal(np.asarray(a), np.asarray(b))
                       for a, b in zip(leaves_a, leaves_b)), \
                "straggler step diverged from all-alive step"
            print(f"selfcheck OK: step with stragglers "
                  f"{[int(w) for w in np.flatnonzero(~mask)]} "
                  "bitwise == all-alive")

        def step_fn(st, batch, i):
            return coded_fn(st, batch, straggle.mask(i))
    else:
        base_fn = jax.jit(make_train_step(cfg, opt, args.microbatches,
                                          args.compress_grads))

        def step_fn(st, batch, i):
            return base_fn(st, batch)

    t0 = time.time()
    start = int(state.step)
    straggled = 0
    for i in range(start, args.steps):
        state, metrics = step_fn(state, data.device_batch(i), i)
        straggled += int(metrics.get("stragglers", 0))
        if ckpt and (i + 1) % args.ckpt_every == 0:
            ckpt.save(i + 1, jax.device_get(state), background=True)
        if i == fail_step:
            print(f"!! simulating failure of shards {fail_shards} at step {i}")
            ckpt.wait()
            s = ckpt.latest_step()
            state = ckpt.restore(s, state, failed_shards=fail_shards)
            print(f"   reconstructed from parity; resumed at step {s}")
        if (i + 1) % args.log_every == 0 or i == start:
            dt = (time.time() - t0) / (i - start + 1)
            print(f"step {i + 1:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(lr(jnp.int32(i))):.2e} {dt * 1e3:.0f} ms/step",
                  flush=True)
    if ckpt:
        ckpt.save(args.steps, jax.device_get(state))
        ckpt.wait()
    if straggle is not None:
        print(f"stragglers: {straggled} worker-steps decoded around "
              f"({args.straggler_mode}, s={args.stragglers})")
    print(f"done: final loss {float(metrics['loss']):.4f}")
    return state


if __name__ == "__main__":
    main()
