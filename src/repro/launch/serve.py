"""Serving launcher: batched prefill + greedy decode on a reduced config.

Demonstrates the full request path (tokenize-stub -> prefill -> KV-cached
decode); on TPU the same decode_step lowers under the production mesh (the
decode_32k / long_500k dry-run cells).

`--coded-selfcheck` additionally runs the replica's parameters through a
`repro.api.CodedSystem` session before serving: shard, RS-parity-encode
(`system.codeword` on the local kernel backend), drop R shards
(`system.fail`), reconstruct (`system.read`), and verify bitwise — the
integrity gate a coded parameter store performs on startup.  With
`--degraded` the recovery leg runs through the session's auto-replanned
decode path instead of the host-side solve: the same cached `DecodePlan` a
degraded read would execute, exercising the repair matrix + Pallas kernel
path end to end.

`--queue-demo N` drives the batched coding queue through `system.submit`:
N concurrent encode and degraded-read decode requests are submitted from
worker threads, coalesced into streamed `run_batched` plan executions
(`launch.coding_queue.CodingQueue` underneath), and every result is
verified bitwise against a direct per-request `plan.run`.

`--service N` is the multi-tenant layer (`launch.service.CodedService`):
two tenants drive pooled sessions through one shared coding queue from
concurrent clients — their same-plan encodes coalesce ACROSS sessions —
one tenant runs a degraded read mid-run, and the per-tenant serving stats
(admission, coalescing ratio, latency percentiles) are printed.

`--chaos R,SEED` is the failure-injection scenario: first a mid-schedule
leg (a `FaultInjector` kills up to R processors at random rounds of a
running repair schedule; `repair_with_faults` restarts against each
enlarged erasure set with exact C1/C2 accounting), then a serving leg
(random `fail()`s race queued encode/decode/rebuild submissions through
one `CodedSystem`, exercising the queue's superset failover), then chaos
UNDER multi-tenant load (kills racing two tenants' queued submissions
through a `CodedService`), and finally a full `rebuild` back to health —
every result self-checked bitwise against the original codeword."""
from __future__ import annotations

import argparse
import time


def _chaos_demo(max_kills: int, seed: int, n_shards: int,
                n_parity: int) -> None:
    import numpy as np

    from ..api import CodedSystem, CodeSpec
    from ..core.field import FERMAT
    from ..core.simulator import FaultInjector, RoundNetwork
    from ..recover import repair_with_faults

    max_kills = max(1, min(int(max_kills), n_parity))
    rng = np.random.default_rng(seed)
    spec = CodeSpec(kind="rs", K=n_shards, R=n_parity)
    x = FERMAT.rand((n_shards, 128), rng)
    system = CodedSystem(spec, backend="local")
    cw = system.codeword(x)

    # -- leg 1: mid-schedule kills on the round network -------------------
    first = int(rng.integers(0, spec.N))
    net = RoundNetwork(spec.N, spec.p)
    inj = FaultInjector(net)
    # small-K repair schedules run only a handful of rounds — keep the
    # injection window inside them so kills actually land mid-schedule
    kills = inj.random_kills(rng, [i for i in range(spec.N) if i != first],
                             max_kills - 1, max_round=2)
    report = repair_with_faults(spec, cw, erased=(first,), net=net)
    assert np.array_equal(report.codeword, cw), "chaos repair mismatch"
    assert net.C1 == sum(a.C1 for a in report.attempts), "C1 accounting"
    assert net.C2 == sum(a.C2 for a in report.attempts), "C2 accounting"
    print(f"chaos mid-schedule OK: kill {{{first}}} at start + injected "
          f"{kills or 'none'}; {report.restarts} restart(s) across "
          f"{len(report.attempts)} attempt(s), final |E|="
          f"{len(report.erased)}, exact C1={net.C1} C2={net.C2} (bitwise)")

    # -- leg 2: random fail()s racing queued submissions ------------------
    futs = []
    for _ in range(6 * max_kills):
        roll = rng.random()
        if roll < 0.35 and len(system.failed) < n_parity:
            alive = [i for i in range(spec.N) if i not in system.failed]
            system.fail(int(rng.choice(alive)))
        elif roll < 0.55:
            futs.append(("encode", None, system.submit("encode", x)))
        elif roll < 0.80:
            futs.append(("decode", system.failed,
                         system.submit("decode", cw)))
        else:
            futs.append(("rebuild", None, system.submit("rebuild", cw)))
    for op, pinned, fut in futs:
        got = fut.result(timeout=120)
        ref = (cw[n_shards:] if op == "encode"
               else cw[list(pinned)] if op == "decode" else cw)
        assert np.array_equal(got, ref), f"queued {op} self-check failed"
    stats = system.stats()
    healed = system.rebuild(cw)
    assert np.array_equal(healed, cw) and system.failed == (), "rebuild"
    qs = stats.get("queue")
    system.close()
    print(f"chaos serving OK: {len(futs)} queued ops under "
          f"{len(stats['failed'])} live failures "
          f"({qs.failovers if qs else 0} superset failover(s)); "
          "rebuild -> healed, all bitwise")

    # -- leg 3: chaos UNDER multi-tenant service load ---------------------
    from .service import CodedService

    with CodedService(backend="local") as svc:
        tens = []
        for t in range(2):
            name = f"tenant{t}"
            xt = FERMAT.rand((n_shards, 64), rng)
            sess = svc.session(name, spec)
            tens.append((name, sess, xt, sess.codeword(xt)))
        sfuts = []
        for _ in range(12 * max_kills):
            name, sess, xt, cwt = tens[int(rng.integers(2))]
            roll = rng.random()
            if roll < 0.3 and len(sess.failed) < n_parity:
                alive = [i for i in range(spec.N) if i not in sess.failed]
                sess.fail(int(rng.choice(alive)))
            elif roll < 0.6:
                sfuts.append(("encode", None, cwt,
                              svc.submit(name, spec, "encode", xt)))
            elif roll < 0.85:
                sfuts.append(("decode", sess.failed, cwt,
                              svc.submit(name, spec, "decode", cwt)))
            else:
                sfuts.append(("rebuild", None, cwt,
                              svc.submit(name, spec, "rebuild", cwt)))
        for op, pinned, cwt, fut in sfuts:
            got = fut.result(timeout=120)
            ref = (cwt[n_shards:] if op == "encode"
                   else cwt[list(pinned)] if op == "decode" else cwt)
            assert np.array_equal(got, ref), f"service {op} self-check"
        sstats = svc.stats()["service"]
        print(f"chaos service OK: {len(sfuts)} ops across 2 tenants' "
              f"sessions under live kills (coalescing "
              f"{sstats['coalescing_ratio']:.2f}x, "
              f"{sstats['failovers']} failover(s)), all bitwise")


def _service_demo(n_requests: int, n_shards: int, n_parity: int) -> None:
    """Multi-tenant serving demo: two tenants drive one `CodedService`
    from concurrent clients — same spec, so their encodes coalesce across
    sessions — one tenant degraded mid-run; everything verified bitwise
    and the per-tenant serving stats printed (`service.describe()`)."""
    import threading

    import numpy as np

    from ..api import CodedSystem, CodeSpec
    from ..core.field import FERMAT
    from .service import CodedService, TenantQuota

    spec = CodeSpec(kind="rs", K=n_shards, R=n_parity)
    ref = CodedSystem(spec, backend="local")
    with CodedService(backend="local") as svc:
        svc.set_quota("acme", TenantQuota(max_inflight_ops=32, weight=2.0))
        futs: list[tuple[np.ndarray, object]] = []
        lock = threading.Lock()

        def client(tenant: str, seed: int) -> None:
            r = np.random.default_rng(seed)
            for _ in range(n_requests):
                x = FERMAT.rand((n_shards, 64), r)
                f = svc.submit(tenant, spec, "encode", x, tag=f"{tenant}/v0")
                with lock:
                    futs.append((ref.codeword(x)[n_shards:], f))

        threads = [threading.Thread(target=client, args=(t, 50 + i))
                   for i, t in enumerate(["acme", "zeta"])]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for want, fut in futs:
            assert np.array_equal(fut.result(timeout=120), want), \
                "service encode self-check failed"
        # one tenant degrades; its decode rides the same shared queue
        x = FERMAT.rand((n_shards, 64), np.random.default_rng(99))
        cw = ref.codeword(x)
        svc.session("zeta", spec).fail(range(n_parity))
        got = svc.submit("zeta", spec, "decode", cw).result(timeout=120)
        assert np.array_equal(got, cw[: n_parity]), "degraded read failed"
        print(svc.describe())
        print(f"service demo OK: {len(futs)} encodes from 2 tenants + 1 "
              "degraded read, all bitwise")


def _queue_demo(n_requests: int, n_shards: int, n_parity: int) -> None:
    import threading

    import numpy as np

    from ..api import CodedSystem, CodeSpec
    from ..core.field import FERMAT

    # one session handle: erasure state + both planners + the coalescing
    # queue behind system.submit (previously hand-wired plans + CodingQueue)
    system = CodedSystem(CodeSpec(kind="rs", K=n_shards, R=n_parity),
                         backend="local")
    system.fail(range(n_parity))  # worst case: first R data shards lost
    enc_plan, dec_plan = system.encode_plan, system.decode_plan

    futs: list[tuple[str, np.ndarray, object]] = []
    lock = threading.Lock()

    def client(seed: int) -> None:
        r = np.random.default_rng(seed)
        x = FERMAT.rand((n_shards, int(r.integers(64, 512))), r)
        fe = system.submit("encode", x)
        full = system.codeword(x)
        v = full[list(system.kept)]
        fd = system.submit("decode", v)
        with lock:
            futs.append(("encode", x, fe))
            futs.append(("decode", v, fd))

    threads = [threading.Thread(target=client, args=(1000 + i,))
               for i in range(n_requests)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for op, payload, fut in futs:
        got = fut.result(timeout=120)
        ref = (enc_plan if op == "encode" else dec_plan).run(payload)
        assert np.array_equal(got, ref), f"queued {op} != direct run"
    stats = system.stats()
    system.close()
    s = stats["queue"]
    print(f"coding queue OK: {s.requests} requests in {s.batches} batched "
          f"plan executions (max coalesced {s.max_coalesced}); "
          f"encode path: {enc_plan.local_impl}")


def _coded_selfcheck(params, n_shards: int, n_parity: int,
                     degraded: bool = False) -> None:
    import numpy as np

    from ..api import CodedSystem, CodeSpec
    from ..ckpt.checkpoint import tree_to_bytes
    from ..core.field import FERMAT, bytes_to_symbols

    if n_shards % n_parity:
        raise SystemExit(
            f"--coded-parity must divide --coded-shards (Remark 4): "
            f"got {n_shards} shards, {n_parity} parity")
    raw, _ = tree_to_bytes(params)
    sym = bytes_to_symbols(raw)
    L = -(-sym.size // n_shards)
    shards = np.concatenate(
        [sym, np.zeros(n_shards * L - sym.size, np.int64)]
    ).reshape(n_shards, L)

    system = CodedSystem(CodeSpec(kind="rs", K=n_shards, R=n_parity),
                         backend="local")
    full = system.codeword(shards)  # [shards | parity]

    # worst case: the first R data shards are lost; recover from parity
    erased = tuple(range(n_parity))
    if degraded:
        system.fail(erased)
        print(system.describe())
        repaired = system.decode(full)
        assert np.array_equal(repaired, shards[: n_parity]), \
            "degraded self-check failed (repair)"
        rec = system.read(full)
        system.heal()
    else:
        from ..core.parity import reconstruct

        print(system.describe())
        kept = np.arange(n_parity, n_shards + n_parity)
        rec = reconstruct(FERMAT, system.encode_plan.sgrs, kept, full[kept])
    assert np.array_equal(rec, shards), "coded self-check failed"
    mode = "degraded DecodePlan" if degraded else "host solve"
    print(f"coded self-check OK ({mode}): {n_shards} param shards + "
          f"{n_parity} parity, recovered {n_parity} lost shards bitwise")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2_780m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--coded-selfcheck", action="store_true",
                    help="verify params survive R lost shards via RS parity")
    ap.add_argument("--degraded", action="store_true",
                    help="recover the self-check erasures via the decode "
                         "subsystem (DecodePlan) instead of the host solve")
    ap.add_argument("--coded-shards", type=int, default=8)
    ap.add_argument("--coded-parity", type=int, default=2)
    ap.add_argument("--queue-demo", type=int, default=0, metavar="N",
                    help="drive the batched coding queue with N concurrent "
                         "encode+decode clients and verify bitwise")
    ap.add_argument("--service", type=int, default=0, metavar="N",
                    help="multi-tenant CodedService demo: two tenants x N "
                         "coalescing encodes + a degraded read, verified "
                         "bitwise, per-tenant stats printed")
    ap.add_argument("--chaos", default=None, metavar="R,SEED",
                    help="failure-injection scenario: kill up to R "
                         "processors at random rounds while serving queued "
                         "encodes/decodes/rebuilds, self-check bitwise")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="capture a Chrome trace-event timeline of the whole "
                         "run (simulator rounds, stream pipeline, queue/"
                         "service ops, kernels) — load in ui.perfetto.dev")
    ap.add_argument("--metrics", action="store_true",
                    help="dump the unified metrics registry (text "
                         "exposition format) at exit")
    args = ap.parse_args()
    if args.degraded and not args.coded_selfcheck:
        ap.error("--degraded modifies the self-check; pass --coded-selfcheck")
    tracer = None
    if args.trace:
        from ..obs import trace as _trace

        tracer = _trace.install(_trace.Tracer())
    try:
        _run(args, ap)
    finally:
        if tracer is not None:
            from ..obs import trace as _trace

            _trace.uninstall(tracer)
            print(f"trace   : {len(tracer)} events -> "
                  f"{tracer.save(args.trace)}")
        if args.metrics:
            from ..obs.metrics import REGISTRY

            print(REGISTRY.render_text(), end="")


def _run(args, ap):
    if args.chaos:
        try:
            kills, seed = (int(t) for t in args.chaos.split(","))
        except ValueError:
            ap.error("--chaos expects R,SEED (e.g. --chaos 3,7)")
        _chaos_demo(kills, seed, args.coded_shards, args.coded_parity)
    if args.queue_demo:
        _queue_demo(args.queue_demo, args.coded_shards, args.coded_parity)
    if args.service:
        _service_demo(args.service, args.coded_shards, args.coded_parity)

    import jax
    import jax.numpy as jnp

    from ..configs import get_config
    from ..models import model as M

    cfg = get_config(args.arch).smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    if args.coded_selfcheck:
        _coded_selfcheck(jax.device_get(params), args.coded_shards,
                         args.coded_parity, degraded=args.degraded)
    B = args.batch
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, args.prompt_len),
                                0, cfg.vocab)
    max_len = args.prompt_len + args.gen_len + 1

    decode = jax.jit(
        lambda p, tok, pos, cache: M.decode_step(cfg, p, tok, pos, cache))
    cache = M.init_cache(cfg, B, max_len)
    tok = prompt[:, 0]
    out = [tok]
    t0 = time.time()
    for t in range(args.prompt_len + args.gen_len - 1):
        logits, cache = decode(params, tok, jnp.int32(t), cache)
        tok = (prompt[:, t + 1] if t + 1 < args.prompt_len
               else jnp.argmax(logits, -1).astype(jnp.int32))
        out.append(tok)
    toks = jnp.stack(out, 1)
    dt = (time.time() - t0) / (toks.shape[1] - 1) * 1e3
    print(f"arch={cfg.name} batch={B} generated {args.gen_len} tokens/seq "
          f"@ {dt:.1f} ms/token (CPU, reduced config)")
    print("sample token ids:", toks[0, args.prompt_len:args.prompt_len + 12].tolist())


if __name__ == "__main__":
    main()
