"""Serving launcher: batched prefill + greedy decode on a reduced config.

Demonstrates the full request path (tokenize-stub -> prefill -> KV-cached
decode); on TPU the same decode_step lowers under the production mesh (the
decode_32k / long_500k dry-run cells)."""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2_780m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from ..configs import get_config
    from ..models import model as M

    cfg = get_config(args.arch).smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B = args.batch
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, args.prompt_len),
                                0, cfg.vocab)
    max_len = args.prompt_len + args.gen_len + 1

    decode = jax.jit(
        lambda p, tok, pos, cache: M.decode_step(cfg, p, tok, pos, cache))
    cache = M.init_cache(cfg, B, max_len)
    tok = prompt[:, 0]
    out = [tok]
    t0 = time.time()
    for t in range(args.prompt_len + args.gen_len - 1):
        logits, cache = decode(params, tok, jnp.int32(t), cache)
        tok = (prompt[:, t + 1] if t + 1 < args.prompt_len
               else jnp.argmax(logits, -1).astype(jnp.int32))
        out.append(tok)
    toks = jnp.stack(out, 1)
    dt = (time.time() - t0) / (toks.shape[1] - 1) * 1e3
    print(f"arch={cfg.name} batch={B} generated {args.gen_len} tokens/seq "
          f"@ {dt:.1f} ms/token (CPU, reduced config)")
    print("sample token ids:", toks[0, args.prompt_len:args.prompt_len + 12].tolist())


if __name__ == "__main__":
    main()
