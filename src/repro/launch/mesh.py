"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
--xla_force_host_platform_device_count *before* any jax initialization.

Hardware model (roofline constants): TPU v5e-class chip —
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import jax

PEAK_FLOPS = 197e12     # bf16 per chip
HBM_BW = 819e9          # bytes/s per chip
ICI_BW = 50e9           # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 4, model: int = 2):
    """Small mesh for multi-device tests on forced host devices."""
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict[str, int]:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    sizes.setdefault("pod", 1)
    return sizes
