"""Multi-tenant admission control and per-tenant serving statistics.

A serving replica fronts many independent tenants, each driving many coded
volumes; without admission control one tenant's burst starves everyone and
an unbounded queue turns overload into silent latency collapse.  This
module is the policy layer `launch.service.CodedService` enforces:

  * `TenantQuota` — per-tenant ceilings on in-flight operations and
    in-flight payload bytes, plus a fair-share `weight`.
  * `AdmissionController` — a single gate every submission passes before
    it may enter the coding queue.  Admission is bounded both globally
    (`max_ops` / `max_bytes` across all tenants) and per tenant (the
    quota); a submission that does not fit either *blocks* until capacity
    frees (bounded backpressure, optional timeout) or — with
    ``block=False`` — fails immediately with `QueueFullError`.  Nothing is
    ever silently dropped: every acquire either succeeds or raises.
  * `ServiceStats` — one tenant's (or one tag's) rolling serving counters:
    submitted / completed / failed / rejected ops, in-flight gauges,
    coalescing group sizes, queue failovers, and a bounded latency
    reservoir answering p50/p99/p999.

Fair scheduling: when several tenants are *waiting* for admission, slots
are not granted in raw arrival order.  Waiters are granted per-tenant
FIFO, but across tenants the next grant goes to the eligible tenant with
the smallest weight-normalized in-flight load (``inflight_ops / weight``)
— a deficit-style weighted fair share, so a tenant that already holds
many slots cannot lock out a light tenant behind it, while arrival order
breaks ties deterministically.
"""
from __future__ import annotations

import math
import threading
from collections import deque
from dataclasses import dataclass, field as dc_field


class QueueFullError(RuntimeError):
    """Admission refused: the request does not fit the tenant's quota or
    the service's global in-flight bounds (and the caller asked not to
    block, or its wait timed out).  Always loud — the service never
    silently drops a submission."""


@dataclass(frozen=True)
class TenantQuota:
    """Per-tenant admission ceilings.

    max_inflight_ops   — operations admitted but not yet resolved
    max_inflight_bytes — sum of admitted payload bytes in flight; one
                         oversized payload is still admitted when the
                         tenant has nothing in flight (it runs alone
                         rather than deadlocking)
    weight             — fair-share weight for contended admission: a
                         tenant with weight 2 is allowed twice the
                         in-flight load of a weight-1 tenant before it
                         loses grant priority
    """

    max_inflight_ops: int = 64
    max_inflight_bytes: int = 1 << 28
    weight: float = 1.0

    def __post_init__(self):
        if self.max_inflight_ops < 1:
            raise ValueError("max_inflight_ops must be >= 1")
        if self.max_inflight_bytes < 1:
            raise ValueError("max_inflight_bytes must be >= 1")
        if not self.weight > 0:
            raise ValueError("weight must be > 0")


@dataclass
class _Waiter:
    tenant: str
    nbytes: int
    seq: int
    granted: bool = False
    abandoned: bool = False


class AdmissionController:
    """Blocking/bounded admission gate over per-tenant + global budgets.

    `acquire(tenant, nbytes)` blocks until the op fits (or raises
    `QueueFullError` with ``block=False`` / on timeout); `release` frees
    the slot and wakes the fairest eligible waiter.  See the module
    docstring for the fairness rule.
    """

    def __init__(self, *, max_ops: int = 1024, max_bytes: int = 1 << 31,
                 default_quota: TenantQuota | None = None):
        if max_ops < 1 or max_bytes < 1:
            raise ValueError("global max_ops/max_bytes must be >= 1")
        self.max_ops = max_ops
        self.max_bytes = max_bytes
        self._default = default_quota or TenantQuota()
        self._quotas: dict[str, TenantQuota] = {}
        self._ops: dict[str, int] = {}
        self._bytes: dict[str, int] = {}
        self._total_ops = 0
        self._total_bytes = 0
        self._waiters: deque[_Waiter] = deque()
        self._seq = 0
        self._cv = threading.Condition()

    # -- quotas --------------------------------------------------------------
    def quota(self, tenant: str) -> TenantQuota:
        return self._quotas.get(tenant, self._default)

    def set_quota(self, tenant: str, quota: TenantQuota) -> None:
        with self._cv:
            self._quotas[tenant] = quota
            self._grant_waiters()
            self._cv.notify_all()

    # -- introspection -------------------------------------------------------
    def inflight(self, tenant: str | None = None) -> tuple[int, int]:
        """(ops, bytes) currently admitted — for `tenant`, or globally."""
        with self._cv:
            if tenant is None:
                return self._total_ops, self._total_bytes
            return self._ops.get(tenant, 0), self._bytes.get(tenant, 0)

    @property
    def waiting(self) -> int:
        with self._cv:
            return sum(1 for w in self._waiters if not w.abandoned)

    # -- the gate ------------------------------------------------------------
    def _refusal(self, tenant: str, nbytes: int) -> str | None:
        """Why (tenant, nbytes) does not fit right now, or None if it
        does.  Byte budgets admit one oversized payload when the relevant
        byte ledger is empty — it runs alone instead of deadlocking."""
        q = self.quota(tenant)
        t_ops = self._ops.get(tenant, 0)
        t_bytes = self._bytes.get(tenant, 0)
        if self._total_ops >= self.max_ops:
            return (f"global in-flight ops at cap ({self.max_ops})")
        if t_ops >= q.max_inflight_ops:
            return (f"tenant {tenant!r} in-flight ops at quota "
                    f"({q.max_inflight_ops})")
        if self._total_bytes + nbytes > self.max_bytes and self._total_bytes:
            return (f"global in-flight bytes at cap ({self.max_bytes})")
        if t_bytes + nbytes > q.max_inflight_bytes and t_bytes:
            return (f"tenant {tenant!r} in-flight bytes at quota "
                    f"({q.max_inflight_bytes})")
        return None

    def _admit(self, tenant: str, nbytes: int) -> None:
        self._ops[tenant] = self._ops.get(tenant, 0) + 1
        self._bytes[tenant] = self._bytes.get(tenant, 0) + nbytes
        self._total_ops += 1
        self._total_bytes += nbytes

    def _grant_waiters(self) -> None:
        """Grant every waiter that now fits, fairest-first (must hold the
        lock).  Eligible set: the FIRST (FIFO) live waiter of each tenant
        that `_refusal` admits; among those, the grant goes to the tenant
        with the smallest weight-normalized in-flight ops, arrival order
        breaking ties."""
        while True:
            heads: dict[str, _Waiter] = {}
            for w in self._waiters:
                if not w.abandoned and not w.granted and w.tenant not in heads:
                    heads[w.tenant] = w
            eligible = [w for w in heads.values()
                        if self._refusal(w.tenant, w.nbytes) is None]
            if not eligible:
                return
            w = min(eligible, key=lambda w: (
                self._ops.get(w.tenant, 0) / self.quota(w.tenant).weight,
                w.seq))
            w.granted = True
            self._admit(w.tenant, w.nbytes)
            self._waiters.remove(w)

    def acquire(self, tenant: str, nbytes: int = 0, *, block: bool = True,
                timeout: float | None = None) -> None:
        """Admit one operation of `nbytes` payload for `tenant`.

        Blocks (bounded backpressure) until the op fits both the tenant's
        quota and the global caps; with ``block=False`` or an expired
        `timeout` raises `QueueFullError` instead.  Per-tenant FIFO: an op
        never jumps ahead of its own tenant's queued waiters.
        """
        with self._cv:
            has_waiters = any(w.tenant == tenant and not w.abandoned
                              for w in self._waiters)
            refusal = self._refusal(tenant, nbytes)
            if refusal is None and not has_waiters:
                self._admit(tenant, nbytes)
                return
            if not block:
                raise QueueFullError(
                    refusal or f"tenant {tenant!r} has queued waiters")
            waiter = _Waiter(tenant, nbytes, self._seq)
            self._seq += 1
            self._waiters.append(waiter)
            self._grant_waiters()
            if not self._cv.wait_for(lambda: waiter.granted, timeout):
                waiter.abandoned = True
                self._waiters.remove(waiter)
                raise QueueFullError(
                    f"admission wait for tenant {tenant!r} timed out after "
                    f"{timeout}s ({self._refusal(tenant, nbytes) or 'contended'})")

    def release(self, tenant: str, nbytes: int = 0) -> None:
        with self._cv:
            self._ops[tenant] = max(0, self._ops.get(tenant, 0) - 1)
            self._bytes[tenant] = max(0, self._bytes.get(tenant, 0) - nbytes)
            self._total_ops = max(0, self._total_ops - 1)
            self._total_bytes = max(0, self._total_bytes - nbytes)
            self._grant_waiters()
            self._cv.notify_all()


# ---------------------------------------------------------------------------
# per-tenant / per-tag serving statistics
# ---------------------------------------------------------------------------

def percentile(xs, frac: float) -> float:
    """Nearest-rank percentile (frac in [0, 1]) of a sequence; NaN when
    empty.  p999 of a small sample is simply its max — honest, if noisy."""
    if not xs:
        return float("nan")
    s = sorted(xs)
    return s[min(len(s) - 1, max(0, math.ceil(frac * len(s)) - 1))]


@dataclass
class ServiceStats:
    """Rolling serving counters for one tenant (or one request tag).

    Mutated from submit threads, the queue worker, and future
    done-callbacks — every mutator takes the internal lock; `snapshot()`
    returns a plain immutable dict (percentiles computed on demand from a
    bounded latency reservoir of the most recent `reservoir` ops).
    """

    name: str
    reservoir: int = 65536
    submitted: int = 0
    completed: int = 0
    failed: int = 0       # futures that resolved with an exception
    rejected: int = 0     # admissions refused with QueueFullError
    failovers: int = 0    # ops replanned onto a superset erasure pattern
    inflight_ops: int = 0
    inflight_bytes: int = 0
    executed: int = 0      # ops with coalescing info (resolved by the queue)
    coalesced_ops: int = 0  # sum of batch group sizes over executed ops
    lat_recorded: int = 0  # latency samples ever recorded (incl. evicted)
    _lat_us: deque = dc_field(default_factory=deque, repr=False)
    _lock: threading.Lock = dc_field(default_factory=threading.Lock,
                                     repr=False)

    def __post_init__(self):
        # bounded reservoir: the deque trims itself (maxlen) instead of a
        # hand-rolled popleft loop on every record
        self._lat_us = deque(self._lat_us, maxlen=self.reservoir)

    def record_submitted(self, nbytes: int) -> None:
        with self._lock:
            self.submitted += 1
            self.inflight_ops += 1
            self.inflight_bytes += nbytes

    def record_rejected(self) -> None:
        with self._lock:
            self.rejected += 1

    def record_executed(self, group_n: int, failover: bool) -> None:
        with self._lock:
            self.executed += 1
            self.coalesced_ops += max(1, int(group_n))
            if failover:
                self.failovers += 1

    def record_done(self, latency_us: float, nbytes: int, ok: bool) -> None:
        with self._lock:
            self.inflight_ops = max(0, self.inflight_ops - 1)
            self.inflight_bytes = max(0, self.inflight_bytes - nbytes)
            if ok:
                self.completed += 1
            else:
                self.failed += 1
            self._lat_us.append(latency_us)
            self.lat_recorded += 1

    @property
    def coalescing_ratio(self) -> float:
        """Mean batch group size over this name's executed ops — 1.0 means
        every op ran alone; >1 means cross-request (and, through the
        service's shared queue, cross-session) coalescing is working."""
        with self._lock:
            return (self.coalesced_ops / self.executed) if self.executed \
                else float("nan")

    def latencies_us(self) -> list[float]:
        with self._lock:
            return list(self._lat_us)

    def snapshot(self) -> dict:
        with self._lock:
            lat = list(self._lat_us)
            out = {
                "name": self.name,
                "submitted": self.submitted,
                "completed": self.completed,
                "failed": self.failed,
                "rejected": self.rejected,
                "failovers": self.failovers,
                "inflight_ops": self.inflight_ops,
                "inflight_bytes": self.inflight_bytes,
                "executed": self.executed,
                "coalescing_ratio": (self.coalesced_ops / self.executed
                                     if self.executed else float("nan")),
                # reservoir visibility: percentiles below cover only the
                # most recent `lat_samples`; `lat_dropped` older samples
                # were evicted (nonzero => truncated percentiles)
                "lat_samples": len(lat),
                "lat_dropped": self.lat_recorded - len(lat),
            }
        out["p50_us"] = percentile(lat, 0.50)
        out["p99_us"] = percentile(lat, 0.99)
        out["p999_us"] = percentile(lat, 0.999)
        return out
