"""Mini HLO cost model with *loop-trip scaling*.

XLA's HloCostAnalysis (what `compiled.cost_analysis()` reports) counts each
`while` body ONCE — a 61-layer `lax.scan` therefore under-reports FLOPs by
~61x (verified empirically; see EXPERIMENTS.md §Dry-run notes).  For the
roofline we parse the compiled HLO text ourselves:

  * per-computation census: dot FLOPs (from result shape x contracted dims),
    elementwise/reduce byte traffic, collective bytes with ring transfer
    factors (all-gather/reduce-scatter (n-1)/n, all-reduce 2(n-1)/n,
    collective-permute 1)
  * call graph: `while` ops multiply their body+condition costs by the trip
    count recovered from the canonical scan pattern (condition compares the
    induction variable against a `constant(N)`); fusions/calls add their
    callee costs once
  * totals roll up from the entry computation.

Numbers are per-DEVICE (the module is the SPMD-partitioned program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
}

_SHAPE_TOKEN = re.compile(r"(\w+)\[([\d,]*)\](?:\{[^}]*\})?")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?)\s*([\w\-]+)\((.*)$")
_COLLECTIVES = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_TOKEN.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(shape_str: str) -> int:
    m = _SHAPE_TOKEN.search(shape_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0            # memory traffic proxy
    coll_bytes: float = 0.0       # weighted collective bytes
    coll_by_kind: dict = field(default_factory=dict)
    calls: list = field(default_factory=list)   # (callee, kind)
    shapes: dict = field(default_factory=dict)  # instr name -> shape str


_COMMENT = re.compile(r"/\*.*?\*/")


def _strip_comments(line: str) -> str:
    return _COMMENT.sub("", line)


def _header_name(line: str) -> str | None:
    """Computation header: '%name (params...) -> shape {' (no '=')."""
    line = _strip_comments(line)
    if "=" in line or "->" not in line or not line.rstrip().endswith("{"):
        return None
    m = re.match(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
    return m.group(1) if m else None


def parse_hlo(text: str) -> dict[str, CompCost]:
    comps: dict[str, CompCost] = {}
    cur: CompCost | None = None
    cur_name = None
    for raw in text.splitlines():
        line = _strip_comments(raw.rstrip())
        hname = _header_name(line)
        if hname:
            cur_name = hname
            cur = comps.setdefault(cur_name, CompCost())
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, shape_str, op, rest = m.groups()
        cur.shapes[name] = shape_str
        out_bytes = _shape_bytes(shape_str)
        # HBM-traffic proxy: skip bookkeeping ops; DUS is in-place (traffic =
        # 2x the updated slice, not the full buffer)
        if op in ("tuple", "get-tuple-element", "bitcast", "parameter",
                  "constant", "iota", "after-all", "partition-id"):
            pass
        elif op == "dynamic-update-slice":
            ops_names = re.findall(r"%([\w.\-]+)", rest)
            upd = _shape_bytes(cur.shapes.get(ops_names[1], "")) if len(ops_names) > 1 else 0
            cur.bytes += 2 * upd
        else:
            cur.bytes += out_bytes  # output write (reads ~ prior writes)

        if op in ("dot", "dot-general") or op == "convolution":
            flops = _dot_flops(shape_str, rest, cur.shapes)
            cur.flops += flops
        elif op in ("add", "multiply", "subtract", "divide", "maximum",
                    "minimum", "exponential", "tanh", "rsqrt", "power",
                    "log", "negate", "compare", "select"):
            cur.flops += _shape_elems(shape_str)
        elif op == "reduce":
            cur.flops += _shape_elems(shape_str)  # coarse

        for kind, factor in _COLLECTIVES.items():
            if op == kind or op == f"{kind}-start":
                n = _group_size(line)
                w = out_bytes * (factor * (n - 1) / n if n > 1 else
                                 (1.0 if kind == "collective-permute" else 0.0))
                if kind == "collective-permute":
                    w = out_bytes
                # XLA-CPU FloatNormalization promotes bf16 reductions to f32
                # (to_apply=%..._promoted); on the TPU target these collectives
                # run in bf16 — halve to model the real wire traffic.
                if "promoted" in line and kind in ("all-reduce", "reduce-scatter"):
                    w *= 0.5
                cur.coll_bytes += w
                k = cur.coll_by_kind.setdefault(kind, [0, 0.0])
                k[0] += 1
                k[1] += w
                break

        if op == "while":
            body = _attr(line, "body")
            cond = _attr(line, "condition")
            if body:
                cur.calls.append((body, "while", cond, name))
        elif op in ("call", "fusion"):
            callee = _attr(line, "calls") or _attr(line, "to_apply")
            if callee:
                cur.calls.append((callee, "call", None, name))
        elif op in ("reduce", "map", "sort", "scatter", "select-and-scatter",
                    "reduce-window", "custom-call", "conditional"):
            callee = _attr(line, "to_apply")
            if callee:
                cur.calls.append((callee, "call", None, name))
    return comps


def _attr(line: str, key: str) -> str | None:
    m = re.search(rf"{key}=%?([\w.\-]+)", line)
    return m.group(1) if m else None


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return len(m.group(1).split(","))
    return 2  # unknown: conservative


def _dot_flops(result_shape: str, rest: str, shapes: dict) -> float:
    """2 * result_elems * contracted_size."""
    res = _shape_elems(result_shape)
    # operand 0 name: only tokens that name parsed instructions (dtype/layout
    # tokens like 'f32' would otherwise match when the '%' sigil is optional)
    cand = re.findall(r"%?([\w.\-]+)", rest.split(")", 1)[0])
    ops = [t for t in cand if t in shapes]
    contracted = 1
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
    if mc and ops:
        lhs_shape = shapes.get(ops[0], "")
        mt = _SHAPE_TOKEN.search(lhs_shape)
        if mt:
            dims = [int(d) for d in mt.group(2).split(",") if d]
            for ci in mc.group(1).split(","):
                if ci and int(ci) < len(dims):
                    contracted *= dims[int(ci)]
    return 2.0 * res * max(contracted, 1)


def analyze(text: str, entry_hint: str | None = None) -> dict:
    comps = parse_hlo(text)
    # constants for trip counts: quick scan of the raw text per computation
    trip_consts: dict[str, int] = {}
    cur = None
    for line in text.splitlines():
        hname = _header_name(line)
        if hname:
            cur = hname
            continue
        if cur and "constant(" in line and "s32[]" in line:
            m = re.search(r"constant\((\d+)\)", line)
            if m:
                trip_consts[cur] = max(trip_consts.get(cur, 1), int(m.group(1)))

    memo: dict[str, tuple] = {}

    def merge_kinds(dst: dict, src: dict, mult: float) -> None:
        for k, v in src.items():
            e = dst.setdefault(k, [0, 0.0])
            e[0] += v[0] * mult
            e[1] += v[1] * mult

    def roll(name: str, depth=0) -> tuple:
        """(flops, bytes, coll_bytes, kinds) with loops scaled by trips.

        Fusion/call bodies contribute flops + collectives but NOT bytes —
        the caller's fusion instruction already accounts for the kernel's
        HBM in/out traffic; while bodies contribute everything x trips.
        """
        if name in memo:
            return memo[name]
        if name not in comps or depth > 60:
            return (0.0, 0.0, 0.0, {})
        memo[name] = (0.0, 0.0, 0.0, {})  # cycle guard
        c = comps[name]
        fl, by, cb = c.flops, c.bytes, c.coll_bytes
        kinds = {k: list(v) for k, v in c.coll_by_kind.items()}
        for call in c.calls:
            callee, kind = call[0], call[1]
            cf, cby, ccb, ck = roll(callee, depth + 1)
            if kind == "while":
                cond = call[2]
                mult = trip_consts.get(cond, trip_consts.get(callee, 1))
                cf2, cby2, ccb2, ck2 = roll(cond, depth + 1)
                fl += (cf + cf2) * mult
                by += (cby + cby2) * mult
                cb += (ccb + ccb2) * mult
                merge_kinds(kinds, ck, mult)
                merge_kinds(kinds, ck2, mult)
            else:
                fl += cf
                cb += ccb
                merge_kinds(kinds, ck, 1)
        memo[name] = (fl, by, cb, kinds)
        return memo[name]

    # entry = computation named like the module or the last 'ENTRY'
    entry = None
    for line in text.splitlines():
        if line.strip().startswith("ENTRY"):
            m = re.match(r"^\s*ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
    if entry is None:
        entry = max(comps, key=lambda n: comps[n].flops, default=None)
    fl, by, cb, kinds = roll(entry) if entry else (0, 0, 0, {})
    return {
        "entry": entry,
        "flops": fl,
        "bytes": by,
        "collective_bytes": cb,
        "collectives_by_kind": {k: {"count": v[0], "weighted_bytes": v[1]}
                                for k, v in kinds.items()},
        "n_computations": len(comps),
    }
