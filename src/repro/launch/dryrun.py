import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces (into --out JSON, one file per cell so runs are
resumable):
  * memory_analysis()  — per-device bytes: proves the cell fits HBM
  * cost_analysis()    — HLO FLOPs / bytes accessed for §Roofline
  * collective byte census parsed from the compiled HLO
  * roofline terms (compute / memory / collective seconds) + dominant term
  * MODEL_FLOPS = 6*N_active*tokens (train) or 2*N_active*tokens (fwd-only)
    and the usefulness ratio MODEL_FLOPS / HLO_FLOPs.

Usage:
  python -m repro.launch.dryrun --arch qwen3_14b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out-dir results/dryrun
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCH_IDS, cell_applicable, get_config, get_shape
from ..data.pipeline import make_batch_specs
from ..dist import sharding as shd
from ..dist.ctx import activation_sharding
from ..models import model as M
from ..models.config import ArchConfig, ShapeConfig
from ..train.state import TrainState, abstract_state, make_train_setup
from ..train.train_loop import make_train_step
from .hlo_cost import analyze as hlo_analyze
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS, make_production_mesh, mesh_axis_sizes


# ---------------------------------------------------------------------------
# analytic model FLOPs (the "useful work" yardstick)
# ---------------------------------------------------------------------------

def active_params(cfg: ArchConfig) -> tuple[int, int]:
    """(total, active) parameter counts from the config arithmetic."""
    D, V, L = cfg.d_model, cfg.vocab, cfg.n_layers
    hd, H, KV = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    emb = V * D * (1 if cfg.tie_embeddings else 2)
    attn = D * hd * (H + 2 * KV) + H * hd * D if H else 0
    per_layer_dense = attn
    if cfg.family == "ssm":
        DI, N, SH = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        per_layer_dense = D * (2 * DI + 2 * N + SH) + DI * D
        ffn_total = ffn_active = 0
    elif cfg.family == "hybrid":
        DI, N, SH = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        per_layer_dense += D * (2 * DI + 2 * N + SH) + DI * D
        ffn_total = ffn_active = 3 * D * cfg.d_ff
    elif cfg.n_experts:
        ffn_total = cfg.n_experts * 3 * D * cfg.d_ff + D * cfg.n_experts
        ffn_active = (cfg.top_k + cfg.n_shared_experts) * 3 * D * cfg.d_ff
    else:
        ffn_total = ffn_active = 3 * D * cfg.d_ff
    enc = cfg.n_enc_layers * (attn + 3 * D * cfg.d_ff) if cfg.n_enc_layers else 0
    total = emb + L * (per_layer_dense + ffn_total) + enc
    active = emb + L * (per_layer_dense + ffn_active) + enc
    return total, active


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    _, active = active_params(cfg)
    # PaLM-style convention: matmul params = non-embedding + the unembed
    # projection (a real 2*V*D matmul per token); the embed gather is free.
    non_emb = active - cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    non_emb = non_emb + cfg.vocab * cfg.d_model
    if shape.is_train:
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * non_emb * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * non_emb * tokens
    # decode: one token per sequence + KV attention reads (flops ~ 2*N*B)
    return 2.0 * non_emb * shape.global_batch


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh, multi_pod: bool):
    """Returns (fn, example_args, in_shardings, out_shardings)."""
    sizes = mesh_axis_sizes(mesh)
    ns = lambda spec: jax.tree.map(lambda s: NamedSharding(mesh, s), spec)

    if shape.is_train:
        opt, _ = make_train_setup(cfg)
        step = make_train_step(cfg, opt, microbatches=1)
        state = abstract_state(cfg, opt)
        batch = make_batch_specs(cfg, shape)
        pspec = shd.param_specs(cfg, state.params, sizes, multi_pod)
        ospec = shd.opt_state_specs(cfg, state.params, state.opt_state, sizes, multi_pod)
        sspec = TrainState(P(), pspec, ospec)
        bspec = shd.batch_specs(cfg, batch, sizes, multi_pod)
        in_sh = (ns(sspec), ns(bspec))
        out_sh = (ns(sspec), ns(jax.tree.map(lambda *_: P(), {"loss": 0, "grad_norm": 0, "lr_step": 0})))
        return step, (state, batch), in_sh, out_sh

    params = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    pspec = shd.param_specs(cfg, params, sizes, multi_pod)
    if shape.kind == "prefill":
        batch = make_batch_specs(cfg, shape)
        bspec = shd.batch_specs(cfg, batch, sizes, multi_pod)

        def prefill(p, b):
            return M.forward(cfg, p, b)

        logits_spec = shd.batch_specs(
            cfg, jax.eval_shape(prefill, params, batch), sizes, multi_pod)
        return prefill, (params, batch), (ns(pspec), ns(bspec)), ns(logits_spec)

    # decode
    B = shape.global_batch
    enc_shape = None
    if cfg.family == "encdec":
        enc_shape = jax.ShapeDtypeStruct((B, cfg.n_frames, cfg.d_model), jnp.bfloat16)
    cache = jax.eval_shape(
        lambda: M.init_cache(cfg, B, shape.seq_len))
    cspec = shd.cache_specs(cfg, cache, sizes, multi_pod)
    token = jax.ShapeDtypeStruct((B,), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    bspec_tok = shd.batch_specs(cfg, token, sizes, multi_pod)

    if cfg.family == "encdec":
        def decode(p, tok, pos_, c, enc):
            return M.decode_step(cfg, p, tok, pos_, c, enc)
        espec = shd.batch_specs(cfg, enc_shape, sizes, multi_pod)
        logits = jax.eval_shape(decode, params, token, pos, cache, enc_shape)
        lspec = (shd.batch_specs(cfg, logits[0], sizes, multi_pod), cspec)
        return (decode, (params, token, pos, cache, enc_shape),
                (ns(pspec), ns(bspec_tok), ns(P()), ns(cspec), ns(espec)),
                ns(lspec))

    def decode(p, tok, pos_, c):
        return M.decode_step(cfg, p, tok, pos_, c)

    logits = jax.eval_shape(decode, params, token, pos, cache)
    lspec = (shd.batch_specs(cfg, logits[0], sizes, multi_pod), cspec)
    return (decode, (params, token, pos, cache),
            (ns(pspec), ns(bspec_tok), ns(P()), ns(cspec)), ns(lspec))


# ---------------------------------------------------------------------------
# per-cell dry-run
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, mesh_kind: str,
             quantize_kv: bool = False) -> dict:
    import dataclasses

    cfg = get_config(arch)
    if quantize_kv:
        cfg = dataclasses.replace(cfg, quantize_kv=True)
    shape = get_shape(shape_name)
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "skipped": why}
    multi_pod = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size

    t0 = time.time()
    fn, args, in_sh, out_sh = build_cell(cfg, shape, mesh, multi_pod)
    with mesh, activation_sharding(mesh, multi_pod):
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    hlo = compiled.as_text()
    # loop-trip-scaled census (cost_analysis counts while bodies once —
    # verified; see hlo_cost.py docstring)
    census = hlo_analyze(hlo)
    coll = {"per_kind": census["collectives_by_kind"],
            "total": {"weighted_bytes": census["collective_bytes"]}}

    flops_dev = float(census["flops"])
    bytes_dev = float(census["bytes"])
    coll_dev = float(census["collective_bytes"])
    xla_reported = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
    }
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    mf_dev = mf / n_dev
    total_p, active_p = active_params(cfg)

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "n_devices": n_dev,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "hlo_flops_per_device": flops_dev,
        "hlo_bytes_per_device": bytes_dev,
        "xla_cost_analysis_unscaled": xla_reported,
        "collectives": coll,
        "roofline": {
            **terms,
            "dominant": dominant,
            "bound_s": max(terms.values()),
        },
        "model_flops_global": mf,
        "model_flops_per_device": mf_dev,
        "useful_ratio": (mf_dev / flops_dev) if flops_dev else None,
        "params_total": total_p,
        "params_active": active_p,
        "roofline_fraction": (mf_dev / PEAK_FLOPS) / max(max(terms.values()), 1e-12),
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out-dir", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--quantize-kv", action="store_true")
    args = ap.parse_args()

    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    archs = [a for a in ARCH_IDS if a != "paper_rs"] if args.all else [args.arch]
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"] \
        if args.all else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    for arch in archs:
        for shp in shapes:
            for mk in meshes:
                out = out_dir / f"{arch}__{shp}__{mk}.json"
                if out.exists() and not args.force:
                    print(f"skip (cached): {out.name}")
                    continue
                print(f"=== {arch} x {shp} x {mk} ===", flush=True)
                try:
                    res = run_cell(arch, shp, mk, quantize_kv=args.quantize_kv)
                except Exception as e:  # record failures — they are bugs
                    res = {"arch": arch, "shape": shp, "mesh": mk,
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                out.write_text(json.dumps(res, indent=1, default=str))
                if "error" in res:
                    print(f"  ERROR: {res['error'][:300]}", flush=True)
                elif "skipped" in res:
                    print(f"  SKIP: {res['skipped']}", flush=True)
                else:
                    r = res["roofline"]
                    print(f"  lower={res['lower_s']}s compile={res['compile_s']}s "
                          f"dominant={r['dominant']} "
                          f"roofline_frac={res['roofline_fraction']:.3f}",
                          flush=True)


if __name__ == "__main__":
    main()
