"""TrainState pytree + factory."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..models import model as M
from ..models.config import ArchConfig
from ..optim import make_optimizer, make_schedule
from ..optim.optimizers import Optimizer


class TrainState(NamedTuple):
    step: jnp.ndarray
    params: Any
    opt_state: Any


def make_train_setup(cfg: ArchConfig, total_steps: int = 10000,
                     peak_lr: float = 3e-4) -> tuple[Optimizer, Any]:
    sched_kind = "wsd" if cfg.name.startswith("minicpm") else "cosine"
    lr = make_schedule(sched_kind, peak_lr, total_steps)
    opt = make_optimizer(cfg.optimizer, lr)
    return opt, lr


def init_state(cfg: ArchConfig, key, opt: Optimizer) -> TrainState:
    params = M.init_params(cfg, key)
    return TrainState(jnp.zeros((), jnp.int32), params, opt.init(params))


def abstract_state(cfg: ArchConfig, opt: Optimizer) -> TrainState:
    """ShapeDtypeStruct state for dry-run lowering (no allocation)."""
    params = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    opt_state = jax.eval_shape(opt.init, params)
    return TrainState(jax.ShapeDtypeStruct((), jnp.int32), params, opt_state)
