"""Train step factory: microbatched grad accumulation, optional int8
gradient compression for the cross-pod all-reduce, remat via the model's
layer scan, and the coded-parity hook for fault-tolerant checkpointing.

Distribution model: pure jit (GSPMD) — params/opt-state sharded by
`dist.sharding` rules, batch sharded on (pod, data).  XLA inserts the
reduce-scatter/all-gather pattern for FSDP; compute/comm overlap comes from
XLA's latency-hiding scheduler (enabled via flags in launch/train.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models import model as M
from ..models.config import ArchConfig
from ..optim.optimizers import Optimizer
from .state import TrainState


def _int8_compress_decompress(g: jnp.ndarray) -> jnp.ndarray:
    """Simulated int8 gradient compression (quantize -> dequantize).

    On a real multi-pod deployment this wraps the cross-pod psum: each pod
    reduces in bf16 locally, then exchanges int8-quantized partial sums over
    DCI. Under jit the quantization error is what matters; the byte savings
    show up in the collective analysis as an int8 all-reduce.
    """
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-8) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def make_train_step(cfg: ArchConfig, opt: Optimizer, microbatches: int = 1,
                    compress_grads: bool = False):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        return M.loss_fn(cfg, params, batch)

    def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        if microbatches > 1:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])

            mb = jax.tree.map(split, batch)

            def acc_body(carry, mbatch):
                loss, grads = jax.value_and_grad(loss_fn)(state.params, mbatch)
                acc_loss, acc_grads = carry
                acc_grads = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), acc_grads, grads)
                return (acc_loss + loss, acc_grads), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss, grads), _ = jax.lax.scan(acc_body, (0.0, zero), mb)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)

        if compress_grads:
            grads = jax.tree.map(_int8_compress_decompress, grads)

        new_params, new_opt = opt.update(grads, state.opt_state, state.params,
                                         state.step)
        metrics = {"loss": loss,
                   "grad_norm": _gnorm(grads),
                   "lr_step": state.step}
        return TrainState(state.step + 1, new_params, new_opt), metrics

    return train_step


def _gnorm(grads):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))


def make_eval_step(cfg: ArchConfig):
    def eval_step(params, batch):
        return M.loss_fn(cfg, params, batch)
    return eval_step
