from . import serve
from .coded_step import StragglerInjector, make_straggler_train_step
from .state import TrainState, abstract_state, init_state, make_train_setup
from .train_loop import make_eval_step, make_train_step

__all__ = ["TrainState", "init_state", "abstract_state", "make_train_setup",
           "make_train_step", "make_eval_step", "make_straggler_train_step",
           "StragglerInjector", "serve"]
