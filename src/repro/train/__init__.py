from .state import TrainState, init_state, abstract_state, make_train_setup
from .train_loop import make_train_step, make_eval_step
from . import serve

__all__ = ["TrainState", "init_state", "abstract_state", "make_train_setup",
           "make_train_step", "make_eval_step", "serve"]
