"""Serving steps: prefill (full-sequence, returns logits + populated KV
cache) and decode (one token per request against the cache)."""
from __future__ import annotations

import jax.numpy as jnp

from ..models import model as M
from ..models.config import ArchConfig


def make_prefill_step(cfg: ArchConfig):
    def prefill(params, batch):
        return M.forward(cfg, params, batch)

    return prefill


def make_decode_step(cfg: ArchConfig):
    def decode(params, token, pos, cache, enc_out=None):
        return M.decode_step(cfg, params, token, pos, cache, enc_out)

    return decode


def greedy_generate(cfg: ArchConfig, params, prompt: jnp.ndarray, steps: int,
                    max_len: int = 256):
    """Simple batched greedy generation driver (used by the serving example)."""
    B, S = prompt.shape
    cache = M.init_cache(cfg, B, max_len)
    tok = prompt[:, 0]
    out = [tok]
    for t in range(S + steps - 1):
        logits, cache = M.decode_step(cfg, params, tok, jnp.int32(t), cache)
        if t + 1 < S:
            tok = prompt[:, t + 1]  # teacher-forced prompt consumption
        else:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    return jnp.stack(out, axis=1)
