"""Straggler-tolerant training via gradient coding (the `GradientCoder`
fractional-repetition scheme wired into a jitted data-parallel step).

The global batch is cut into `coder.n_workers` parts along the batch axis;
group g's workers each compute the gradient sum of all (s+1) parts owned
by g (one report per worker, bitwise-identical within a group by
construction — the sum is formed once, in fixed part order).  The decode
is `decode_weights(alive)` applied per step: the 0/1 weight vector selects
one live representative per group and the weighted cross-group sum is the
EXACT full-batch gradient — bitwise-equal in float to the all-alive step
for any ≤ s stragglers, because surviving reports enter the sum scaled by
exactly 1.0 and zeroed reports contribute exactly 0.  More than s
stragglers in one group raises loudly on the host (`RuntimeError`), before
the device step runs.

Observability: every step lands a `coded_train_step` span on the installed
tracer (`obs.trace.get_tracer()`) with the straggler set as span args, and
the `coded_train_*` metrics family (steps/stragglers counters, per-step
dispatch-time histogram) feeds `obs.metrics.REGISTRY`.

Straggler patterns come from `StragglerInjector` — `FaultInjector`-driven
masks (each training step is one round of a virtual `RoundNetwork`): per
step `random` draws, `bursty` runs of a sticky victim set, or a `fixed`
worker set.  `launch/train.py --stragglers s` threads all of this end to
end.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field as dc_field

import jax
import jax.numpy as jnp
import numpy as np

from ..coding.gradient_code import GradientCoder
from ..core.simulator import FaultInjector, RoundNetwork
from ..models import model as M
from ..models.config import ArchConfig
from ..obs import metrics, trace
from ..optim.optimizers import Optimizer
from .state import TrainState
from .train_loop import _gnorm

_STEPS = metrics.REGISTRY.counter(
    "coded_train_steps_total", "coded train steps run")
_STRAGGLED = metrics.REGISTRY.counter(
    "coded_train_stragglers_total", "worker-steps lost to stragglers")
_STEP_US = metrics.REGISTRY.histogram(
    "coded_train_step_us", "coded step wall time (host dispatch), us")


def make_straggler_train_step(cfg: ArchConfig, opt: Optimizer,
                              coder: GradientCoder):
    """Returns coded_step(state, batch, alive=None) -> (state, metrics).

    `batch` leaves must have a leading batch dim divisible by
    `coder.n_workers`; `alive` is a per-step (n_workers,) bool mask (None
    = all alive).  The returned metrics carry loss/grad_norm/lr_step like
    `make_train_step` plus the straggler count.  Gradient recovery is
    bitwise-exact vs the same step with `alive=None` for any ≤ s
    stragglers; > s in one group raises `RuntimeError` before dispatch.
    """
    n = coder.n_workers
    G, m = coder.n_groups, coder.s + 1

    def loss_fn(params, batch):
        return M.loss_fn(cfg, params, batch)

    @jax.jit
    def _step(state: TrainState, batch: dict, weights: jnp.ndarray):
        def split(x):
            return x.reshape((n, x.shape[0] // n) + x.shape[1:])

        parts = jax.tree.map(split, batch)

        def per_part(pb):
            return jax.value_and_grad(loss_fn)(state.params, pb)

        # every worker computes its group's (s+1) parts; parts are
        # evaluated once here and group-summed once, in fixed part order —
        # the per-worker "reports" within a group are therefore
        # bitwise-identical, as in the real protocol
        losses, pgrads = jax.lax.map(per_part, parts)
        ggrads = jax.tree.map(
            lambda t: jnp.sum(t.reshape((G, m) + t.shape[1:]), axis=1),
            pgrads)

        # decode: sum_w a_w * report_w = sum_g (sum_{w in g} a_w) * g_sum;
        # decode_weights puts exactly one 1.0 in each live group, so the
        # per-group coefficient is exactly 1.0 (or the step is rejected on
        # the host) and the float combine is bitwise mask-independent
        gw = jnp.sum(weights.reshape(G, m), axis=1)

        def combine(t):
            w = gw.reshape((G,) + (1,) * (t.ndim - 1))
            return jnp.sum(t * w, axis=0) / n

        grads = jax.tree.map(combine, ggrads)
        loss = jnp.mean(losses)
        new_params, new_opt = opt.update(grads, state.opt_state, state.params,
                                         state.step)
        metrics_out = {"loss": loss,
                       "grad_norm": _gnorm(grads),
                       "lr_step": state.step}
        return TrainState(state.step + 1, new_params, new_opt), metrics_out

    def coded_step(state: TrainState, batch: dict, alive=None):
        alive = np.ones(n, bool) if alive is None else np.asarray(alive, bool)
        if alive.shape != (n,):
            raise ValueError(f"alive must be ({n},) bool, got {alive.shape}")
        b0 = jax.tree.leaves(batch)[0].shape[0]
        if b0 % n:
            raise ValueError(f"batch dim {b0} not divisible by n_workers={n}")
        a = coder.decode_weights(alive)  # raises on > s in a group
        stragglers = [int(w) for w in np.flatnonzero(~alive)]
        tracer = trace.get_tracer()
        t0 = time.perf_counter()
        out = _step(state, batch, jnp.asarray(a, jnp.float32))
        dur_us = (time.perf_counter() - t0) * 1e6
        if tracer is not None:
            tracer.complete("coded_train_step", tracer.now_us() - dur_us,
                            dur_us, pid="train", tid="coded_step",
                            cat="train.step",
                            args={"step": int(state.step),
                                  "stragglers": stragglers})
        _STEPS.inc(workers=n, s=coder.s)
        if stragglers:
            _STRAGGLED.inc(len(stragglers), workers=n, s=coder.s)
        _STEP_US.observe(dur_us, workers=n, s=coder.s)
        state2, mets = out
        mets = dict(mets)
        mets["stragglers"] = len(stragglers)
        return state2, mets

    return coded_step


@dataclass
class StragglerInjector:
    """Per-step straggler masks, `FaultInjector`-driven.

    Each training step is one round of a virtual `RoundNetwork`: the
    chosen pattern is registered up front through `FaultInjector.kill_at`
    (so `injector.plan` lists every (step, worker) straggle and the same
    chaos tooling as `launch/serve.py --chaos` applies), and `mask(step)`
    replays it as an alive mask for `make_straggler_train_step`.  Kills
    here are transient — a worker straggles the registered steps only,
    matching the gradient-coding fault model (slow, not dead).

    Patterns (all keep ≤ s victims per step, so every mask is decodable):
      random — each step straggles, with prob `rate`, a fresh uniform
               victim set of size 1..s
      bursty — a sticky victim set straggles for a geometric run of steps
               (mean `burst`), then a quiet gap, then a redraw
      fixed  — the given workers (default 0..s-1) straggle every step
    """

    coder: GradientCoder
    injector: FaultInjector
    _by_step: dict[int, frozenset] = dc_field(default_factory=dict)

    @property
    def plan(self) -> list:
        """The registered (step, worker) pairs, in registration order."""
        return self.injector.plan

    def mask(self, step: int) -> np.ndarray:
        alive = np.ones(self.coder.n_workers, bool)
        for w in self._by_step.get(int(step), ()):
            alive[w] = False
        return alive

    @classmethod
    def _new(cls, coder: GradientCoder) -> "StragglerInjector":
        net = RoundNetwork(coder.n_workers, p=1)
        return cls(coder, FaultInjector(net))

    def _register(self, step: int, victims) -> None:
        victims = frozenset(int(v) for v in victims)
        if victims:
            self.injector.kill_at(step, sorted(victims))
            self._by_step[int(step)] = victims

    @classmethod
    def random(cls, coder: GradientCoder, steps: int, *, rate: float = 0.3,
               seed: int = 0) -> "StragglerInjector":
        inj = cls._new(coder)
        rng = np.random.default_rng(seed)
        for t in range(steps):
            if rng.random() < rate:
                k = int(rng.integers(1, coder.s + 1)) if coder.s else 0
                inj._register(t, rng.choice(coder.n_workers, size=k,
                                            replace=False))
        return inj

    @classmethod
    def bursty(cls, coder: GradientCoder, steps: int, *, rate: float = 0.3,
               burst: int = 4, seed: int = 0) -> "StragglerInjector":
        inj = cls._new(coder)
        rng = np.random.default_rng(seed)
        t = 0
        while t < steps:
            if rng.random() < rate and coder.s:
                k = int(rng.integers(1, coder.s + 1))
                victims = rng.choice(coder.n_workers, size=k, replace=False)
                run = 1 + int(rng.geometric(1.0 / max(burst, 1)))
                for u in range(t, min(t + run, steps)):
                    inj._register(u, victims)
                t += run
            else:
                t += 1
        return inj

    @classmethod
    def fixed(cls, coder: GradientCoder, steps: int,
              workers=None) -> "StragglerInjector":
        workers = list(range(coder.s)) if workers is None else list(workers)
        if len(workers) > coder.s:
            raise ValueError(f"{len(workers)} fixed stragglers exceed "
                             f"tolerance s={coder.s}")
        inj = cls._new(coder)
        for t in range(steps):
            inj._register(t, workers)
        return inj

    @classmethod
    def build(cls, mode: str, coder: GradientCoder, steps: int, *,
              rate: float = 0.3, seed: int = 0) -> "StragglerInjector":
        if mode == "random":
            return cls.random(coder, steps, rate=rate, seed=seed)
        if mode == "bursty":
            return cls.bursty(coder, steps, rate=rate, seed=seed)
        if mode == "fixed":
            return cls.fixed(coder, steps)
        raise ValueError(f"unknown straggler mode {mode!r} "
                         "(random | bursty | fixed)")
