"""Sharding rules: PartitionSpec validation plus the spec factories the
dry-run/production launchers use for params, optimizer state, batches and
decode caches.

`guard` is the single rule deciding whether a requested sharding axis is
legal for a concrete array shape: an axis (or tuple of axes) is kept only if
every named mesh axis exists and the array dimension is divisible by the
product of their sizes; otherwise that dimension falls back to replication
(None).  Dropping instead of erroring is deliberate — reduced smoke configs
frequently have dimensions (e.g. a 30-wide vocab slice) that the production
16-way model axis cannot divide, and the numerically-identical replicated
layout is always available.

The `*_specs` factories all funnel through `guard`, so every produced spec
is valid for the concrete mesh by construction:

  * params / optimizer state: tensor-parallel over "model" on the largest
    divisible dimension (vocab for embeddings, features for projections);
    scalars and non-divisible leaves replicate,
  * batches / activations: leading batch dimension over the data-parallel
    axes ("pod" joining "data" on multi-pod meshes), plus "model" on the
    trailing feature dimension of rank >= 3 activations (vocab-sharded
    logits, frame/vision embeddings),
  * decode caches: layer-stacked leaves (layers, batch, ...) shard batch on
    dim 1 and "model" on the innermost divisible feature dimension.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec


def guard(spec: PartitionSpec, shape: tuple[int, ...],
          axis_sizes: dict[str, int]) -> PartitionSpec:
    """Validate `spec` for an array of `shape` on a mesh with `axis_sizes`.

    Each spec entry is kept iff all its mesh axes exist and the corresponding
    array dimension is divisible by the product of their sizes; non-divisible
    (or unknown-axis) entries are dropped to None.
    """
    entries = []
    for dim, entry in enumerate(spec):
        if entry is None:
            entries.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        known = True
        for a in axes:
            if a not in axis_sizes:
                known = False
                break
            size *= axis_sizes[a]
        if known and dim < len(shape) and shape[dim] % size == 0:
            entries.append(entry)
        else:
            entries.append(None)
    return PartitionSpec(*entries)


# ---------------------------------------------------------------------------
# spec factories (all guarded)
# ---------------------------------------------------------------------------

def data_axes(axis_sizes: dict[str, int], multi_pod: bool):
    """The batch-dimension mesh axes: ("pod", "data") when the pod axis is
    batch-parallel, else ("data",)."""
    names = ("pod", "data") if multi_pod else ("data",)
    kept = tuple(a for a in names if axis_sizes.get(a, 0) > 1)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def _model_dim(shape: tuple[int, ...], msize: int, skip: tuple[int, ...] = ()):
    """Largest dimension divisible by the model-axis size (ties -> last)."""
    best = None
    for d, n in enumerate(shape):
        if d in skip or msize < 2 or n % msize != 0 or n < msize:
            continue
        if best is None or n >= shape[best]:
            best = d
    return best


def _param_leaf(shape, axis_sizes) -> PartitionSpec:
    msize = axis_sizes.get("model", 1)
    entries = [None] * len(shape)
    d = _model_dim(shape, msize)
    if d is not None:
        entries[d] = "model"
    return guard(PartitionSpec(*entries), shape, axis_sizes)


def param_specs(cfg, params, axis_sizes: dict[str, int], multi_pod: bool):
    """Tensor-parallel parameter layout: "model" on the largest divisible
    dimension of each leaf (vocab for embeddings, features elsewhere)."""
    del cfg, multi_pod
    return jax.tree.map(lambda l: _param_leaf(l.shape, axis_sizes), params)


def opt_state_specs(cfg, params, opt_state, axis_sizes: dict[str, int],
                    multi_pod: bool):
    """Optimizer state follows the parameter rule leaf-by-leaf (moment
    buffers share param shapes; factored/scalar leaves fall out of the same
    divisibility rule)."""
    del cfg, params, multi_pod
    return jax.tree.map(lambda l: _param_leaf(l.shape, axis_sizes), opt_state)


def batch_specs(cfg, batch, axis_sizes: dict[str, int], multi_pod: bool):
    """Model inputs/outputs: batch dim 0 over the data axes; rank >= 3
    activations additionally put "model" on the trailing feature dim
    (vocab-sharded logits, vision/frame embeddings)."""
    del cfg
    dax = data_axes(axis_sizes, multi_pod)

    def rule(leaf):
        shape = leaf.shape
        if not shape:
            return PartitionSpec()
        entries = [None] * len(shape)
        entries[0] = dax
        if len(shape) >= 3:
            entries[-1] = "model"
        return guard(PartitionSpec(*entries), shape, axis_sizes)

    return jax.tree.map(rule, batch)


def cache_specs(cfg, cache, axis_sizes: dict[str, int], multi_pod: bool):
    """Decode caches are layer-stacked (layers, batch, ...): batch on dim 1,
    "model" on the innermost divisible feature dimension (head_dim / heads),
    never on the layer or batch dims."""
    del cfg
    dax = data_axes(axis_sizes, multi_pod)
    msize = axis_sizes.get("model", 1)

    def rule(leaf):
        shape = leaf.shape
        if len(shape) < 2:
            return PartitionSpec(*([None] * len(shape)))
        entries = [None] * len(shape)
        entries[1] = dax
        for d in range(len(shape) - 1, 1, -1):
            if msize >= 2 and shape[d] % msize == 0 and shape[d] >= msize:
                entries[d] = "model"
                break
        return guard(PartitionSpec(*entries), shape, axis_sizes)

    return jax.tree.map(rule, cache)
