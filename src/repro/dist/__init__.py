"""Distribution helpers: mesh-aware sharding constraints and spec guards.

`ctx.constrain` is the model-code entry point (logical axis names ->
mesh-validated `with_sharding_constraint`); `sharding.guard` is the pure
validation rule it relies on.
"""
from .ctx import activation_sharding, constrain, current_mesh
from .sharding import (
    batch_specs,
    cache_specs,
    data_axes,
    guard,
    opt_state_specs,
    param_specs,
)

__all__ = [
    "activation_sharding", "constrain", "current_mesh", "guard",
    "data_axes", "param_specs", "opt_state_specs", "batch_specs",
    "cache_specs",
]
