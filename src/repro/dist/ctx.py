"""Mesh context for model code: logical-axis sharding constraints.

Model layers annotate activations with *logical* axis names
(`constrain(x, "batch", None, "model")`); this module resolves them against
whatever mesh is active:

  * no mesh (single-device smoke tests, simulator runs): no-op,
  * a mesh without the named axis, or a non-divisible dimension: that axis is
    dropped by `sharding.guard` (replicated) instead of erroring,
  * "batch" maps to all data-parallel axes present (("pod", "data") on the
    multi-pod production mesh, ("data",) on host meshes).

Keeping the resolution here (not in the layers) lets the same model code run
unmodified under 1-device pytest, the 8-device host mesh, and the 16x16(+pod)
production meshes.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec

from .sharding import guard

# logical name -> candidate mesh axes (first all present are combined)
_LOGICAL = {"batch": ("pod", "data")}

_ACTIVE = threading.local()  # set by activation_sharding()


@contextlib.contextmanager
def activation_sharding(mesh, multi_pod: bool = False):
    """Scope in which `constrain` resolves against `mesh`.

    Entered by the launchers around lowering/compilation (alongside
    `with mesh:`); `multi_pod=False` keeps the "batch" logical axis off the
    pod axis even when the mesh has one (pipeline-style pod use)."""
    prev = getattr(_ACTIVE, "ctx", None)
    _ACTIVE.ctx = (mesh, multi_pod)
    try:
        yield
    finally:
        _ACTIVE.ctx = prev


def current_mesh():
    """The mesh `constrain` resolves against: the innermost
    `activation_sharding` scope, else the ambient `with mesh:` context."""
    ctx = getattr(_ACTIVE, "ctx", None)
    if ctx is not None:
        return ctx[0]
    try:
        from jax.interpreters import pxla

        mesh = pxla.thread_resources.env.physical_mesh
        if mesh is not None and not mesh.empty:
            return mesh
    except Exception:
        pass
    return None


def _resolve(name, axis_sizes: dict[str, int]):
    if name is None:
        return None
    if isinstance(name, tuple):
        kept = tuple(a for a in name if a in axis_sizes)
        return kept if kept else None
    if name in _LOGICAL:
        kept = tuple(a for a in _LOGICAL[name] if a in axis_sizes)
        if not kept:
            return None
        return kept if len(kept) > 1 else kept[0]
    return name if name in axis_sizes else None


def constrain(x, *axes):
    """`with_sharding_constraint(x, P(*axes))` with logical-name resolution
    and divisibility guarding; identity when no mesh is active."""
    mesh = current_mesh()
    if mesh is None:
        return x
    ctx = getattr(_ACTIVE, "ctx", None)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if ctx is not None and not ctx[1]:
        sizes.pop("pod", None)  # pod axis not batch-parallel in this scope
    spec = PartitionSpec(*(_resolve(a, sizes) for a in axes))
    spec = guard(spec, x.shape, sizes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
