"""LR schedules: cosine and WSD (warmup-stable-decay, minicpm arXiv:2404.06395)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)

    return lr


def wsd_schedule(peak_lr: float, warmup: int, stable: int, decay: int,
                 floor: float = 0.01):
    """Warmup -> flat -> exponential-ish (linear here) decay to floor*peak."""
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup - stable) / jnp.maximum(decay, 1), 0.0, 1.0)
        dec = peak_lr * (1 - (1 - floor) * t)
        out = jnp.where(step < warmup, warm, peak_lr)
        return jnp.where(step > warmup + stable, dec, out)

    return lr


def make_schedule(kind: str, peak_lr: float, total: int, warmup: int | None = None):
    warmup = warmup if warmup is not None else max(10, total // 100)
    if kind == "wsd":
        stable = int(0.8 * (total - warmup))
        return wsd_schedule(peak_lr, warmup, stable, total - warmup - stable)
    return cosine_schedule(peak_lr, warmup, total)
