from .optimizers import Optimizer, adafactor, adamw, make_optimizer
from .schedules import cosine_schedule, make_schedule, wsd_schedule

__all__ = ["adamw", "adafactor", "make_optimizer", "Optimizer",
           "cosine_schedule", "wsd_schedule", "make_schedule"]
