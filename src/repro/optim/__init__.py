from .optimizers import adamw, adafactor, make_optimizer, Optimizer
from .schedules import cosine_schedule, wsd_schedule, make_schedule

__all__ = ["adamw", "adafactor", "make_optimizer", "Optimizer",
           "cosine_schedule", "wsd_schedule", "make_schedule"]
