"""Optimizers as pure (init, update) pairs over param pytrees (no optax).

* adamw     — fp32 m/v states, decoupled weight decay.
* adafactor — factored second moment (row/col statistics for >=2D params),
              no first moment by default: ~1 byte-equivalent of state per
              param element. Required for kimi-k2 on the 512-chip HBM
              envelope (DESIGN.md §5).

Both support global-norm clipping and an `lr(step)` schedule callable.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Params], Params]
    update: Callable[[Params, Params, Params, jnp.ndarray], tuple[Params, Params]]
    # update(grads, state, params, step) -> (new_params, new_state)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw(
    lr: Callable,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        grads, _ = clip_by_global_norm(grads, clip_norm)
        t = step.astype(jnp.float32) + 1.0
        lr_t = lr(step)
        bc1 = 1 - b1**t
        bc2 = 1 - b2**t

        def upd(g, m, v, p):
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            upd_ = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            new_p = p.astype(jnp.float32) - lr_t * (upd_ + weight_decay * p.astype(jnp.float32))
            return new_p.astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v}

    return Optimizer(init, update)


def adafactor(
    lr: Callable,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    decay: float = 0.8,
    weight_decay: float = 0.0,
    clip_norm: float = 1.0,
) -> Optimizer:
    """Factored RMS optimizer (Shazeer & Stern 2018), momentum-free."""

    def _factored(p) -> bool:
        return p.ndim >= 2

    def init(params):
        def st(p):
            if _factored(p):
                return {
                    "r": jnp.zeros(p.shape[:-1], jnp.float32),      # row stats
                    "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return jax.tree.map(st, params, is_leaf=lambda x: hasattr(x, "shape"))

    def update(grads, state, params, step):
        grads, _ = clip_by_global_norm(grads, clip_norm)
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)
        lr_t = lr(step)

        def upd(g, s, p):
            g2 = g * g + eps
            if _factored(p):
                r = beta * s["r"] + (1 - beta) * jnp.mean(g2, axis=-1)
                c = beta * s["c"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rc = r / jnp.maximum(jnp.mean(r, axis=-1, keepdims=True), eps)
                vhat = rc[..., None] * c[..., None, :]
                new_s = {"r": r, "c": c}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                vhat = v
                new_s = {"v": v}
            u = g / jnp.sqrt(vhat + eps)
            # update clipping (RMS)
            rms_u = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            new_p = p.astype(jnp.float32) - lr_t * (u + weight_decay * p.astype(jnp.float32))
            return new_p.astype(p.dtype), new_s

        out = jax.tree.map(upd, grads, state, params,
                           is_leaf=lambda x: isinstance(x, dict) and ("r" in x or "v" in x))
        is_pair = lambda x: isinstance(x, tuple)
        new_params = jax.tree.map(lambda o: o[0], out, is_leaf=is_pair)
        new_state = jax.tree.map(lambda o: o[1], out, is_leaf=is_pair)
        return new_params, new_state

    return Optimizer(init, update)


def make_optimizer(kind: str, lr: Callable, **kw) -> Optimizer:
    return {"adamw": adamw, "adafactor": adafactor}[kind](lr, **kw)
