from .checkpoint import CodedCheckpointer, tree_to_bytes, bytes_to_tree

__all__ = ["CodedCheckpointer", "tree_to_bytes", "bytes_to_tree"]
