from .checkpoint import CodedCheckpointer, bytes_to_tree, tree_to_bytes

__all__ = ["CodedCheckpointer", "tree_to_bytes", "bytes_to_tree"]
