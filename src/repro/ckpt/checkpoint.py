"""Fault-tolerant checkpointing with Reed-Solomon coded parity.

Layout (one directory per step, atomic rename on completion):

    ckpt_dir/step_000123/
        meta.json            — pytree structure, shapes, dtypes, N, R, q
        shard_000.npy ...    — N data shards (equal-size 16-bit symbol chunks
                               of the concatenated flat state)
        parity_000.npy ...   — R parity shards (systematic GRS over F_65537)

The parity is exactly the paper's decentralized-encoding output: on a real
cluster each of the N hosts writes its own shard and the R parity shards are
produced *in-network* by `core.parity.mesh_parity_encode` along the data
axis (no central encoder); here the host-side `encode_parity` reuses the
same StructuredGRS code so restore logic is identical.

Restore tolerates up to R missing shards (any-N-of-(N+R) MDS property,
validated in tests): shard/parity files missing from disk are detected,
`fail()`-ed on a restore-scoped `repro.api.CodedSystem` session, and
decoded around automatically (degraded read — the same `DecodePlan` the
survivors would execute in-network).  Elastic
resharding is supported: a checkpoint written with N shards restores onto
any N' (the flat symbol stream is re-split).

Integrity: `save` records a sha256 of every shard/parity payload in
meta.json, and `scrub()` is the background-repair pass a coded store runs
continuously — verify every file on disk against its checksum, then
rebuild missing/corrupt ones *in place* via the streamed decentralized
rebuild (`CodedSystem.rebuild_stream` off the survivor memmaps), restoring
full redundancy without ever materializing the whole codeword.

Async: `save(..., background=True)` hands the write to a daemon thread —
training continues; `wait()` joins before the next save (single-writer).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from dataclasses import dataclass
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..api import CodedSystem, CodeSpec
from ..core.field import FERMAT, bytes_to_symbols, symbols_to_bytes


# ---------------------------------------------------------------------------
# pytree <-> flat symbol stream
# ---------------------------------------------------------------------------

def _leaf_meta(x) -> dict:
    return {"shape": list(x.shape), "dtype": str(x.dtype)}


def tree_to_bytes(tree: Any) -> tuple[np.ndarray, dict]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    metas = []
    bufs = []
    for leaf in leaves:
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
            metas.append({"shape": list(leaf.shape), "dtype": "bfloat16"})
        else:
            metas.append(_leaf_meta(arr))
        bufs.append(arr.tobytes())
    raw = np.frombuffer(b"".join(bufs), np.uint8)
    meta = {"leaves": metas, "treedef": str(treedef), "nbytes": int(raw.size)}
    return raw, meta


def bytes_to_tree(raw: np.ndarray, meta: dict, treedef_example: Any) -> Any:
    leaves_ex, treedef = jax.tree_util.tree_flatten(treedef_example)
    out = []
    off = 0
    for m, ex in zip(meta["leaves"], leaves_ex):
        if m["dtype"] == "bfloat16":
            nb = int(np.prod(m["shape"])) * 2
            arr = np.frombuffer(raw[off:off + nb].tobytes(), np.uint16)
            arr = jnp.asarray(arr.reshape(m["shape"]).view(jnp.bfloat16))
        else:
            dt = np.dtype(m["dtype"])
            nb = int(np.prod(m["shape"])) * dt.itemsize
            arr = np.frombuffer(raw[off:off + nb].tobytes(), dt).reshape(m["shape"])
        out.append(arr)
        off += nb
    assert off == meta["nbytes"]
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# coded checkpoint manager
# ---------------------------------------------------------------------------

@dataclass
class CodedCheckpointer:
    directory: str
    n_shards: int = 16
    n_parity: int = 4
    field: Any = None
    # streaming chunk width (payload columns) for the coded save/restore
    # paths; None = api.stream.default_chunk_w for the shard count
    chunk_w: int | None = None
    _thread: threading.Thread | None = None

    def __post_init__(self):
        self.field = self.field or FERMAT
        assert self.n_shards % self.n_parity == 0, "R | N (Remark 4)"
        # one CodedSystem session owns both coding directions: the encode
        # plan carries the StructuredGRS code and its generator block, and
        # degraded restores replan the decode side per erasure pattern.
        # The shared plan caches mean repeated checkpointer instances
        # (reshard, restarts) never rebuild the code tables.  The uint32
        # kernel backend is Fermat-only; other fields fall back to the
        # exact host matmul for parity (same generator block either way).
        spec = CodeSpec(kind="rs", K=self.n_shards, R=self.n_parity,
                        q=self.field.q)
        self._fermat = self.field.q == FERMAT.q
        self._system = CodedSystem(
            spec, backend="local" if self._fermat else "simulator",
            chunk_w=self.chunk_w)
        self.sgrs = self._system.encode_plan.sgrs
        self._A = self._system.encode_plan.A
        Path(self.directory).mkdir(parents=True, exist_ok=True)

    # -- encode -------------------------------------------------------------
    def shard_symbols(self, raw: np.ndarray) -> np.ndarray:
        """(N, L) int64 symbols: 16-bit chunks, zero-padded to N*L."""
        sym = bytes_to_symbols(raw)
        L = -(-sym.size // self.n_shards)
        pad = np.zeros(self.n_shards * L - sym.size, np.int64)
        return np.concatenate([sym, pad]).reshape(self.n_shards, L)

    def encode_parity(self, shards: np.ndarray) -> np.ndarray:
        """(R, L) parity — same code the in-network mesh encode computes.

        Runs through `CodedSystem.encode`, i.e. the kernels.ops encode
        path (previously a host-side field.matmul); non-Fermat fields keep
        the exact host matmul."""
        if not self._fermat:
            return self.field.matmul(self._A.T, shards)
        return self._system.encode(shards)

    def _parity_stream(self, shards: np.ndarray):
        """Generator of (R, w) parity blocks — `CodedSystem.encode_stream`
        on the kernel path (cached chunk callables, NTT fast path when the
        shard counts allow it), exact chunked host matmul otherwise."""
        if self._fermat:
            yield from self._system.encode_stream(shards)
            return
        from ..api.stream import iter_chunks

        for c in iter_chunks(shards, self.n_shards, self.chunk_w):
            yield self.field.matmul(self._A.T, c)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state: Any, background: bool = False) -> str:
        raw, meta = tree_to_bytes(state)
        shards = self.shard_symbols(raw)

        def _write():
            final = Path(self.directory) / f"step_{step:06d}"
            tmp = Path(self.directory) / f".tmp_step_{step:06d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            # per-file sha256 of the symbol payload (the uint32 array
            # bytes, not the .npy container) — scrub() verifies against
            # these to localize silent corruption to a file
            sums: dict[str, str] = {}
            for k in range(self.n_shards):
                arr = shards[k].astype(np.uint32)
                np.save(tmp / f"shard_{k:03d}.npy", arr)
                sums[f"shard_{k:03d}"] = hashlib.sha256(
                    arr.tobytes()).hexdigest()
            # parity is STREAMED into preallocated .npy memmaps: the encode
            # runs chunk-by-chunk (double-buffered on the kernel path) and
            # the full (R, L) parity matrix is never materialized; the
            # checksums accumulate over exactly the bytes written
            L = shards.shape[1]
            if L == 0:  # empty state: mmap cannot map zero bytes
                for r in range(self.n_parity):
                    np.save(tmp / f"parity_{r:03d}.npy",
                            np.zeros(0, np.uint32))
                    sums[f"parity_{r:03d}"] = hashlib.sha256(b"").hexdigest()
            else:
                mms = [np.lib.format.open_memmap(
                           tmp / f"parity_{r:03d}.npy", mode="w+",
                           dtype=np.uint32, shape=(L,))
                       for r in range(self.n_parity)]
                hs = [hashlib.sha256() for _ in range(self.n_parity)]
                col = 0
                for blk in self._parity_stream(shards):
                    w = blk.shape[1]
                    for r in range(self.n_parity):
                        row = blk[r].astype(np.uint32)
                        mms[r][col : col + w] = row
                        hs[r].update(row.tobytes())
                    col += w
                assert col == L
                for mm in mms:
                    mm.flush()
                del mms
                for r in range(self.n_parity):
                    sums[f"parity_{r:03d}"] = hs[r].hexdigest()
            meta2 = dict(meta, N=self.n_shards, R=self.n_parity,
                         q=self.field.q, step=step, sha256=sums)
            (tmp / "meta.json").write_text(json.dumps(meta2))
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)

        self.wait()  # single-writer: join any in-flight background save
        if background:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()
        return str(Path(self.directory) / f"step_{step:06d}")

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- restore ------------------------------------------------------------
    def latest_step(self) -> int | None:
        steps = sorted(int(p.name.split("_")[1])
                       for p in Path(self.directory).glob("step_*"))
        return steps[-1] if steps else None

    def restore(self, step: int, example_state: Any,
                failed_shards: set[int] = frozenset()) -> Any:
        """Restore, reconstructing up to R erased shards via the decode
        subsystem (`repro.recover.Decoder`).

        Degraded reads are automatic: shard/parity files missing from disk
        count as erasures, in addition to the explicitly `failed_shards`
        (simulated node failures, indices into [0, N)).  The restore
        succeeds as long as data + parity erasures total at most R."""
        d = Path(self.directory) / f"step_{step:06d}"
        meta = json.loads((d / "meta.json").read_text())
        N, R = meta["N"], meta["R"]
        erased = {int(k) for k in failed_shards}
        for k in range(N):
            if k not in erased and not (d / f"shard_{k:03d}.npy").exists():
                erased.add(k)
        for r in range(R):
            if not (d / f"parity_{r:03d}.npy").exists():
                erased.add(N + r)

        loaded: dict[int, np.ndarray] = {}

        def _load(idx: int) -> np.ndarray:
            # memory-mapped: survivor files are read chunk-by-chunk by the
            # streamed repair and row-by-row by the final assembly, never
            # duplicated wholesale on the heap
            if idx not in loaded:
                name = (f"shard_{idx:03d}.npy" if idx < N
                        else f"parity_{idx - N:03d}.npy")
                loaded[idx] = np.load(d / name, mmap_mode="r")
            return loaded[idx]

        if any(e < N for e in erased):
            assert len(erased) <= R, "more failures than parity can cover"
            spec = CodeSpec(kind="rs", K=N, R=R,
                            q=int(meta.get("q", self.field.q)))
            # a restore-scoped CodedSystem session for the file's (N, R)
            # layout (may differ from self under elastic reshard): fail
            # the missing positions, then stream the degraded read
            rsys = CodedSystem(
                spec, backend="local" if spec.q == FERMAT.q else "simulator",
                chunk_w=self.chunk_w)
            rsys.fail(sorted(erased))
            plan = rsys.decode_plan
            # repair only the |E| lost columns (K x |E| work) instead of
            # re-deriving all K data shards through the full K x K solve;
            # repaired rows for missing *parity* files ride along unused
            # (they must be in `erased` so plan.kept avoids them — at most
            # R-1 extra columns, still far below the K-column full solve).
            # The repair itself is STREAMED: survivor chunks are sliced
            # straight off the memmaps and decoded through the plan's
            # cached chunk callables, so no full-width survivor stack or
            # repaired matrix is ever materialized at once.
            L = int(_load(plan.kept[0]).shape[0])
            rep = {e: np.empty(L, np.int64) for e in plan.erased}
            from ..api.stream import default_chunk_w

            cw = self.chunk_w or default_chunk_w(N)

            def survivor_chunks():
                for c0 in range(0, L, cw):
                    yield np.stack([np.asarray(_load(i)[c0 : c0 + cw],
                                               np.int64)
                                    for i in plan.kept])

            col = 0
            for blk in rsys.decode_stream(survivor_chunks()):
                for j, e in enumerate(plan.erased):
                    rep[e][col : col + blk.shape[1]] = blk[j]
                col += blk.shape[1]
            assert col == L
            shards = np.stack([rep[k] if k in rep
                               else np.asarray(_load(k), np.int64)
                               for k in range(N)])
        else:
            shards = np.stack([np.asarray(_load(k), np.int64)
                               for k in range(N)])
        sym = shards.reshape(-1)[: -(-meta["nbytes"] // 2)]
        raw = symbols_to_bytes(sym, meta["nbytes"])
        return bytes_to_tree(raw, meta, example_state)

    # -- scrub: verify on-disk shards, rebuild the bad ones in place --------
    def scrub(self, step: int | None = None) -> dict:
        """Verify a checkpoint's shard/parity files and rebuild the
        missing/corrupt ones in place (the coded store's background
        integrity pass: fail -> rebuild -> healed, on disk).

        Every file must exist, parse as the expected (L,) uint32 array and
        match the sha256 recorded at save time (checkpoints written before
        checksums fall back to a shape + symbol-range check).  Files
        failing any check count as erasures; as long as they total at most
        R, the survivors rebuild them bitwise via the streamed
        decentralized rebuild (`CodedSystem.rebuild_stream` driven off the
        survivor memmaps — no full-width stack is ever materialized), each
        rebuilt file is re-verified against its recorded checksum, and the
        replacement is atomic per file.  Returns a report dict:

            {"step", "checked", "missing", "corrupt", "rebuilt",
             "verified"}
        """
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(
                    f"no checkpoints under {self.directory}")
        d = Path(self.directory) / f"step_{step:06d}"
        meta = json.loads((d / "meta.json").read_text())
        N, R, q = meta["N"], meta["R"], int(meta.get("q", self.field.q))
        sums: dict = meta.get("sha256", {})
        sym = -(-meta["nbytes"] // 2)
        L = -(-sym // N) if sym else 0

        def _name(i: int) -> str:
            return (f"shard_{i:03d}" if i < N else f"parity_{i - N:03d}")

        missing: list[int] = []
        corrupt: list[int] = []
        for i in range(N + R):
            path = d / (_name(i) + ".npy")
            if not path.exists():
                missing.append(i)
                continue
            try:
                mm = np.load(path, mmap_mode="r")
            except Exception:  # noqa: BLE001 — unparseable container
                corrupt.append(i)
                continue
            if mm.shape != (L,) or mm.dtype != np.uint32:
                corrupt.append(i)
                continue
            expected = sums.get(_name(i))
            if expected is not None:
                h = hashlib.sha256()
                for c0 in range(0, L, 1 << 20):
                    h.update(np.ascontiguousarray(
                        mm[c0 : c0 + (1 << 20)]).tobytes())
                if h.hexdigest() != expected:
                    corrupt.append(i)
            elif L and int(np.max(mm)) >= q:
                corrupt.append(i)  # pre-checksum checkpoint: range check
        erased = sorted(missing + corrupt)
        report = {"step": step, "checked": N + R, "missing": missing,
                  "corrupt": corrupt, "rebuilt": erased, "verified": True}
        if not erased:
            return report
        if len(erased) > R:
            raise RuntimeError(
                f"scrub: {len(erased)} missing/corrupt files exceed the "
                f"code's R={R} — the checkpoint is unrecoverable "
                f"(missing={missing}, corrupt={corrupt})")

        if L == 0:
            for e in erased:
                np.save(d / (_name(e) + ".npy"), np.zeros(0, np.uint32))
            return report

        spec = CodeSpec(kind="rs", K=N, R=R, q=q)
        rsys = CodedSystem(
            spec, backend="local" if q == FERMAT.q else "simulator",
            chunk_w=self.chunk_w)
        rsys.fail(erased)
        kept = rsys.decode_plan.kept
        srcs = {i: np.load(d / (_name(i) + ".npy"), mmap_mode="r")
                for i in kept}
        from ..api.stream import default_chunk_w

        cw = self.chunk_w or default_chunk_w(N)
        hs = {e: hashlib.sha256() for e in erased}
        try:
            tmps = {e: np.lib.format.open_memmap(
                        d / f".scrub_{_name(e)}.npy", mode="w+",
                        dtype=np.uint32, shape=(L,))
                    for e in erased}

            def survivor_chunks():
                for c0 in range(0, L, cw):
                    yield np.stack([np.asarray(srcs[i][c0 : c0 + cw],
                                               np.int64)
                                    for i in kept])

            col = 0
            for healed in rsys.rebuild_stream(survivor_chunks()):
                w = healed.shape[1]
                for e in erased:
                    row = healed[e].astype(np.uint32)
                    tmps[e][col : col + w] = row
                    hs[e].update(row.tobytes())
                col += w
            assert col == L
            for e in erased:
                tmps[e].flush()
            del tmps
            # verify EVERY rebuilt payload before replacing ANY file: a
            # checksum mismatch must leave the checkpoint untouched
            for e in erased:
                expected = sums.get(_name(e))
                if expected is not None and hs[e].hexdigest() != expected:
                    report["verified"] = False
                    raise RuntimeError(
                        f"scrub: rebuilt {_name(e)} does not match its "
                        "recorded checksum — survivors are inconsistent "
                        "(more corruption than the parity can localize?)")
            for e in erased:
                os.replace(d / f".scrub_{_name(e)}.npy",
                           d / (_name(e) + ".npy"))
        finally:
            # never strand .scrub_* temps on a failed rebuild/verify
            for e in erased:
                (d / f".scrub_{_name(e)}.npy").unlink(missing_ok=True)
        return report

    def reshard(self, step: int, new_n: int, new_r: int) -> "CodedCheckpointer":
        """Elastic rescale: rewrite step with a different (N, R) layout."""
        d = Path(self.directory) / f"step_{step:06d}"
        meta = json.loads((d / "meta.json").read_text())
        shards = np.stack([np.load(d / f"shard_{k:03d}.npy").astype(np.int64)
                           for k in range(meta["N"])])
        sym = shards.reshape(-1)[: -(-meta["nbytes"] // 2)]
        raw = symbols_to_bytes(sym, meta["nbytes"])
        new = CodedCheckpointer(self.directory + f"_n{new_n}", new_n, new_r,
                                self.field)
        nshards = new.shard_symbols(raw)
        parity = new.encode_parity(nshards)
        final = Path(new.directory) / f"step_{meta['step']:06d}"
        final.mkdir(parents=True, exist_ok=True)
        meta2 = dict(meta, N=new_n, R=new_r)
        (final / "meta.json").write_text(json.dumps(meta2))
        for k in range(new_n):
            np.save(final / f"shard_{k:03d}.npy", nshards[k].astype(np.uint32))
        for r in range(new_r):
            np.save(final / f"parity_{r:03d}.npy", parity[r].astype(np.uint32))
        return new
