"""Analytic communication-cost model (Table I + Sec. III theorems) and
literature baselines for comparison.

All costs are (C1, C2) pairs in (rounds, field elements); the scalar cost is
C = alpha*C1 + beta*ceil(log2 q)*C2*W for W-element payload vectors.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from .collectives import cost_broadcast
from .dft_a2a import cost_dft
from .draw_loose import cost_draw_loose
from .prepare_shoot import cost_universal


@dataclass(frozen=True)
class LinearCost:
    """C = alpha*C1 + beta_bits*C2 (beta_bits = beta * ceil(log2 q))."""

    C1: int
    C2: int

    def total(self, alpha: float, beta_bits: float, W: int = 1) -> float:
        return alpha * self.C1 + beta_bits * self.C2 * W

    def __add__(self, other: "LinearCost") -> "LinearCost":
        return LinearCost(self.C1 + other.C1, self.C2 + other.C2)


def universal(K: int, p: int) -> LinearCost:
    return LinearCost(*cost_universal(K, p))


def dft(K: int, P: int, p: int) -> LinearCost:
    return LinearCost(*cost_dft(K, P, p))


def vandermonde(sp, p: int) -> LinearCost:
    return LinearCost(*cost_draw_loose(sp, p))


def broadcast(N: int, p: int, W: int = 1) -> LinearCost:
    return LinearCost(*cost_broadcast(N, p, W))


def framework(K: int, R: int, p: int, a2a: LinearCost, W: int = 1) -> LinearCost:
    """Thm. 1 / Thm. 2: phase-one A2A (parallel, max over blocks) + phase-two
    broadcast-or-reduce over the ceil(max/min) grid dimension."""
    M = math.ceil(max(K, R) / min(K, R))
    br = broadcast(M + 1, p, W)
    return LinearCost(a2a.C1 + br.C1, a2a.C2 * W + br.C2)


# ---------------------------------------------------------------------------
# Baselines from the literature (Sec. II)
# ---------------------------------------------------------------------------

def gather_encode_scatter(K: int, R: int, p: int, W: int = 1) -> LinearCost:
    """Centralized strawman: gather all K payloads at one processor
    ((p+1)-nomial gather: log rounds, ~K/p elements through the root's
    ports), encode locally, then send each of R sinks its packet."""
    t_gather = math.ceil(math.log(K, p + 1)) if K > 1 else 0
    c2_gather = math.ceil((K - 1) / p) * W
    t_scatter = math.ceil(R / p)
    c2_scatter = math.ceil(R / p) * W
    return LinearCost(t_gather + t_scatter, c2_gather + c2_scatter)


def multireduce_jeong(K: int, R: int, p: int, W: int = 1) -> LinearCost:
    """Multi-reduce of Jeong et al. [21] (one-port, R | K): per Sec. II it
    incurs (R - 2*sqrt(R) - 1) * beta*log2(q)*W more traffic than our
    framework-with-universal-A2A solution; C1 comparable."""
    assert p == 1, "multi-reduce is defined for the one-port model"
    ours = framework(K, R, p, universal(min(K, R), p), W)
    extra = max(0.0, (R - 2 * math.sqrt(R) - 1)) * W
    return LinearCost(ours.C1, int(round(ours.C2 + extra)))


def lower_bound_c2(K: int, p: int) -> float:
    """Lemma 2: C2 >= sqrt(2K)/p - O(1) for any universal algorithm."""
    return math.sqrt(2 * K) / p - (1 - 1 / p + 0.5)


def lower_bound_c1(K: int, p: int) -> int:
    """Lemma 1: C1 >= ceil(log_{p+1} K)."""
    return math.ceil(math.log(K, p + 1)) if K > 1 else 0


def summary_table(K: int, p: int) -> dict[str, tuple[int, int]]:
    """Table I for a given K (when the specific algorithms apply)."""
    from .matrices import StructuredPoints
    from .field import FERMAT

    out = {"universal": cost_universal(K, p)}
    if K & (K - 1) == 0:  # power of two: DFT applies over F_65537
        out["dft(P=2)"] = cost_dft(K, 2, p)
    try:
        sp = StructuredPoints.build(FERMAT, K, P=2)
        out["vandermonde"] = cost_draw_loose(sp, p)
    except ValueError:
        pass
    return out
