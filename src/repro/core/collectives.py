"""(p+1)-nomial tree one-to-all broadcast and all-to-one reduce (Defs. 2-3,
Appendix A).  Cost: C_BR(N, W) = ceil(log_{p+1} N) rounds of W-element
messages.  Reduce is the dual of broadcast (reversed communication order).
"""
from __future__ import annotations

import math

import numpy as np

from .field import Field
from .simulator import Msg


def _n_rounds(N: int, p: int) -> int:
    if N <= 1:
        return 0
    T = math.ceil(math.log(N, p + 1))
    while (p + 1) ** T < N:
        T += 1
    while T > 1 and (p + 1) ** (T - 1) >= N:
        T -= 1
    return T


def broadcast(
    field: Field,
    value: np.ndarray,
    procs: list[int],
    p: int,
    out: dict[int, np.ndarray],
):
    """Root procs[0] disseminates `value` to every processor in `procs`."""
    N = len(procs)
    W = int(np.asarray(value).size)
    T = _n_rounds(N, p)
    have = {0}
    for t in range(1, T + 1):
        stride = (p + 1) ** (T - t)
        msgs, new = [], set()
        for i in sorted(have):
            for rho in range(1, p + 1):
                j = i + rho * stride
                if j < N and j not in have and j not in new:
                    msgs.append(Msg(procs[i], procs[j], W))
                    new.add(j)
        yield msgs
        have |= new
    assert have == set(range(N))
    for i in range(N):
        out[procs[i]] = field.arr(value)


def reduce(
    field: Field,
    values: dict[int, np.ndarray],
    procs: list[int],
    p: int,
    out: dict[int, np.ndarray],
):
    """All-to-one sum-reduce onto root procs[0] (dual of broadcast)."""
    N = len(procs)
    acc = {i: field.arr(values[procs[i]]) for i in range(N)}
    W = int(np.asarray(acc[0]).size)
    T = _n_rounds(N, p)
    # replay broadcast rounds in reverse: receivers become senders
    plan: list[list[tuple[int, int]]] = []
    have = {0}
    for t in range(1, T + 1):
        stride = (p + 1) ** (T - t)
        edges, new = [], set()
        for i in sorted(have):
            for rho in range(1, p + 1):
                j = i + rho * stride
                if j < N and j not in have and j not in new:
                    edges.append((i, j))
                    new.add(j)
        plan.append(edges)
        have |= new
    for edges in reversed(plan):
        msgs = [Msg(procs[j], procs[i], W) for (i, j) in edges]
        yield msgs
        for (i, j) in edges:
            acc[i] = field.add(acc[i], acc[j])
    out[procs[0]] = acc[0]


def cost_broadcast(N: int, p: int, W: int = 1) -> tuple[int, int]:
    T = _n_rounds(N, p)
    return T, T * W
