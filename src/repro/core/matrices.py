"""Coding matrices used throughout the paper.

Everything is exact numpy int64 over a prime field (`core.field.Field`).
These constructions follow Sec. V/VI of the paper:

* Vandermonde `V[i, j] = alpha_j ** i`
* DFT matrix `D_K` (eq. 8) and its column permutation `D_K @ P` with
  `P[k, rev(k)] = 1` (digit reversal base P)
* generalized Reed-Solomon generator (eq. 22), its systematic form
  `A = (V_alpha P)^-1 V_beta Q` (eq. 23) and the equivalent Cauchy-like
  closed form (eq. 24)
* Lagrange matrices `L = V_alpha^-1 V_beta` (Remark 9)
* structured evaluation-point sets `omega_{i,j} = g^{phi(i)} * zeta^{rev(j)}`
  (eq. 15) that make draw-and-loose (and hence RS/Lagrange specific
  algorithms) applicable.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .field import Field


def digits(k: int, base: int, width: int) -> list[int]:
    """Base-`base` digits of k, least significant first, padded to `width`."""
    out = []
    for _ in range(width):
        out.append(k % base)
        k //= base
    return out


def digit_reverse(k: int, base: int, width: int) -> int:
    """Reverse the base-`base` digit string of k (paper eq. 7)."""
    ds = digits(k, base, width)
    out = 0
    for d in ds:  # least-significant digit becomes most-significant
        out = out * base + d
    return out


def vandermonde(field: Field, points, nrows: int | None = None) -> np.ndarray:
    """V[i, j] = points[j]^i, shape (nrows, len(points))."""
    points = field.arr(points)
    n = nrows if nrows is not None else points.size
    v = np.ones((n, points.size), np.int64)
    for i in range(1, n):
        v[i] = field.mul(v[i - 1], points)
    return v


def gauss_inverse(field: Field, a: np.ndarray) -> np.ndarray:
    """Exact matrix inverse over F_q via Gauss-Jordan elimination."""
    a = field.arr(a).copy()
    n = a.shape[0]
    assert a.shape == (n, n)
    inv = np.eye(n, dtype=np.int64)
    for col in range(n):
        piv = col + int(np.nonzero(a[col:, col])[0][0])
        if piv != col:
            a[[col, piv]] = a[[piv, col]]
            inv[[col, piv]] = inv[[piv, col]]
        scale = field.inv(a[col, col])
        a[col] = field.mul(a[col], scale)
        inv[col] = field.mul(inv[col], scale)
        for row in range(n):
            if row != col and a[row, col] != 0:
                f = a[row, col]
                a[row] = field.sub(a[row], field.mul(f, a[col]))
                inv[row] = field.sub(inv[row], field.mul(f, inv[col]))
    return inv


def dft_matrix(field: Field, K: int) -> np.ndarray:
    """D_K (eq. 8): Vandermonde at beta^k, beta = primitive K-th root."""
    beta = field.root_of_unity(K)
    points = np.array([pow(beta, k, field.q) for k in range(K)], np.int64)
    return vandermonde(field, points)


def permuted_dft_matrix(field: Field, K: int, P: int) -> np.ndarray:
    """D_K @ Pi where Pi[k, rev_P(k)] = 1: column k' of D_K lands at rev(k')."""
    H = round(np.log(K) / np.log(P))
    assert P**H == K, f"K={K} must equal P^H"
    d = dft_matrix(field, K)
    out = np.zeros_like(d)
    for k in range(K):
        out[:, digit_reverse(k, P, H)] = d[:, k]
    return out


# ---------------------------------------------------------------------------
# Structured evaluation points for draw-and-loose (Sec. V-B, eq. 15)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class StructuredPoints:
    """Evaluation points omega_{i,j} = alpha_i * zeta^{rev(j)} on an M x Z grid.

    Z = P^H divides q-1; alpha_i = g^{phi(i)} with phi injective into
    [0, (q-1)/Z): guarantees all K = M*Z points are distinct (footnote 3).
    Processor k = i*Z + j holds grid cell (row i, col j).
    """

    field: Field
    M: int
    P: int
    H: int
    phi: tuple[int, ...]  # injective map [0,M) -> [0,(q-1)/Z)

    @property
    def Z(self) -> int:
        return self.P**self.H

    @property
    def K(self) -> int:
        return self.M * self.Z

    @property
    def zeta(self) -> int:
        """Primitive Z-th root of unity g^((q-1)/Z)."""
        return self.field.root_of_unity(self.Z) if self.Z > 1 else 1

    def alpha(self, i: int) -> int:
        return int(pow(self.field.generator, self.phi[i], self.field.q))

    def omega(self, i: int, j: int) -> int:
        jr = digit_reverse(j, self.P, self.H)
        return int(self.field.mul(self.alpha(i), pow(self.zeta, jr, self.field.q)))

    def points(self) -> np.ndarray:
        """All K points; index k = i*Z + j."""
        return np.array(
            [self.omega(k // self.Z, k % self.Z) for k in range(self.K)], np.int64
        )

    @staticmethod
    def build(
        field: Field, K: int, P: int = 2, phi_offset: int = 0,
        max_h: int | None = None,
    ) -> "StructuredPoints":
        """Factor K = M * P^H with H maximal s.t. P^H | gcd(K, q-1)
        (optionally capped at max_h)."""
        H = 0
        z = 1
        qm1 = field.q - 1
        while K % (z * P) == 0 and qm1 % (z * P) == 0 and (max_h is None or H < max_h):
            z *= P
            H += 1
        M = K // z
        if M > qm1 // z:
            raise ValueError(f"cannot place M={M} rows into (q-1)/Z={qm1 // z} cosets")
        phi = tuple(phi_offset + i for i in range(M))
        if phi[-1] >= qm1 // z:
            raise ValueError("phi not injective into [0,(q-1)/Z)")
        return StructuredPoints(field, M, P, H, phi)


# ---------------------------------------------------------------------------
# Reed-Solomon / Lagrange constructions (Sec. VI)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SystematicGRS:
    """[N=K+R, K] generalized RS code, eq. (22)-(24).

    alphas (K) and betas (R) are distinct; u (K), v (R) nonzero multipliers.
    `A` is the K x R non-systematic part of G = [I | A].
    """

    field: Field
    alphas: np.ndarray
    betas: np.ndarray
    u: np.ndarray
    v: np.ndarray

    def __post_init__(self):
        pts = np.concatenate([self.alphas, self.betas])
        assert len(set(pts.tolist())) == pts.size, "evaluation points must be distinct"
        assert np.all(self.u % self.field.q != 0) and np.all(self.v % self.field.q != 0)

    @property
    def K(self) -> int:
        return self.alphas.size

    @property
    def R(self) -> int:
        return self.betas.size

    def A_direct(self) -> np.ndarray:
        """A = (V_alpha P)^-1 V_beta Q by explicit inversion (eq. 23)."""
        f = self.field
        va = vandermonde(f, self.alphas)
        vb = vandermonde(f, self.betas, nrows=self.K)
        # V_a P scales column k of V_a by u_k => (V_a P)^-1 = P^-1 V_a^-1
        lhs = f.matmul(np.diag(f.inv(self.u)), gauss_inverse(f, va))
        return f.matmul(f.matmul(lhs, vb), np.diag(f.arr(self.v)))

    def A_cauchy(self) -> np.ndarray:
        """Closed form eq. (24): A[k,r] = c_k d_r / (beta_r - alpha_k)."""
        f = self.field
        K, R = self.K, self.R
        c = np.zeros(K, np.int64)
        for k in range(K):
            diffs = f.sub(self.alphas[k], np.delete(self.alphas, k))
            c[k] = f.mul(f.inv(self.u[k]), f.inv(_prod(f, diffs)))
        d = np.zeros(R, np.int64)
        for r in range(R):
            d[r] = f.mul(self.v[r], _prod(f, f.sub(self.betas[r], self.alphas)))
        denom = f.sub(self.betas[None, :], self.alphas[:, None])
        return f.mul(f.mul(c[:, None], d[None, :]), f.inv(denom))

    def encode(self, x: np.ndarray) -> np.ndarray:
        """x: (K, W) -> parity (R, W) = A^T-applied combination (Def. 1)."""
        return self.field.matmul(self.A_direct().T, x)

    # -- Thm. 6 block decomposition helpers (case K >= R, K = M*R) ----------
    def block_decomposition(self, m: int) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Return (alphas_m, phi_m, psi_m, A_m) for block m (Thm. 6).

        A_m = (V_{alpha,m} Phi_m)^-1 V_beta Psi_m, all R x R.
        """
        f = self.field
        R = self.R
        sel = np.arange(m * R, (m + 1) * R)
        a_m = self.alphas[sel]
        others = np.delete(self.alphas, sel)
        phi = np.zeros(R, np.int64)
        psi = np.zeros(R, np.int64)
        for s in range(R):
            phi[s] = f.mul(self.u[m * R + s], _prod(f, f.sub(a_m[s], others)))
            psi[s] = f.mul(self.v[s], _prod(f, f.sub(self.betas[s], others)))
        va_m = vandermonde(f, a_m)
        vb = vandermonde(f, self.betas)
        A_m = f.matmul(
            f.matmul(np.diag(f.inv(phi)), gauss_inverse(f, va_m)),
            f.matmul(vb, np.diag(psi)),
        )
        return a_m, phi, psi, A_m


def _prod(field: Field, xs: np.ndarray) -> int:
    out = np.int64(1)
    for x in np.asarray(xs, np.int64).ravel():
        out = (out * (int(x) % field.q)) % field.q
    return np.int64(out)


def lagrange_matrix(field: Field, alphas, betas) -> np.ndarray:
    """L = V_alpha^-1 V_beta (Remark 9): Cauchy-like with u = v = 1."""
    alphas = field.arr(alphas)
    betas = field.arr(betas)
    va = vandermonde(field, alphas)
    vb = vandermonde(field, betas, nrows=alphas.size)
    return field.matmul(gauss_inverse(field, va), vb)


def structured_grs(field: Field, K: int, R: int, P: int = 2) -> SystematicGRS:
    """A systematic GRS code whose alpha and beta points are *both* structured
    (draw-and-loose applicable): alphas from StructuredPoints at phi offset 0,
    betas at a disjoint offset. Requires the two grids not to collide.
    """
    blk = max(K, R) if (max(K, R) % min(K, R) == 0) else K
    # points for sources: organized for blocks of size R (K>=R) or K (K<R)
    size_a, size_b = K, R
    spa = StructuredPoints.build(field, size_a, P=P, phi_offset=0)
    # offset beta grid beyond alpha grid rows to keep cosets disjoint
    spb = StructuredPoints.build(field, size_b, P=P, phi_offset=spa.M)
    alphas, betas = spa.points(), spb.points()
    both = np.concatenate([alphas, betas])
    if len(set(both.tolist())) != both.size:
        raise ValueError("structured point sets collide; pick different offsets")
    ones_k = np.ones(K, np.int64)
    ones_r = np.ones(R, np.int64)
    return SystematicGRS(field, alphas, betas, ones_k, ones_r)
