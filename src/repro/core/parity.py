"""Mesh parity encode: Sec. III-A framework across a device axis.

N devices each hold one state shard x_k (k = device index); R parity symbols
of the systematic [N+R, N] GRS code must land on devices 0..R-1 (which also
keep their own data shards — rotating-parity style double duty; any f <= R/2
device failures erase at most 2f codeword symbols and remain decodable;
with parity *offloaded to a checkpoint store* any R erasures are decodable).

Phase 1 — column-wise all-to-all encode: devices form an R x M grid
(column m = devices [mR, (m+1)R), M = N/R); each column computes its R x R
block A_m of A.  Implemented either with the universal prepare-and-shoot
tables ('universal') or the Thm. 7 Cauchy-like pipeline ('rs':
scale phi^-1 -> inverse draw-and-loose on V_{alpha,m} -> forward
draw-and-loose on V_beta -> scale psi).

Phase 2 — row-wise (p+1)-nomial reduce onto the column-0 device of each row.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from .cauchy import StructuredGRS
from .field import FERMAT_Q, Field, fermat_add, fermat_mul
from .matrices import StructuredPoints, gauss_inverse
from .shardmap_exec import (
    DFTTables,
    UniversalTables,
    _group_perm,
    _ppermute,
    _v_m_matrix,
    build_dft_tables,
    build_universal_tables,
    mesh_dft,
    mesh_universal_a2a,
)


@dataclass(frozen=True)
class ParityTables:
    """Everything the jitted parity-encode step needs, precomputed host-side.

    `sgrs` is None when the tables were built from an arbitrary (non-GRS)
    generator block via `build_encode_tables(..., method="universal")`.
    """

    N: int
    R: int
    M: int
    p: int
    method: str
    sgrs: StructuredGRS | None
    # universal path
    univ: UniversalTables | None
    # rs path: inverse DL on alpha blocks + forward DL on beta
    dl_scale_pre: np.ndarray | None    # (N,) phi^-1
    dl_inv_univ: UniversalTables | None
    dl_inv_dft: DFTTables | None
    dl_inv_scale: np.ndarray | None
    dl_fwd_univ: UniversalTables | None
    dl_fwd_dft: DFTTables | None
    dl_fwd_scale: np.ndarray | None
    dl_scale_post: np.ndarray | None   # (N,) psi
    reduce_mask: np.ndarray            # (T_red, p, N) uint32

    def device_arrays(self) -> dict[str, np.ndarray]:
        """Arrays to pass as sharded (axis-partitioned) step inputs."""
        out = {"reduce_mask": np.moveaxis(self.reduce_mask, -1, 0)}  # (N, T, p)
        if self.method == "universal":
            out["u_coef"] = self.univ.coef
            out["u_corr"] = self.univ.corr
        else:
            out["pre"] = self.dl_scale_pre
            out["post"] = self.dl_scale_post
            out["i_scale"] = self.dl_inv_scale
            out["f_scale"] = self.dl_fwd_scale
            if self.dl_inv_univ is not None:
                out["i_coef"] = self.dl_inv_univ.coef
                out["i_corr"] = self.dl_inv_univ.corr
            if self.dl_inv_dft is not None:
                out["i_ca"] = self.dl_inv_dft.ca.T  # (N, H)
                out["i_cb"] = self.dl_inv_dft.cb.T
            if self.dl_fwd_univ is not None:
                out["f_coef"] = self.dl_fwd_univ.coef
                out["f_corr"] = self.dl_fwd_univ.corr
            if self.dl_fwd_dft is not None:
                out["f_ca"] = self.dl_fwd_dft.ca.T
                out["f_cb"] = self.dl_fwd_dft.cb.T
        return out


def _build_grid_draw_loose(
    field: Field,
    sps: list[StructuredPoints],
    p: int,
    inverse: bool,
) -> tuple[UniversalTables | None, DFTTables | None, np.ndarray]:
    """Draw-and-loose tables for several grids along the axis, one
    StructuredPoints per grid (they must share M, Z, P)."""
    sp0 = sps[0]
    M, Z = sp0.M, sp0.Z
    K = M * Z
    N = len(sps) * K
    univ = None
    if M > 1:
        mats = []
        # group id for (grid g, column j) = g*Z + j
        for g in range(len(sps)):
            vm = _v_m_matrix(field, sps[g])
            if inverse:
                vm = gauss_inverse(field, vm)
            mats.extend([vm] * Z)
        univ = build_universal_tables(field, mats, N, p, group_stride=Z)
    dft = None
    if Z > 1:
        dft = build_dft_tables(field, N, Z, group_stride=1, inverse=inverse)
    scale = np.zeros(N, np.uint32)
    for dev in range(N):
        g, k = dev // K, dev % K
        i, j = k // Z, k % Z
        s = pow(sps[g].alpha(i), j, field.q)
        if inverse:
            s = pow(s, field.q - 2, field.q)
        scale[dev] = s
    return univ, dft, scale


def build_parity_tables(
    field: Field, N: int, R: int, p: int = 1, method: str = "rs"
) -> ParityTables:
    """Systematic [N+R, N] GRS parity across an N-device axis, R | N."""
    sgrs = StructuredGRS.build(field, N, R, P=2)
    return build_encode_tables(field, sgrs.grs.A_direct(), p=p, method=method,
                               sgrs=sgrs)


def build_encode_tables(
    field: Field,
    A: np.ndarray,
    p: int = 1,
    method: str = "universal",
    sgrs: StructuredGRS | None = None,
) -> ParityTables:
    """Mesh-encode tables for an arbitrary (K, R) generator block A, R | K.

    The K devices of the axis hold the sources; sink r overlays device r
    (Sec. III-A with borrowed sinks).  method="universal" works for ANY A;
    method="rs" additionally needs the StructuredGRS code A came from
    (Thm. 7 factorization).  This is the single table builder behind both
    `build_parity_tables` and the unified `repro.api` mesh backend.
    """
    A = field.arr(A)
    N, R = A.shape
    assert N % R == 0, "R must divide the axis size"
    M = N // R

    univ = None
    pre = post = i_scale = f_scale = None
    i_univ = i_dft = f_univ = f_dft = None
    if method == "universal":
        mats = [A[m * R : (m + 1) * R, :] for m in range(M)]
        univ = build_universal_tables(field, mats, N, p, group_stride=1)
    elif method == "rs":
        assert sgrs is not None and sgrs.K == N and sgrs.R == R, \
            "method='rs' needs the StructuredGRS code A was built from"
        pre = np.zeros(N, np.uint32)
        post = np.zeros(N, np.uint32)
        for m in range(M):
            phi, psi = sgrs.scaling_factors(m)
            for s in range(R):
                pre[m * R + s] = pow(int(phi[s]), field.q - 2, field.q)
                post[m * R + s] = int(psi[s])
        i_univ, i_dft, i_scale = _build_grid_draw_loose(
            field, list(sgrs.alpha_blocks), p, inverse=True
        )
        f_univ, f_dft, f_scale = _build_grid_draw_loose(
            field, [sgrs.beta_blocks[0]] * M, p, inverse=False
        )
    else:
        raise ValueError(method)

    # phase-2 reduce masks: rows = {r, r+R, ...}, reduce onto position 0
    T_red = max(1, math.ceil(math.log(M, p + 1))) if M > 1 else 0
    mask = np.zeros((T_red, p, N), np.uint32)
    for t in range(1, T_red + 1):
        blk = (p + 1) ** t
        sub = (p + 1) ** (t - 1)
        for dev in range(N):
            j = dev // R  # position within the row group (stride R)
            for rho in range(1, p + 1):
                if j % blk == 0 and (j + rho * sub) < M:
                    mask[t - 1, rho - 1, dev] = 1
    return ParityTables(
        N, R, M, p, method, sgrs, univ,
        pre, i_univ, i_dft, i_scale, f_univ, f_dft, f_scale, post, mask,
    )


def mesh_parity_encode(x, rows: dict, t: ParityTables, axis_name: str):
    """shard_map body: x (W,) uint32 -> (W,) where devices 0..R-1 end up
    holding parity symbols 0..R-1 (other devices return partial garbage that
    callers mask out)."""
    v = x.astype(jnp.uint32)

    # ---- phase 1: column-wise A2A on A_m ---------------------------------
    if t.method == "universal":
        v = mesh_universal_a2a(v, rows["u_coef"], rows["u_corr"], t.univ, axis_name)
    else:
        v = fermat_mul(rows["pre"], v)
        # inverse draw-and-loose on V_{alpha,m}
        if t.dl_inv_dft is not None:
            v = mesh_dft(v, rows["i_ca"], rows["i_cb"], t.dl_inv_dft, axis_name, inverse=True)
        v = fermat_mul(rows["i_scale"], v)
        if t.dl_inv_univ is not None:
            v = mesh_universal_a2a(v, rows["i_coef"], rows["i_corr"], t.dl_inv_univ, axis_name)
        # forward draw-and-loose on V_beta
        if t.dl_fwd_univ is not None:
            v = mesh_universal_a2a(v, rows["f_coef"], rows["f_corr"], t.dl_fwd_univ, axis_name)
        v = fermat_mul(rows["f_scale"], v)
        if t.dl_fwd_dft is not None:
            v = mesh_dft(v, rows["f_ca"], rows["f_cb"], t.dl_fwd_dft, axis_name, inverse=False)
        v = fermat_mul(rows["post"], v)

    # ---- phase 2: row-wise reduce onto column 0 ---------------------------
    N, R, M, p = t.N, t.R, t.M, t.p
    T_red = t.reduce_mask.shape[0]
    for tt in range(1, T_red + 1):
        sub = (p + 1) ** (tt - 1)
        for rho in range(1, p + 1):
            perm = _group_perm(N, R, M, -rho * sub)
            recv = _ppermute(v, axis_name, perm)
            m_row = rows["reduce_mask"][tt - 1, rho - 1]
            v = fermat_add(v, fermat_mul(m_row, recv))
    return v


def reconstruct(field: Field, sgrs: StructuredGRS, kept: np.ndarray, vals: np.ndarray) -> np.ndarray:
    """Any-K-of-N decode: kept (K,) codeword indices, vals (K, W) symbols.

    For the Fermat field the solve runs on the `kernels.gf_solve` path
    (uint32 Gauss-Jordan inverse + Pallas/jnp matmul application); other
    fields keep the exact numpy host path.  Both are exact mod q, so the
    result is bitwise identical either way.
    """
    K = sgrs.K
    A = sgrs.grs.A_direct()
    G = np.concatenate([np.eye(K, dtype=np.int64), A], axis=1)
    sub = G[:, kept]  # K x K
    if field.q == FERMAT_Q:
        from ..kernels.gf_solve import gf_solve

        return np.asarray(gf_solve(sub.T % FERMAT_Q, field.arr(vals)), np.int64)
    return field.matmul(gauss_inverse(field, sub.T), field.arr(vals))
