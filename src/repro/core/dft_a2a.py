"""Specific all-to-all encode for (permuted) DFT matrices (Sec. V-A).

For K = P^H with K | q-1, computes x * (D_K @ Pi) where Pi is the base-P
digit-reversal column permutation: processor P_k ends with f(beta^{k'}),
k' = digit_reverse(k).  H stages; stage h runs K/P parallel P-sized all-to-all
encodes (prepare-and-shoot) on the Vandermonde matrices A_k^{(h)} of eq. (14),
whose points are the gamma tree elements of eq. (9)-(10).

Cost: H * C_univ(P)  (Thm. 4); when P = p+1 each stage is a single round of
1-element messages, so C = H * (alpha + beta*log2 q) — strictly optimal
(Cor. 1).  The algorithm is invertible stage-by-stage (Lemma 5).
"""
from __future__ import annotations

import numpy as np

from .field import Field
from .matrices import gauss_inverse, vandermonde
from .prepare_shoot import cost_universal, prepare_shoot
from .simulator import run_lockstep


def _stage_groups(K: int, P: int, H: int, h: int):
    """Groups for stage h (0-indexed): members differ in k-digit (H-h), i.e.
    position P^(H-h-1); the top h digits of k form the shared gamma prefix."""
    pos = P ** (H - h - 1)
    groups = []
    for base in range(K):
        if (base // pos) % P != 0:
            continue
        members = [base + rho * pos for rho in range(P)]
        groups.append(members)
    return groups


def _stage_matrix(field: Field, K: int, P: int, H: int, h: int, member0: int) -> np.ndarray:
    """A^{(h)} of eq. (14) for the group containing `member0`.

    gamma_rho = beta^((rho*P^h + prefix) * K / P^(h+1)), prefix = value of the
    top h digits of k read as the low digits of k' (eq. 9).
    """
    beta = field.root_of_unity(K)
    # top h digits of k (shared in group) -> k'_1..k'_h (low digits of k')
    prefix = 0
    kk = member0 // (P ** (H - h))  # top h digits as an integer, MSD..(H-h+1)
    # k digits at positions H, H-1, ..., H-h+1 (1-indexed LSF) map to
    # k'_1, k'_2, ..., k'_h: prefix = sum_j k'_j P^(j-1)
    top_digits = []
    for _ in range(h):
        top_digits.append(kk % P)
        kk //= P
    # top_digits[0] = digit H-h+1 of k = k'_h, ..., top_digits[h-1] = digit H = k'_1
    for j, d in enumerate(reversed(top_digits)):  # now k'_1 first
        prefix += d * P**j
    exp_scale = K // P ** (h + 1)
    gammas = [pow(beta, (rho * P**h + prefix) * exp_scale, field.q) for rho in range(P)]
    return vandermonde(field, np.array(gammas, np.int64))


def dft_a2a(
    field: Field,
    x: dict[int, np.ndarray],
    procs: list[int],
    p: int,
    P: int,
    out: dict[int, np.ndarray],
    inverse: bool = False,
):
    """Generator schedule: out[g] = (x * D'_K)[local index of g], D'_K = D_K Pi.

    With inverse=True computes x * D'_K^{-1} (Lemma 5).
    """
    K = len(procs)
    H = 0
    while P**H < K:
        H += 1
    assert P**H == K, f"K={K} must be a power of P={P}"
    assert (field.q - 1) % K == 0, "needs K | q-1"

    vals = {k: field.arr(x[procs[k]]) for k in range(K)}
    stages = range(H - 1, -1, -1) if inverse else range(H)
    for h in stages:
        groups = _stage_groups(K, P, H, h)
        gens = []
        stage_out: dict[int, np.ndarray] = {}
        for members in groups:
            mat = _stage_matrix(field, K, P, H, h, members[0])
            if inverse:
                mat = gauss_inverse(field, mat)
            gx = {procs[m]: vals[m] for m in members}
            gens.append(
                prepare_shoot(field, mat, gx, [procs[m] for m in members], p, stage_out)
            )
        yield from run_lockstep(*gens)
        for k in range(K):
            vals[k] = stage_out[procs[k]]
    for k in range(K):
        out[procs[k]] = vals[k]


def cost_dft(K: int, P: int, p: int) -> tuple[int, int]:
    """(C1, C2) of the DFT-specific algorithm (Thm. 4): H * C_univ(P)."""
    H = 0
    while P**H < K:
        H += 1
    c1, c2 = cost_universal(P, p)
    return H * c1, H * c2
