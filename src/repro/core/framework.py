"""The general decentralized-encoding framework (Sec. III + Appendix B).

Global processor ids: sources S_k = k (k in [0, K)), sinks T_r = K + r
(r in [0, R)).  Given the non-systematic part A (K x R) of G = [I | A] and
source payloads x (K, W), every sink T_r must obtain x^T A[:, r].

Case K >= R (Sec. III-A): sources form an R x M grid (M = ceil(K/R), position
k = r + m*R at row r / column m); sinks are borrowed (holding 0) to pad the
last column.  Phase 1: M parallel column-wise A2As on the R x R blocks A'_m;
phase 2: R parallel row-wise all-to-one reduces into each sink.

Case K < R (Sec. III-B): sinks form a K x M grid (M = ceil(R/K)); sources are
appended as an extra column and borrowed to pad unfilled rows.  Phase 1: K
parallel row-wise broadcasts of x_k; phase 2: M parallel column-wise A2As on
the K x K blocks A'_m.

Appendix B (non-systematic G, K x N): sinks hold 0 and the system runs one
big A2A on the padded square G' (case K > R), or row-broadcasts + column A2As
on padded square blocks (case K <= R).

The per-block A2A is pluggable: 'universal' (prepare-and-shoot, any A) or
'rs' (Cauchy-like two-phase draw-and-loose, Thm. 7/9 — requires a
StructuredGRS).

The planners no longer drive these generators directly: `core.schedule`'s
builders transcribe them round-for-round into a backend-neutral `RoundIR`
(byte-exact round structure, asserted by golden-digest tests), and all
backends lower that IR.  `decentralized_encode` remains the
paper-fidelity reference body and the shim for direct callers.
"""
from __future__ import annotations

import math

import numpy as np

from . import collectives
from .cauchy import StructuredGRS, cauchy_a2a
from .field import Field
from .prepare_shoot import prepare_shoot
from .simulator import RoundNetwork, run_lockstep


def _pad_rows(field: Field, A: np.ndarray, rows: int) -> np.ndarray:
    """Append an arbitrary matrix B (zeros — the choice is immaterial since
    borrowed processors hold 0) to make A have `rows` rows."""
    K, R = A.shape
    if rows == K:
        return field.arr(A)
    return np.concatenate([field.arr(A), np.zeros((rows - K, R), np.int64)])


def decentralized_encode(
    field: Field,
    A: np.ndarray,
    x: np.ndarray,
    p: int = 1,
    method: str = "universal",
    sgrs: StructuredGRS | None = None,
    net: RoundNetwork | None = None,
) -> tuple[np.ndarray, RoundNetwork]:
    """Run the full framework; returns (sink values (R, W), network)."""
    A = field.arr(A)
    K, R = A.shape
    x = field.arr(x)
    assert x.shape[0] == K
    N = K + R
    net = net or RoundNetwork(N, p)
    if method == "rs":
        assert sgrs is not None and sgrs.K == K and sgrs.R == R
        ref = sgrs.grs.A_direct()
        assert np.array_equal(ref, A), "A must come from the StructuredGRS code"

    if K >= R:
        M = math.ceil(K / R)
        Ap = _pad_rows(field, A, M * R)

        def pos_proc(r: int, m: int) -> int:
            k = r + m * R
            return k if k < K else K + r  # borrowed sink T_r holds 0

        def pos_val(r: int, m: int) -> np.ndarray:
            k = r + m * R
            return x[k] if k < K else np.zeros_like(x[0])

        # ---- phase 1: column-wise A2A --------------------------------
        partial: dict[int, np.ndarray] = {}
        gens = []
        for m in range(M):
            procs = [pos_proc(r, m) for r in range(R)]
            vals = {pos_proc(r, m): pos_val(r, m) for r in range(R)}
            if method == "rs":
                gens.append(cauchy_a2a(sgrs, m, vals, procs, p, partial))
            else:
                Am = Ap[m * R : (m + 1) * R, :]
                gens.append(prepare_shoot(field, Am, vals, procs, p, partial))
        net.run(run_lockstep(*gens))

        # ---- phase 2: row-wise reduce into sink T_r -------------------
        out: dict[int, np.ndarray] = {}
        gens = []
        for r in range(R):
            row = [pos_proc(r, m) for m in range(M)]
            sink = K + r
            procs = ([sink] + row) if sink not in row else ([sink] + [q for q in row if q != sink])
            vals = {q: partial[q] for q in row}
            if sink not in vals:
                vals[sink] = np.zeros_like(x[0])
            gens.append(collectives.reduce(field, vals, procs, p, out))
        net.run(run_lockstep(*gens))
        result = np.stack([out[K + r] for r in range(R)])

    else:
        M = math.ceil(R / K)
        Ap = np.concatenate(
            [field.arr(A), np.zeros((K, M * K - R), np.int64)], axis=1
        )

        def pos_proc(k: int, m: int) -> int:
            """Grid K x M of sinks; borrowed source S_k pads unfilled rows."""
            r = k + m * K
            return K + r if r < R else k

        # ---- phase 1: row-wise broadcast of x_k -----------------------
        xk: dict[int, np.ndarray] = {}
        gens = []
        for k in range(K):
            row = [k] + [pos_proc(k, m) for m in range(M) if pos_proc(k, m) != k]
            gens.append(collectives.broadcast(field, x[k], row, p, xk))
        net.run(run_lockstep(*gens))

        # ---- phase 2: column-wise A2A on A'_m -------------------------
        out = {}
        gens = []
        for m in range(M):
            procs = [pos_proc(k, m) for k in range(K)]
            vals = {pos_proc(k, m): xk[pos_proc(k, m)] for k in range(K)}
            if method == "rs":
                gens.append(cauchy_a2a(sgrs, m, vals, procs, p, out))
            else:
                Am = Ap[:, m * K : (m + 1) * K]
                gens.append(prepare_shoot(field, Am, vals, procs, p, out))
        net.run(run_lockstep(*gens))
        result = np.stack([out[pos_proc(r % K, r // K)] for r in range(R)])

    return result, net


def nonsystematic_encode(
    field: Field,
    G: np.ndarray,
    x: np.ndarray,
    p: int = 1,
    net: RoundNetwork | None = None,
) -> tuple[np.ndarray, RoundNetwork]:
    """Appendix B: all N = K + R processors obtain x^T G[:, n] for a
    non-systematic generator G (K x N). Sinks start with 0 payloads."""
    G = field.arr(G)
    x = field.arr(x)
    K, N = G.shape
    R = N - K
    assert R >= 0
    net = net or RoundNetwork(N, p)

    if K > R:
        # pad G to N x N; sinks hold zero packets; one big A2A (App. B-A)
        Gp = np.concatenate([G, np.zeros((R, N), np.int64)])
        vals = {k: x[k] for k in range(K)}
        vals.update({K + r: np.zeros_like(x[0]) for r in range(R)})
        out: dict[int, np.ndarray] = {}
        net.run(prepare_shoot(field, Gp, vals, list(range(N)), p, out))
        return np.stack([out[i] for i in range(N)]), net

    # K <= R (App. B-B): grid of K-processor columns — column 0 = the sources
    # themselves, columns 1..M-1 = full sink columns, leftover L sinks are
    # distributed round-robin across the columns (stacked at the bottom,
    # holding zero packets, Fig. 9).
    full_sink_cols = R // K
    L = R % K
    M = 1 + full_sink_cols  # including the source column

    def col_members(m: int) -> list[int]:
        if m == 0:
            return list(range(K))  # sources
        return [K + (m - 1) * K + k for k in range(K)]

    leftovers = [K + full_sink_cols * K + l for l in range(L)]
    extras = {m: [t for i, t in enumerate(leftovers) if i % M == m] for m in range(M)}

    # ---- phase 1: row-wise broadcast of x_k to the sink columns ----------
    xk: dict[int, np.ndarray] = {}
    gens = []
    for k in range(K):
        row = [k] + [col_members(m)[k] for m in range(1, M)]
        gens.append(collectives.broadcast(field, x[k], row, p, xk))
    net.run(run_lockstep(*gens))

    # ---- phase 2: per-column A2A on square G'_m ---------------------------
    # main member k of column m outputs G column (m*K + k) ... wait: column 0
    # outputs G[:, 0:K] (the sources' own coded packets); sink column m >= 1
    # outputs G[:, m*K : (m+1)*K]; extra sink t outputs its own G column.
    out: dict[int, np.ndarray] = {}
    gens = []
    for m in range(M):
        members = col_members(m) + extras[m]
        n = len(members)
        out_cols = [m * K + k for k in range(K)] + [
            K + (t - K) for t in extras[m]
        ]
        sq = np.zeros((n, n), np.int64)
        sq[:K, :] = np.take(G, out_cols, axis=1)
        vals = {g: xk[g] for g in col_members(m)}
        for t in extras[m]:
            vals[t] = np.zeros_like(x[0])
        gens.append(prepare_shoot(field, sq, vals, members, p, out))
    net.run(run_lockstep(*gens))

    coded = np.zeros((N,) + np.asarray(x[0]).shape, np.int64)
    for m in range(M):
        for i, g in enumerate(col_members(m) + extras[m]):
            col = (m * K + i) if i < K else K + (extras[m][i - K] - K)
            coded[col] = out[g]
    return coded, net
