"""All-to-all encode for Cauchy-like matrices — systematic Reed-Solomon and
Lagrange codes (Sec. VI, Thms. 6-9, Remark 9).

Thm. 6: for a systematic GRS code [I | A] with A = (V_alpha P)^-1 V_beta Q,
every R x R block A_m of A (case K >= R, eq. 1) factors as

    A_m = (V_{alpha,m} Phi_m)^-1  V_beta  Psi_m

so processor group m computes x * A_m by:
    1. local scale by phi_{m,s}^-1          (free)
    2. inverse draw-and-loose on V_{alpha,m}  (Lemma 6)
    3. forward draw-and-loose on V_beta
    4. local scale by psi_r                  (free)

This requires the alpha points of every block and the beta points to be
*structured* (eq. 15) — `StructuredGRS.build` constructs such codes, placing
each block's alpha grid and the beta grid in disjoint generator cosets so all
K + R evaluation points stay distinct.

Cost (Thm. 7): C1 = 2*ceil(log_{p+1} R); C2 = C2(V_alpha,m) + C2(V_beta).

Lagrange matrices (Remark 9) are the u = v = 1 case and reuse this machinery.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .draw_loose import cost_draw_loose, draw_loose
from .field import Field
from .matrices import StructuredPoints, SystematicGRS, _prod


@dataclass(frozen=True)
class StructuredGRS:
    """Systematic GRS code whose evaluation points are draw-and-loose ready.

    Case K >= R (K = M*R): alpha block m (size R) is `alpha_blocks[m]`;
    betas are one structured R-point set.
    Case K < R (R = M*K): alphas are one structured K-point set; beta block m
    (size K) is `beta_blocks[m]`.
    """

    grs: SystematicGRS
    alpha_blocks: tuple[StructuredPoints, ...]
    beta_blocks: tuple[StructuredPoints, ...]

    @property
    def field(self) -> Field:
        return self.grs.field

    @property
    def K(self) -> int:
        return self.grs.K

    @property
    def R(self) -> int:
        return self.grs.R

    @staticmethod
    def build(field: Field, K: int, R: int, P: int = 2, lagrange: bool = False) -> "StructuredGRS":
        """Build a structured systematic GRS (or Lagrange, u=v=1) code.

        Requires min | max of (K, R). Blocks get consecutive phi offsets so
        every evaluation point g^(o+i) * zeta^{j'} is distinct.
        """
        big, small = max(K, R), min(K, R)
        assert big % small == 0, "assume K | R or R | K (Remark 4)"
        n_small_sets = big // small + 1  # M blocks of the big side + 1 small set

        # factor `small` = M_s * P^H against q-1
        proto = StructuredPoints.build(field, small, P=P, phi_offset=0)
        rows_per_set = proto.M
        sets = []
        for b in range(n_small_sets):
            sets.append(
                StructuredPoints(field, proto.M, proto.P, proto.H,
                                 tuple(b * rows_per_set + i for i in range(proto.M)))
            )
        if (n_small_sets) * rows_per_set > (field.q - 1) // proto.Z:
            raise ValueError("not enough cosets in F_q for this (K, R)")

        if K >= R:
            alpha_blocks = tuple(sets[:-1])
            beta_blocks = (sets[-1],)
            alphas = np.concatenate([s.points() for s in alpha_blocks])
            betas = beta_blocks[0].points()
        else:
            alpha_blocks = (sets[-1],)
            beta_blocks = tuple(sets[:-1])
            alphas = alpha_blocks[0].points()
            betas = np.concatenate([s.points() for s in beta_blocks])
        u = np.ones(K, np.int64)
        v = np.ones(R, np.int64)
        grs = SystematicGRS(field, alphas, betas, u, v)
        return StructuredGRS(grs, alpha_blocks, beta_blocks)

    # ------------------------------------------------------------------
    def scaling_factors(self, m: int) -> tuple[np.ndarray, np.ndarray]:
        """(phi_m, psi_m) of eqs. (26)-(27) (case K>=R) or the K<R analogue
        from Thm. 8: A_m = (P V_alpha)^-1 V_{beta,m} Q_m."""
        f, grs = self.field, self.grs
        if self.K >= self.R:
            R = self.R
            sel = np.arange(m * R, (m + 1) * R)
            others = np.delete(grs.alphas, sel)
            phi = np.array(
                [f.mul(grs.u[m * R + s], _prod(f, f.sub(grs.alphas[m * R + s], others)))
                 for s in range(R)], np.int64)
            psi = np.array(
                [f.mul(grs.v[r], _prod(f, f.sub(grs.betas[r], others)))
                 for r in range(R)], np.int64)
            return phi, psi
        else:
            # Thm. 8: full V_alpha inverse, block of betas; phi has no
            # excluded indices (S_m covers nothing of alphas)
            K = self.K
            sel = np.arange(m * K, (m + 1) * K)
            phi = np.array(
                [f.mul(grs.u[s], np.int64(1)) for s in range(K)], np.int64)
            psi = np.array([grs.v[r] for r in sel], np.int64)
            return phi, psi


def cauchy_a2a(
    sgrs: StructuredGRS,
    m: int,
    x: dict[int, np.ndarray],
    procs: list[int],
    p: int,
    out: dict[int, np.ndarray],
):
    """Generator schedule computing x * A_m on one processor group.

    Group size is R (case K>=R, Thm. 7) or K (case K<R, Thm. 9).
    """
    f = sgrs.field
    phi, psi = sgrs.scaling_factors(m)
    if sgrs.K >= sgrs.R:
        sp_in, sp_out = sgrs.alpha_blocks[m], sgrs.beta_blocks[0]
    else:
        sp_in, sp_out = sgrs.alpha_blocks[0], sgrs.beta_blocks[m]
    n = len(procs)
    assert n == sp_in.K == sp_out.K

    # 1. local scale by phi^-1
    vals = {procs[k]: f.mul(f.inv(phi[k]), f.arr(x[procs[k]])) for k in range(n)}
    # 2. inverse draw-and-loose on V_alpha(,m)
    mid: dict[int, np.ndarray] = {}
    yield from draw_loose(f, sp_in, vals, procs, p, mid, inverse=True)
    # 3. forward draw-and-loose on V_beta(,m)
    fin: dict[int, np.ndarray] = {}
    yield from draw_loose(f, sp_out, mid, procs, p, fin)
    # 4. local scale by psi
    for k in range(n):
        out[procs[k]] = f.mul(psi[k], fin[procs[k]])


def lagrange_a2a(field: Field, K: int, R: int, x, procs, p, out, P: int = 2):
    """Remark 9 convenience: Lagrange matrix A2A (u=v=1), systematic when
    alpha_k = beta_k. Returns the schedule for the single square block."""
    sgrs = StructuredGRS.build(field, K, R, P=P, lagrange=True)
    return cauchy_a2a(sgrs, 0, x, procs, p, out)


def cost_cauchy(sgrs: StructuredGRS, m: int, p: int) -> tuple[int, int]:
    """(C1, C2) per Thm. 7/9: two draw-and-looses."""
    if sgrs.K >= sgrs.R:
        sp_in, sp_out = sgrs.alpha_blocks[m], sgrs.beta_blocks[0]
    else:
        sp_in, sp_out = sgrs.alpha_blocks[0], sgrs.beta_blocks[m]
    c1a, c2a = cost_draw_loose(sp_in, p)
    c1b, c2b = cost_draw_loose(sp_out, p)
    return c1a + c1b, c2a + c2b
