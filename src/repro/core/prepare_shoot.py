"""Universal all-to-all encode: the prepare-and-shoot algorithm (Sec. IV-B).

Computes (x_0..x_{K-1}) * C for ANY square matrix C over F_q with a fixed,
matrix-independent scheduling:

  * L = ceil(log_{p+1} K) rounds total (optimal C1, Lemma 1)
  * prepare phase (T_p = ceil(L/2) rounds): K parallel one-to-m broadcasts on
    (p+1)-nomial trees — after it, P_k holds x_r for r in R_k^- = [k-m+1, k]
  * shoot phase (T_s = floor(L/2) rounds): K parallel n-to-one reduces of the
    partially-encoded packets w_{k, k+l*m} = sum_{r in R_k^-} C[r, k+l*m] x_r
  * local overlap correction (eq. 4) when K < m*n.

NOTE on fidelity: the paper's Alg. 2 writes the round-t stride as `m^t`; the
correct stride — the dual of the prepare broadcast tree, validated here by
simulation against a direct matmul for every K <= 200 and p <= 4 — is
`m * (p+1)^(t-1)`.  See DESIGN.md §2.

State is a dict proc->np.ndarray (shape (W,) payload vectors; Remark 2: a
vector in F_q^W is an element of the extension field F_{q^W}, costing W times
C2 but the same C1).
"""
from __future__ import annotations

import math
from collections import Counter, defaultdict

import numpy as np

from .field import Field
from .simulator import Msg


def phase_split(K: int, p: int) -> tuple[int, int, int, int]:
    """Return (L, T_p, T_s, m) per Sec. IV-B."""
    if K <= 1:
        return 0, 0, 0, 1
    L = math.ceil(math.log(K, p + 1))
    # guard float fuzz: smallest L with (p+1)^L >= K
    while (p + 1) ** L < K:
        L += 1
    while L > 1 and (p + 1) ** (L - 1) >= K:
        L -= 1
    T_p = (L + 1) // 2
    T_s = L // 2
    m = (p + 1) ** T_p
    return L, T_p, T_s, m


def prepare_shoot(
    field: Field,
    C: np.ndarray,
    x: dict[int, np.ndarray],
    procs: list[int],
    p: int,
    out: dict[int, np.ndarray],
):
    """Generator schedule computing x*C on the processor group `procs`.

    `procs[i]` is the global id of local processor i; `x[g]` the initial
    payload of global proc g (np int64, any shape, last axis = W); results are
    written to `out[g]`.  Yields one list[Msg] per communication round.
    """
    K = len(procs)
    C = field.arr(C)
    assert C.shape == (K, K)
    if K == 1:
        out[procs[0]] = field.mul(C[0, 0], x[procs[0]])
        return
        yield  # pragma: no cover

    L, T_p, T_s, m = phase_split(K, p)
    n = math.ceil(K / m)
    W = int(np.asarray(x[procs[0]]).size)

    # ---------------- prepare phase (Alg. 1) ------------------------------
    memory: list[dict[int, np.ndarray]] = [
        {k: field.arr(x[procs[k]])} for k in range(K)
    ]
    for t in range(1, T_p + 1):
        stride = (p + 1) ** (T_p - t)
        msgs: list[Msg] = []
        incoming: list[list[dict[int, np.ndarray]]] = [[] for _ in range(K)]
        for k in range(K):
            payload = dict(memory[k])  # entire memory content (Alg. 1 line 5)
            for rho in range(1, p + 1):
                dst = (k + rho * stride) % K
                if dst == k:
                    continue
                msgs.append(Msg(procs[k], procs[dst], len(payload) * W))
                incoming[dst].append(payload)
        yield msgs
        for k in range(K):
            for payload in incoming[k]:
                memory[k].update(payload)

    # each P_k now holds x_r for r in R_k^- = {k-l mod K : l in [0, m-1]}
    r_minus = [{(k - l) % K for l in range(min(m, K))} for k in range(K)]
    for k in range(K):
        assert set(memory[k]) == r_minus[k], "prepare phase coverage bug"

    # ---------------- shoot phase (Alg. 2, corrected stride) --------------
    # w[k][s]: partially coded packet for target s held at k
    w: list[dict[int, np.ndarray]] = [dict() for _ in range(K)]
    for k in range(K):
        for l in range(n):
            s = (k + l * m) % K
            acc = np.zeros(np.asarray(x[procs[k]]).shape, np.int64)
            for r in memory[k]:
                acc = field.add(acc, field.mul(C[r, s], memory[k][r]))
            w[k][s] = acc

    for t in range(1, T_s + 1):
        stride = m * (p + 1) ** (t - 1)  # paper's "m^t" corrected
        blk = (p + 1) ** t
        sub = (p + 1) ** (t - 1)
        grouped: dict[tuple[int, int], dict[int, np.ndarray]] = defaultdict(dict)
        for s in range(K):
            for j in range(n):
                rem = j % blk
                if rem == 0 or rem % sub != 0:
                    continue  # j not eliminated this round
                src = (s - j * m) % K
                dst = (s - (j - rem) * m) % K
                if s in w[src]:
                    grouped[(src, dst)][s] = w[src].pop(s)
        msgs = [
            Msg(procs[src], procs[dst], len(pl) * W)
            for (src, dst), pl in grouped.items()
        ]
        yield msgs
        for (src, dst), pl in grouped.items():
            for s, val in pl.items():
                w[dst][s] = field.add(w[dst][s], val)

    # ---------------- overlap correction (eq. 4) --------------------------
    for k in range(K):
        y = w[k][k]
        # multiplicity of each source index across the n sets R_{k-j*m}^-
        mult = Counter()
        for j in range(n):
            for r in r_minus[(k - j * m) % K]:
                mult[r] += 1
        corr = np.zeros_like(y)
        for r, c in mult.items():
            if c > 1:
                assert r in memory[k], "correction term not locally available"
                corr = field.add(corr, field.mul((c - 1) * C[r, k] % field.q, memory[k][r]))
        out[procs[k]] = field.sub(y, corr)


def universal_a2a(
    field: Field, C: np.ndarray, x: np.ndarray, p: int = 1, net=None
) -> np.ndarray:
    """Convenience wrapper: run prepare-and-shoot on K standalone processors.

    x: (K,) or (K, W) int64. Returns x*C with identical shape semantics.
    """
    from .simulator import RoundNetwork

    x = field.arr(x)
    K = C.shape[0]
    xs = {k: x[k] for k in range(K)}
    out: dict[int, np.ndarray] = {}
    net = net or RoundNetwork(K, p)
    net.run(prepare_shoot(field, C, xs, list(range(K)), p, out))
    return np.stack([out[k] for k in range(K)])


# ---------------- analytic costs (Thm. 3) ----------------------------------

def cost_universal(K: int, p: int) -> tuple[int, int]:
    """(C1, C2) of prepare-and-shoot for a K-processor group (W=1)."""
    if K <= 1:
        return 0, 0
    L, T_p, T_s, m = phase_split(K, p)
    c2_prep = ((p + 1) ** T_p - 1) // p
    c2_shoot = ((p + 1) ** T_s - 1) // p
    return L, c2_prep + c2_shoot


def cost_universal_exact(K: int, p: int) -> tuple[int, int]:
    """Exact measured (C1, C2) of `prepare_shoot`, round by round (W=1).

    Thm. 3 (`cost_universal`) counts the shoot phase at its worst case
    n = (p+1)^T_s targets per processor; when K is not a power of p+1 the
    actual n = ceil(K/m) is smaller, some shoot rounds carry fewer (or no)
    packets, and the simulator measures strictly less.  This closed form
    reproduces the schedule's counts exactly: shoot round t moves, from
    each sender, one packet per alive target index j with
    j mod (p+1)^t = rho*(p+1)^(t-1); a round with no such j never hits the
    network.  Used by the decode cost model, which is asserted *equal* to
    the measured RoundNetwork counts.
    """
    if K <= 1:
        return 0, 0
    L, T_p, T_s, m = phase_split(K, p)
    n = math.ceil(K / m)
    c1 = T_p
    c2 = ((p + 1) ** T_p - 1) // p
    for t in range(1, T_s + 1):
        blk = (p + 1) ** t
        sub = (p + 1) ** (t - 1)
        m_t = max(
            sum(1 for j in range(n) if j % blk == rho * sub)
            for rho in range(1, p + 1))
        if m_t:
            c1 += 1
            c2 += m_t
    return c1, c2
