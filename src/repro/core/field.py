"""Finite-field arithmetic for decentralized encoding.

Two execution paths share one `Field` definition:

* a **numpy int64** path used by the round-based network simulator and all
  correctness oracles (exact, host-side), and
* a **jnp uint32** path (`fermat_*`) specialised for the Fermat prime
  q = 2^16 + 1 = 65537, designed so that *no 64-bit integer is ever needed* —
  this is the path that runs inside `shard_map` bodies and Pallas TPU kernels
  (TPU has no int64).

Why 65537 is the default field:
  * q - 1 = 2^16, so radix-2^k DFTs exist for every K = 2^h <= 65536 — exactly
    what the paper's specific (DFT / draw-and-loose) algorithms need.
  * data symbols are 16-bit chunks (any uint16 value < q), so real state bytes
    (checkpoints, gradients) embed losslessly with zero inflation.
  * modular reduction is two shifts and a subtract: 2^16 == -1 (mod q), so for
    x < 2^32:  x mod q == (x & 0xffff) - (x >> 16)  (+q if negative).
  * the only uint32-overflow corner in a*b is a == b == 65536 (== -1), i.e.
    (-1)*(-1) == 1; we special-case a == 65536 via 65536 == -1 (mod q).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

FERMAT_Q = 65537  # 2^16 + 1, Fermat prime F4


def is_prime(n: int) -> bool:
    if n < 2:
        return False
    if n % 2 == 0:
        return n == 2
    # deterministic Miller-Rabin for n < 3.3e24
    d, s = n - 1, 0
    while d % 2 == 0:
        d //= 2
        s += 1
    for a in (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37):
        if a % n == 0:
            continue
        x = pow(a, d, n)
        if x in (1, n - 1):
            continue
        for _ in range(s - 1):
            x = x * x % n
            if x == n - 1:
                break
        else:
            return False
    return True


def factorize(n: int) -> dict[int, int]:
    out: dict[int, int] = {}
    d = 2
    while d * d <= n:
        while n % d == 0:
            out[d] = out.get(d, 0) + 1
            n //= d
        d += 1
    if n > 1:
        out[n] = out.get(n, 0) + 1
    return out


@functools.lru_cache(maxsize=None)
def find_generator(q: int) -> int:
    """Smallest generator of the multiplicative group of F_q."""
    phi = q - 1
    primes = list(factorize(phi))
    for g in range(2, q):
        if all(pow(g, phi // p, q) != 1 for p in primes):
            return g
    raise ValueError(f"no generator found for q={q}")


@dataclass(frozen=True)
class Field:
    """Prime field F_q with vectorized numpy int64 arithmetic.

    Requires q < 2^31 so that single products fit int64 with headroom for
    K-term accumulations in `matmul` (K * q^2 < 2^63  =>  K < 2^63 / q^2).
    """

    q: int

    def __post_init__(self):
        if not is_prime(self.q):
            raise ValueError(f"q={self.q} is not prime")
        if self.q >= 1 << 31:
            raise ValueError("q must be < 2^31")

    # -- scalars / numpy arrays (exact oracle path) -------------------------
    @property
    def generator(self) -> int:
        return find_generator(self.q)

    def arr(self, x) -> np.ndarray:
        return np.asarray(x, dtype=np.int64) % self.q

    def add(self, a, b):
        return (np.asarray(a, np.int64) + np.asarray(b, np.int64)) % self.q

    def sub(self, a, b):
        return (np.asarray(a, np.int64) - np.asarray(b, np.int64)) % self.q

    def neg(self, a):
        return (-np.asarray(a, np.int64)) % self.q

    def mul(self, a, b):
        return (np.asarray(a, np.int64) * np.asarray(b, np.int64)) % self.q

    def pow(self, a, e: int):
        """Element-wise a**e mod q (e may be negative)."""
        e = int(e) % (self.q - 1) if e != 0 else 0
        a = np.asarray(a, np.int64) % self.q
        result = np.ones_like(a)
        base = a
        while e:
            if e & 1:
                result = (result * base) % self.q
            base = (base * base) % self.q
            e >>= 1
        return result

    def inv(self, a):
        a = np.asarray(a, np.int64) % self.q
        if np.any(a == 0):
            raise ZeroDivisionError("inverse of 0")
        return self.pow(a, self.q - 2)

    def matmul(self, a, b):
        """(a @ b) mod q, exact. Accumulation bound: K*q^2 < 2^63."""
        a = np.asarray(a, np.int64) % self.q
        b = np.asarray(b, np.int64) % self.q
        k = a.shape[-1]
        if k * (self.q - 1) ** 2 >= 1 << 63:
            # chunked accumulation to stay exact
            step = max(1, ((1 << 62) // (self.q - 1) ** 2))
            acc = np.zeros(np.broadcast_shapes(a.shape[:-1] + (b.shape[-1],)), np.int64)
            for i in range(0, k, step):
                acc = (acc + a[..., i : i + step] @ b[i : i + step]) % self.q
            return acc
        return (a @ b) % self.q

    def dot(self, a, b):
        return self.matmul(np.atleast_2d(a), b)

    def rand(self, shape, rng: np.random.Generator):
        return rng.integers(0, self.q, size=shape, dtype=np.int64)

    # -- polynomial helpers --------------------------------------------------
    def poly_eval(self, coeffs, x):
        """Horner evaluation of sum_i coeffs[i] * x^i (coeffs along axis 0)."""
        coeffs = self.arr(coeffs)
        x = self.arr(x)
        out = np.zeros(np.broadcast_shapes(coeffs.shape[1:] if coeffs.ndim > 1 else (), x.shape), np.int64)
        for c in coeffs[::-1]:
            out = (out * x + c) % self.q
        return out

    def root_of_unity(self, order: int) -> int:
        """A primitive `order`-th root of unity; requires order | q-1."""
        if (self.q - 1) % order != 0:
            raise ValueError(f"order {order} does not divide q-1={self.q - 1}")
        return int(pow(self.generator, (self.q - 1) // order, self.q))


FERMAT = Field(FERMAT_Q)


# ---------------------------------------------------------------------------
# jnp uint32 path for q = 65537 (TPU/Pallas compatible: no 64-bit anywhere).
# These are module-level functions (not Field methods) so they can be called
# from inside Pallas kernel bodies and shard_map bodies without capturing
# python objects.
# ---------------------------------------------------------------------------

def fermat_reduce(x):
    """Reduce x (uint32, x < 2^32) mod 65537 using 2^16 == -1.

    x = hi*2^16 + lo  ==>  x == lo - hi (mod q).  lo, hi < 2^16, so
    lo - hi in (-2^16, 2^16): at most one correction.
    """
    import jax.numpy as jnp

    x = x.astype(jnp.uint32)
    lo = x & jnp.uint32(0xFFFF)
    hi = x >> jnp.uint32(16)
    # compute in uint32 with wraparound guard: lo - hi + q is always positive
    r = lo + jnp.uint32(FERMAT_Q) - hi
    return jnp.where(r >= jnp.uint32(FERMAT_Q), r - jnp.uint32(FERMAT_Q), r)


def fermat_mul(a, b):
    """a*b mod 65537 for a, b in [0, 65537), pure uint32.

    If a <= 65535 then a*b <= 65535*65536 = 2^32 - 2^16 < 2^32: no overflow.
    The only corner is a == 65536 == -1 (mod q): result is q - b (mod q).
    """
    import jax.numpy as jnp

    a = a.astype(jnp.uint32)
    b = b.astype(jnp.uint32)
    safe_a = jnp.where(a == jnp.uint32(65536), jnp.uint32(0), a)
    prod = fermat_reduce(safe_a * b)
    neg_b = jnp.where(b == jnp.uint32(0), jnp.uint32(0), jnp.uint32(FERMAT_Q) - b)
    return jnp.where(a == jnp.uint32(65536), neg_b, prod)


def fermat_add(a, b):
    import jax.numpy as jnp

    s = a.astype(jnp.uint32) + b.astype(jnp.uint32)  # < 2*q < 2^32
    return jnp.where(s >= jnp.uint32(FERMAT_Q), s - jnp.uint32(FERMAT_Q), s)


def fermat_sub(a, b):
    import jax.numpy as jnp

    s = a.astype(jnp.uint32) + jnp.uint32(FERMAT_Q) - b.astype(jnp.uint32)
    return jnp.where(s >= jnp.uint32(FERMAT_Q), s - jnp.uint32(FERMAT_Q), s)


def fermat_matvec_cols(x, cmat):
    """y[j] = sum_k x[..., k] * cmat[k, j] mod q.

    x: (..., K) uint32; cmat: (K, J) uint32. Accumulates reduced products in
    uint32 — safe for K <= 65535 since K * (q-1) < 2^32.
    """
    import jax.numpy as jnp

    assert cmat.shape[0] <= 65535, "accumulation overflow guard"
    prods = fermat_mul(x[..., :, None], cmat[None, ...] if x.ndim > 1 else cmat)
    # prods entries < q; sum over K axis fits uint32 for K <= 65535
    acc = jnp.sum(prods.astype(jnp.uint32), axis=-2)
    return fermat_reduce(acc)


# ---------------------------------------------------------------------------
# byte <-> symbol packing (for coded checkpoints / gradient coding)
# ---------------------------------------------------------------------------

def bytes_to_symbols(raw: np.ndarray) -> np.ndarray:
    """uint8[n] -> int64 symbols in [0, 65536): 16-bit little-endian chunks.

    Pads with zero byte if n is odd. Every symbol < 2^16 < q: lossless.
    """
    raw = np.asarray(raw, np.uint8).ravel()
    if raw.size % 2:
        raw = np.concatenate([raw, np.zeros(1, np.uint8)])
    return raw.view("<u2").astype(np.int64)


def symbols_to_bytes(sym: np.ndarray, nbytes: int) -> np.ndarray:
    sym = np.asarray(sym)
    if np.any((sym < 0) | (sym >= 1 << 16)):
        raise ValueError("symbol out of uint16 range — not a data payload")
    return sym.astype("<u2").view(np.uint8)[:nbytes]
