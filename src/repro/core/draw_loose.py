"""Draw-and-loose: specific A2A for general Vandermonde matrices (Sec. V-B).

For K = M * Z (Z = P^H | q-1) and structured evaluation points
omega_{i,j} = alpha_i * zeta^{j'} (eq. 15), computes x * V where
V[k, i*Z+j] = omega_{i,j}^k:

  draw phase : Z parallel column-wise universal A2As on V_M (eq. 20),
               then a free local scaling by alpha_i^j (eq. 21)
  loose phase: M parallel row-wise permuted-DFT A2As on D_Z Pi (eq. 19)

Cost (Thm. 5): C_univ(M) + C_dft(Z).  Invertible (Lemma 6) by running the
inverse DFT, unscaling, and a universal A2A on V_M^{-1}.
"""
from __future__ import annotations

import numpy as np

from .dft_a2a import cost_dft, dft_a2a
from .field import Field
from .matrices import StructuredPoints, gauss_inverse, vandermonde
from .prepare_shoot import cost_universal, prepare_shoot
from .simulator import run_lockstep


def _v_m(field: Field, sp: StructuredPoints) -> np.ndarray:
    """V_M of eq. (20): V_M[l, i] = alpha_i^(Z*l)."""
    alphas_z = np.array(
        [pow(sp.alpha(i), sp.Z, field.q) for i in range(sp.M)], np.int64
    )
    return vandermonde(field, alphas_z)


def draw_loose(
    field: Field,
    sp: StructuredPoints,
    x: dict[int, np.ndarray],
    procs: list[int],
    p: int,
    out: dict[int, np.ndarray],
    inverse: bool = False,
):
    """Generator schedule: out = x * V (or x * V^-1), V the K x K Vandermonde
    at sp.points(); local index k = i*Z + j sits at grid (row i, col j)."""
    M, Z, P = sp.M, sp.Z, sp.P
    K = M * Z
    assert len(procs) == K
    vals = {k: field.arr(x[procs[k]]) for k in range(K)}

    def col_procs(j):
        return [procs[i * Z + j] for i in range(M)]

    def row_procs(i):
        return [procs[i * Z + j] for j in range(Z)]

    def run_draw(mat):
        gens = []
        stage_out: dict[int, np.ndarray] = {}
        for j in range(Z):
            gx = {procs[i * Z + j]: vals[i * Z + j] for i in range(M)}
            gens.append(prepare_shoot(field, mat, gx, col_procs(j), p, stage_out))
        return gens, stage_out

    def run_loose(inv):
        gens = []
        stage_out: dict[int, np.ndarray] = {}
        for i in range(M):
            gx = {procs[i * Z + j]: vals[i * Z + j] for j in range(Z)}
            gens.append(
                dft_a2a(field, gx, row_procs(i), p, P, stage_out, inverse=inv)
            )
        return gens, stage_out

    def scale(invert):
        for i in range(M):
            for j in range(Z):
                s = pow(sp.alpha(i), j, field.q)
                if invert:
                    s = int(field.inv(s))
                vals[i * Z + j] = field.mul(vals[i * Z + j], s)

    if not inverse:
        # ---- draw: column A2A on V_M, then local scale alpha_i^j ----------
        if M > 1:
            gens, so = run_draw(_v_m(field, sp))
            yield from run_lockstep(*gens)
            for k in range(K):
                vals[k] = so[procs[k]]
        scale(invert=False)
        # ---- loose: row-wise permuted DFT ---------------------------------
        if Z > 1:
            gens, so = run_loose(inv=False)
            yield from run_lockstep(*gens)
            for k in range(K):
                vals[k] = so[procs[k]]
    else:
        # ---- inverse loose --------------------------------------------------
        if Z > 1:
            gens, so = run_loose(inv=True)
            yield from run_lockstep(*gens)
            for k in range(K):
                vals[k] = so[procs[k]]
        scale(invert=True)
        # ---- inverse draw ---------------------------------------------------
        if M > 1:
            gens, so = run_draw(gauss_inverse(field, _v_m(field, sp)))
            yield from run_lockstep(*gens)
            for k in range(K):
                vals[k] = so[procs[k]]

    for k in range(K):
        out[procs[k]] = vals[k]


def cost_draw_loose(sp: StructuredPoints, p: int) -> tuple[int, int]:
    """(C1, C2) per Thm. 5: C_univ(M) + C_dft(Z)."""
    c1u, c2u = cost_universal(sp.M, p)
    c1d, c2d = cost_dft(sp.Z, sp.P, p) if sp.Z > 1 else (0, 0)
    return c1u + c1d, c2u + c2d
