"""Round-schedule IR: one backend-neutral program per (spec, method).

The paper's all-to-all encode/decode is ONE algorithm, but the repo used to
carry three implementations per code kind (simulator generators, mesh
ppermute tables, local kernels) with bitwise identity enforced only by
tests.  This module reifies the round schedule as a first-class IR so the
4-kinds x 3-backends matrix collapses into 4 *builders* + 3 *lowerings*:

    builders   build_encode_ir / build_decode_ir transcribe the per-kind
               generator schedules (universal prepare-and-shoot, rs/lagrange
               draw-and-loose, dft butterfly stages, the Sec.-III framework
               glue, and the decode-as-encode batches of recover/engine)
               into an explicit `RoundIR`: a sequence of `Round`s, each a
               tuple of `Send`s (packet movements) plus per-processor
               linear `Combine` ops over a shared coefficient pool.
    passes     `validate()` — static port/erasure-constraint check at plan
               time; `attribute(placement)` — per-tier round counts the
               drift ledger cross-checks; `tier_commute(placement)` —
               rewrites the commuting reduce phase under a placement so
               inter-host rounds strictly shrink; `digest()` — stable
               content hash for golden-schedule tests.
    lowerings  `execute(ir, ...)` runs the IR generically on the
               `RoundNetwork` simulator (round-for-round identical to the
               legacy generators: same strides, same payload snapshots, so
               measured C1/C2 still equal the closed forms bit for bit);
               `core.shardmap_exec.build_ir_mesh_program` compiles IR
               rounds into ppermute legs; `coeff_matrix()` recovers the
               generator block the local/host tables consume.

Packets are value-carrying ids: a `Send` moves ids between processors (the
value is unchanged — a broadcast shares one id), a `Combine` creates a new
id as a linear combination of ids available at its processor.  Rounds with
no sends are free, matching the simulator's local-compute contract.

The legacy generator entry points (`prepare_shoot`, `dft_a2a`,
`cauchy_a2a`, `decentralized_encode`, ...) remain importable and correct —
they are the transcription sources and the parity oracles — but the
planner backends now execute the IR.
"""
from __future__ import annotations

import hashlib
import itertools
import math
from collections import Counter, defaultdict
from dataclasses import dataclass, replace

import numpy as np

from .collectives import _n_rounds
from .dft_a2a import _stage_groups, _stage_matrix
from .field import Field
from .matrices import StructuredPoints, gauss_inverse
from .prepare_shoot import phase_split
from .simulator import Msg


class ScheduleValidationError(ValueError):
    """The IR breaks a static invariant: port overflow, a packet used
    before it exists (or away from where it lives), double creation, or
    traffic through a processor declared failed."""


# ---------------------------------------------------------------------------
# IR data model
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Send:
    """Move packets `packets` (ids, values unchanged) src -> dst; costs one
    port each way and len(packets) * W field elements."""

    src: int
    dst: int
    packets: tuple[int, ...]


@dataclass(frozen=True)
class Combine:
    """Create packet `out` at `proc` as sum(coeffs[cref] * packet) over
    `terms`; empty terms make a zero packet (borrowed processors)."""

    proc: int
    out: int
    terms: tuple[tuple[int, int], ...]  # (coeff_ref, packet)


@dataclass(frozen=True)
class Round:
    """One network round: sends deliver first, then combines run in order
    (a combine may consume packets delivered this round or created by an
    earlier combine of the same round).  No sends -> free round."""

    sends: tuple[Send, ...]
    combines: tuple[Combine, ...]
    tag: str = ""


@dataclass(frozen=True)
class ReduceJob:
    """Commute metadata for one all-to-one sum-reduce: `out` (the packet
    the rest of the IR consumes) equals the sum of the `members` packets.
    `tier_commute` may drop the job's rounds (tag `reduce:{seg}`) and
    re-synthesize them placement-aware, as mod-q addition commutes."""

    seg: int
    root: int
    members: tuple[tuple[int, int], ...]  # (proc, packet)
    out: int


@dataclass(frozen=True)
class RoundIR:
    """A complete backend-neutral round program (see module docstring)."""

    kind: str                               # "encode/<method>" | "decode"
    n_procs: int
    p: int
    q: int
    n_packets: int
    coeffs: tuple[int, ...]                 # shared coefficient pool
    inputs: tuple[tuple[int, int], ...]     # (proc, packet) in payload order
    outputs: tuple[tuple[int, int], ...]    # (proc, packet) in result order
    rounds: tuple[Round, ...]
    jobs: tuple[ReduceJob, ...] = ()

    # -- analysis ----------------------------------------------------------

    def cost(self) -> tuple[int, int]:
        """Measured-equivalent flat (C1, C2) at W=1 (free rounds excluded)."""
        c1 = c2 = 0
        for r in self.rounds:
            if r.sends:
                c1 += 1
                c2 += max(len(s.packets) for s in r.sends)
        return c1, c2

    def attribute(self, placement) -> dict[str, tuple[int, int]]:
        """Per-tier (C1, C2) at W=1 under `placement` — a round is "inter"
        if ANY of its sends crosses hosts (the RoundNetwork rule)."""
        host_of = placement.host_of
        c1 = {"intra": 0, "inter": 0}
        c2 = {"intra": 0, "inter": 0}
        for r in self.rounds:
            if not r.sends:
                continue
            tier = ("inter" if any(host_of(s.src) != host_of(s.dst)
                                   for s in r.sends) else "intra")
            c1[tier] += 1
            c2[tier] += max(len(s.packets) for s in r.sends)
        return {t: (c1[t], c2[t]) for t in ("intra", "inter")}

    def digest(self) -> str:
        """Stable 16-hex content hash of the full program (golden tests)."""
        h = hashlib.sha256()
        h.update(repr((self.kind, self.n_procs, self.p, self.q,
                       self.n_packets, self.coeffs, self.inputs,
                       self.outputs)).encode())
        for r in self.rounds:
            h.update(repr((r.tag,
                           tuple((s.src, s.dst, s.packets) for s in r.sends),
                           tuple((c.proc, c.out, c.terms)
                                 for c in r.combines))).encode())
        return h.hexdigest()[:16]

    def summary(self, placement=None) -> str:
        """One describe() line: round/message totals (+ per-tier split)."""
        active = sum(1 for r in self.rounds if r.sends)
        n_msgs = sum(len(r.sends) for r in self.rounds)
        peak = max((len(r.sends) for r in self.rounds if r.sends), default=0)
        commuted = any(r.tag.startswith("commute") for r in self.rounds)
        s = (f"{active} rounds, {n_msgs} msgs (max {peak}/round), "
             f"digest={self.digest()}")
        if placement is not None:
            a = self.attribute(placement)
            s += (f"; tiers intra {a['intra'][0]} | "
                  f"inter {a['inter'][0]} rounds")
        if commuted:
            s += " [commuted]"
        return s

    def coeff_matrix(self, field: Field | None = None) -> np.ndarray:
        """(n_outputs, n_inputs) linear map the program computes: row i of
        the result is output_i = sum_j mat[i, j] * input_j.  For an encode
        IR this equals A.T; for a decode IR, D.T — the local/host table
        lowering is derived (and tested) against exactly this."""
        field = field or Field(self.q)
        n_in = len(self.inputs)
        vec: dict[int, np.ndarray] = {}
        for i, (_, pid) in enumerate(self.inputs):
            e = np.zeros(n_in, np.int64)
            e[i] = 1
            vec[pid] = e
        for r in self.rounds:
            for c in r.combines:
                acc = np.zeros(n_in, np.int64)
                for cref, pid in c.terms:
                    acc = field.add(acc, field.mul(self.coeffs[cref],
                                                   vec[pid]))
                vec[c.out] = acc
        if not self.outputs:
            return np.zeros((0, n_in), np.int64)
        return np.stack([vec[pid] for _, pid in self.outputs])

    # -- validation --------------------------------------------------------

    def validate(self, failed=None) -> "RoundIR":
        """Static plan-time check; raises `ScheduleValidationError`.

        Verifies processor ranges, the p-port constraint per round, packet
        provenance (sent packets exist at their source from a PRIOR round;
        combine terms are available at the combining processor, same-round
        deliveries included), single assignment of packet ids, coefficient
        refs in range, output availability — and, with `failed`, that no
        send or combine touches an erased processor."""
        failed = frozenset(failed or ())
        n, p = self.n_procs, self.p

        def _chk_proc(g, what):
            if not 0 <= g < n:
                raise ScheduleValidationError(
                    f"{what}: processor {g} outside [0, {n})")
            if g in failed:
                raise ScheduleValidationError(
                    f"{what}: touches failed processor {g}")

        avail: dict[int, set[int]] = {}
        created: set[int] = set()
        for proc, pid in self.inputs:
            _chk_proc(proc, "input")
            if pid in created:
                raise ScheduleValidationError(
                    f"packet {pid} created twice (input)")
            created.add(pid)
            avail[pid] = {proc}
        for t, r in enumerate(self.rounds):
            where = f"round {t} [{r.tag}]"
            sends_per: Counter = Counter()
            recvs_per: Counter = Counter()
            delivered: list[tuple[int, int]] = []
            for s in r.sends:
                _chk_proc(s.src, where)
                _chk_proc(s.dst, where)
                if s.src == s.dst:
                    raise ScheduleValidationError(
                        f"{where}: self-send at {s.src}")
                if not s.packets:
                    raise ScheduleValidationError(
                        f"{where}: empty send {s.src}->{s.dst}")
                sends_per[s.src] += 1
                recvs_per[s.dst] += 1
                for pid in s.packets:
                    if pid not in created:
                        raise ScheduleValidationError(
                            f"{where}: packet {pid} sent before creation")
                    if s.src not in avail[pid]:
                        raise ScheduleValidationError(
                            f"{where}: packet {pid} not at sender {s.src}")
                    delivered.append((s.dst, pid))
            over = {g: c for g, c in sends_per.items() if c > p}
            if over:
                raise ScheduleValidationError(
                    f"{where}: send-port violation {over} with p={p}")
            over = {g: c for g, c in recvs_per.items() if c > p}
            if over:
                raise ScheduleValidationError(
                    f"{where}: recv-port violation {over} with p={p}")
            for dst, pid in delivered:
                avail[pid].add(dst)
            for c in r.combines:
                _chk_proc(c.proc, where)
                if c.out in created:
                    raise ScheduleValidationError(
                        f"{where}: packet {c.out} created twice")
                for cref, pid in c.terms:
                    if not 0 <= cref < len(self.coeffs):
                        raise ScheduleValidationError(
                            f"{where}: coefficient ref {cref} out of range")
                    if pid not in created or c.proc not in avail[pid]:
                        raise ScheduleValidationError(
                            f"{where}: combine at {c.proc} uses packet "
                            f"{pid} it does not hold")
                created.add(c.out)
                avail[c.out] = {c.proc}
        for proc, pid in self.outputs:
            _chk_proc(proc, "output")
            if pid not in created or proc not in avail[pid]:
                raise ScheduleValidationError(
                    f"output packet {pid} not available at {proc}")
        return self

    # -- rewrite pass ------------------------------------------------------

    def tier_commute(self, placement) -> "RoundIR":
        """Placement-aware rewrite of the commuting reduce segments.

        Mod-q all-to-one sums commute, so each `ReduceJob` segment may be
        re-synthesized against the placement: per-host partial sums pack
        into intra-host rounds, outgoing partials coalesce onto one
        forwarder per source host, and ALL cross-host traffic collapses
        into bundled forwarder->sink-host rounds — the inter-host round
        count strictly shrinks or the segment is left untouched (so
        canonical plans keep their closed-form tier splits).  Outputs are
        value-identical: the final combine recreates each job's original
        `out` packet id from the re-routed partials."""
        if not self.jobs:
            return self
        host_of = placement.host_of
        by_seg: dict[int, list[ReduceJob]] = defaultdict(list)
        for j in self.jobs:
            by_seg[j.seg].append(j)

        coeffs = list(self.coeffs)
        cmap = {c: i for i, c in enumerate(coeffs)}

        def cref(c):
            c = int(c) % self.q
            if c not in cmap:
                cmap[c] = len(coeffs)
                coeffs.append(c)
            return cmap[c]

        state = {"next": self.n_packets}

        def new_pid():
            i = state["next"]
            state["next"] += 1
            return i

        def seg_tiers(rounds):
            return sum(1 for r in rounds if r.sends
                       and any(host_of(s.src) != host_of(s.dst)
                               for s in r.sends))

        rounds = list(self.rounds)
        changed = False
        for seg in sorted(by_seg):
            tag = f"reduce:{seg}"
            idxs = [i for i, r in enumerate(rounds) if r.tag == tag]
            if not idxs or idxs != list(range(idxs[0], idxs[-1] + 1)):
                continue  # nothing to rewrite / non-contiguous segment
            old = rounds[idxs[0]: idxs[-1] + 1]
            synth = _resynth_reduce(by_seg[seg], placement, self.p,
                                    new_pid, cref, seg)
            if seg_tiers(synth) >= seg_tiers(old):
                continue  # rewrite must strictly shrink inter rounds
            rounds[idxs[0]: idxs[-1] + 1] = synth
            changed = True
        if not changed:
            return self
        return replace(self, rounds=tuple(rounds), coeffs=tuple(coeffs),
                       n_packets=state["next"], jobs=()).validate()


# ---------------------------------------------------------------------------
# generic simulator lowering
# ---------------------------------------------------------------------------

def execute(ir: RoundIR, field: Field, x: np.ndarray, net) -> np.ndarray:
    """Run the IR on a `RoundNetwork`: x rows are the input payloads in
    `ir.inputs` order; returns the output payloads stacked in `ir.outputs`
    order.  The generator yields exactly the legacy schedules' rounds
    (combines run lazily after each round's delivery, like the generator
    state updates they transcribe), so port checks, tier attribution,
    RoundEvents and PartialRunError semantics all come from the untouched
    simulator."""
    x = field.arr(x)
    if x.shape[0] != len(ir.inputs):
        raise ValueError(f"x must carry {len(ir.inputs)} input rows, "
                         f"got {x.shape}")
    row_shape = x.shape[1:]
    W = int(np.prod(row_shape, dtype=np.int64)) if row_shape else 1
    coeffs = ir.coeffs
    vals: dict[int, np.ndarray] = {}
    for (_, pid), row in zip(ir.inputs, x):
        vals[pid] = row

    def gen():
        for r in ir.rounds:
            yield [Msg(s.src, s.dst, len(s.packets) * W) for s in r.sends]
            for c in r.combines:
                acc = np.zeros(row_shape, np.int64)
                for cr, pid in c.terms:
                    acc = field.add(acc, field.mul(coeffs[cr], vals[pid]))
                vals[c.out] = acc

    net.run(gen())
    if not ir.outputs:
        return np.zeros((0,) + row_shape, np.int64)
    return np.stack([vals[pid] for _, pid in ir.outputs])


# ---------------------------------------------------------------------------
# builder plumbing: packet/coefficient allocation + fragment lockstep
# ---------------------------------------------------------------------------

class _Builder:
    """Allocates packet ids and deduplicated coefficient refs."""

    def __init__(self, field: Field, p: int):
        self.field = field
        self.p = p
        self.n_packets = 0
        self.inputs: list[tuple[int, int]] = []
        self.coeffs: list[int] = []
        self._cmap: dict[int, int] = {}

    def pid(self) -> int:
        i = self.n_packets
        self.n_packets += 1
        return i

    def input(self, proc: int) -> int:
        i = self.pid()
        self.inputs.append((proc, i))
        return i

    def cref(self, c) -> int:
        c = int(c) % self.field.q
        i = self._cmap.get(c)
        if i is None:
            i = self._cmap[c] = len(self.coeffs)
            self.coeffs.append(c)
        return i

    def comb(self, proc: int, terms) -> Combine:
        return Combine(proc, self.pid(),
                       tuple((self.cref(c), pid) for c, pid in terms))

    def finish(self, kind: str, n_procs: int, rounds, outputs,
               jobs=()) -> RoundIR:
        return RoundIR(kind=kind, n_procs=n_procs, p=self.p,
                       q=self.field.q, n_packets=self.n_packets,
                       coeffs=tuple(self.coeffs),
                       inputs=tuple(self.inputs), outputs=tuple(outputs),
                       rounds=tuple(rounds), jobs=tuple(jobs))


def _lockstep(*frags):
    """Merge fragment streams positionally — the IR-level `run_lockstep`:
    parallel instances on disjoint groups share rounds 1:1."""
    for parts in itertools.zip_longest(*frags, fillvalue=None):
        sends: list[Send] = []
        combines: list[Combine] = []
        for part in parts:
            if part is not None:
                s, c = part
                sends.extend(s)
                combines.extend(c)
        yield (sends, combines)


def _rounds_from(frags, tag: str) -> list[Round]:
    return [Round(tuple(s), tuple(c), tag) for s, c in frags]


# ---------------------------------------------------------------------------
# fragment builders — line-for-line transcriptions of the legacy generators
# (same strides, same payload snapshots, same grouped pops), yielding
# (sends, combines) per round so the IR matches them round-for-round
# ---------------------------------------------------------------------------

def _ps_frag(b: _Builder, C, x: dict[int, int], procs: list[int],
             out: dict[int, int]):
    """Universal prepare-and-shoot (`core.prepare_shoot.prepare_shoot`)."""
    field, p = b.field, b.p
    K = len(procs)
    C = field.arr(C)
    if K == 1:
        c = b.comb(procs[0], [(int(C[0, 0]), x[procs[0]])])
        out[procs[0]] = c.out
        yield ([], [c])
        return

    L, T_p, T_s, m = phase_split(K, p)
    n = math.ceil(K / m)

    # ---- prepare phase (Alg. 1): payload snapshots move input ids --------
    memory: list[dict[int, int]] = [{k: x[procs[k]]} for k in range(K)]
    w: list[dict[int, int]] = []
    for t in range(1, T_p + 1):
        stride = (p + 1) ** (T_p - t)
        sends: list[Send] = []
        incoming: list[list[dict[int, int]]] = [[] for _ in range(K)]
        for k in range(K):
            payload = dict(memory[k])
            for rho in range(1, p + 1):
                dst = (k + rho * stride) % K
                if dst == k:
                    continue
                sends.append(Send(procs[k], procs[dst],
                                  tuple(payload[r] for r in sorted(payload))))
                incoming[dst].append(payload)
        for k in range(K):
            for payload in incoming[k]:
                memory[k].update(payload)
        combines: list[Combine] = []
        if t == T_p:
            # shoot-packet init runs after the last prepare delivery
            for k in range(K):
                wk: dict[int, int] = {}
                for l in range(n):
                    s = (k + l * m) % K
                    c = b.comb(procs[k], [(int(C[r, s]), memory[k][r])
                                          for r in sorted(memory[k])])
                    wk[s] = c.out
                    combines.append(c)
                w.append(wk)
            if T_s == 0:
                combines.extend(_ps_correction(b, C, memory, w, procs,
                                               n, m, K, out))
        yield (sends, combines)

    # ---- shoot phase (Alg. 2, corrected stride) --------------------------
    for t in range(1, T_s + 1):
        blk = (p + 1) ** t
        sub = (p + 1) ** (t - 1)
        grouped: dict[tuple[int, int], dict[int, int]] = defaultdict(dict)
        for s in range(K):
            for j in range(n):
                rem = j % blk
                if rem == 0 or rem % sub != 0:
                    continue
                src = (s - j * m) % K
                dst = (s - (j - rem) * m) % K
                if s in w[src]:
                    grouped[(src, dst)][s] = w[src].pop(s)
        sends = [Send(procs[src], procs[dst],
                      tuple(pl[s] for s in sorted(pl)))
                 for (src, dst), pl in grouped.items()]
        combines = []
        for (src, dst), pl in grouped.items():
            for s in sorted(pl):
                c = b.comb(procs[dst], [(1, w[dst][s]), (1, pl[s])])
                w[dst][s] = c.out
                combines.append(c)
        if t == T_s:
            combines.extend(_ps_correction(b, C, memory, w, procs,
                                           n, m, K, out))
        yield (sends, combines)


def _ps_correction(b, C, memory, w, procs, n, m, K, out):
    """Overlap correction (eq. 4): out_k = w[k][k] - sum over duplicated
    source indices — emitted as one combine with negated coefficients."""
    q = b.field.q
    combines = []
    for k in range(K):
        mult: Counter = Counter()
        for j in range(n):
            for r in memory[(k - j * m) % K]:
                mult[r] += 1
        extra = [((-(c - 1) * int(C[r, k])) % q, memory[k][r])
                 for r, c in sorted(mult.items()) if c > 1]
        if extra:
            c2 = b.comb(procs[k], [(1, w[k][k])] + extra)
            out[procs[k]] = c2.out
            combines.append(c2)
        else:
            out[procs[k]] = w[k][k]
    return combines


def _bcast_plan(N: int, p: int) -> list[list[tuple[int, int]]]:
    """(p+1)-nomial broadcast edge plan of `collectives.broadcast` — the
    reduce schedules replay it reversed."""
    T = _n_rounds(N, p)
    plan: list[list[tuple[int, int]]] = []
    have = {0}
    for t in range(1, T + 1):
        stride = (p + 1) ** (T - t)
        edges, new = [], set()
        for i in sorted(have):
            for rho in range(1, p + 1):
                j = i + rho * stride
                if j < N and j not in have and j not in new:
                    edges.append((i, j))
                    new.add(j)
        plan.append(edges)
        have |= new
    return plan


def _bcast_frag(b: _Builder, pid: int, procs: list[int],
                out: dict[int, int]):
    """One-to-all broadcast: every member ends holding the SAME packet."""
    for edges in _bcast_plan(len(procs), b.p):
        yield ([Send(procs[i], procs[j], (pid,)) for i, j in edges], [])
    for g in procs:
        out[g] = pid


def _reduce_frag(b: _Builder, vals: dict[int, int], procs: list[int],
                 out: dict[int, int], jobs: list[ReduceJob] | None,
                 seg: int):
    """All-to-one sum-reduce onto procs[0] (dual of broadcast); records a
    `ReduceJob` so `tier_commute` may re-synthesize it."""
    N = len(procs)
    acc = {i: vals[procs[i]] for i in range(N)}
    members = tuple((procs[i], acc[i]) for i in range(N))
    plan = _bcast_plan(N, b.p)
    for edges in reversed(plan):
        sends = [Send(procs[j], procs[i], (acc[j],)) for i, j in edges]
        combines = []
        for i, j in edges:
            c = b.comb(procs[i], [(1, acc[i]), (1, acc[j])])
            acc[i] = c.out
            combines.append(c)
        yield (sends, combines)
    out[procs[0]] = acc[0]
    if jobs is not None and plan:
        jobs.append(ReduceJob(seg, procs[0], members, acc[0]))


def _dft_frag(b: _Builder, x: dict[int, int], procs: list[int], P: int,
              out: dict[int, int], inverse: bool = False):
    """Permuted-DFT butterfly stages (`core.dft_a2a.dft_a2a`)."""
    field = b.field
    K = len(procs)
    H = 0
    while P ** H < K:
        H += 1
    vals = {k: x[procs[k]] for k in range(K)}
    stages = range(H - 1, -1, -1) if inverse else range(H)
    for h in stages:
        frags = []
        stage_out: dict[int, int] = {}
        for members in _stage_groups(K, P, H, h):
            mat = _stage_matrix(field, K, P, H, h, members[0])
            if inverse:
                mat = gauss_inverse(field, mat)
            gx = {procs[mm]: vals[mm] for mm in members}
            frags.append(_ps_frag(b, mat, gx,
                                  [procs[mm] for mm in members], stage_out))
        yield from _lockstep(*frags)
        for k in range(K):
            vals[k] = stage_out[procs[k]]
    for k in range(K):
        out[procs[k]] = vals[k]


def _dl_frag(b: _Builder, sp: StructuredPoints, x: dict[int, int],
             procs: list[int], out: dict[int, int],
             inverse: bool = False):
    """Draw-and-loose (`core.draw_loose.draw_loose`): column A2As on V_M,
    the free local scaling (a sendless combine round), row DFTs."""
    from .draw_loose import _v_m

    field = b.field
    M, Z, P = sp.M, sp.Z, sp.P
    K = M * Z
    vals = {k: x[procs[k]] for k in range(K)}

    def draw(mat):
        frags, so = [], {}
        for j in range(Z):
            gx = {procs[i * Z + j]: vals[i * Z + j] for i in range(M)}
            frags.append(_ps_frag(b, mat, gx,
                                  [procs[i * Z + j] for i in range(M)], so))
        return frags, so

    def loose(inv):
        frags, so = [], {}
        for i in range(M):
            gx = {procs[i * Z + j]: vals[i * Z + j] for j in range(Z)}
            frags.append(_dft_frag(b, gx,
                                   [procs[i * Z + j] for j in range(Z)],
                                   P, so, inverse=inv))
        return frags, so

    def scale(invert):
        combines = []
        for i in range(M):
            for j in range(Z):
                s = pow(sp.alpha(i), j, field.q)
                if invert:
                    s = int(field.inv(s))
                if s != 1:
                    c = b.comb(procs[i * Z + j], [(s, vals[i * Z + j])])
                    vals[i * Z + j] = c.out
                    combines.append(c)
        return combines

    def sync(so):
        for k in range(K):
            vals[k] = so[procs[k]]

    if not inverse:
        if M > 1:
            frags, so = draw(_v_m(field, sp))
            yield from _lockstep(*frags)
            sync(so)
        yield ([], scale(invert=False))
        if Z > 1:
            frags, so = loose(False)
            yield from _lockstep(*frags)
            sync(so)
    else:
        if Z > 1:
            frags, so = loose(True)
            yield from _lockstep(*frags)
            sync(so)
        yield ([], scale(invert=True))
        if M > 1:
            frags, so = draw(gauss_inverse(field, _v_m(field, sp)))
            yield from _lockstep(*frags)
            sync(so)
    for k in range(K):
        out[procs[k]] = vals[k]


def _cauchy_frag(b: _Builder, sgrs, m: int, x: dict[int, int],
                 procs: list[int], out: dict[int, int]):
    """Cauchy-like block A2A (`core.cauchy.cauchy_a2a`): phi^-1 scale,
    inverse draw-loose, forward draw-loose, psi scale."""
    f = b.field
    phi, psi = sgrs.scaling_factors(m)
    if sgrs.K >= sgrs.R:
        sp_in, sp_out = sgrs.alpha_blocks[m], sgrs.beta_blocks[0]
    else:
        sp_in, sp_out = sgrs.alpha_blocks[0], sgrs.beta_blocks[m]
    n = len(procs)
    vals: dict[int, int] = {}
    head = []
    for k in range(n):
        s = int(f.inv(phi[k]))
        if s != 1:
            c = b.comb(procs[k], [(s, x[procs[k]])])
            vals[procs[k]] = c.out
            head.append(c)
        else:
            vals[procs[k]] = x[procs[k]]
    yield ([], head)
    mid: dict[int, int] = {}
    yield from _dl_frag(b, sp_in, vals, procs, mid, inverse=True)
    fin: dict[int, int] = {}
    yield from _dl_frag(b, sp_out, mid, procs, fin)
    tail = []
    for k in range(n):
        s = int(psi[k]) % f.q
        if s != 1:
            c = b.comb(procs[k], [(s, fin[procs[k]])])
            out[procs[k]] = c.out
            tail.append(c)
        else:
            out[procs[k]] = fin[procs[k]]
    yield ([], tail)


# ---------------------------------------------------------------------------
# top-level builders
# ---------------------------------------------------------------------------

def build_universal_a2a_ir(field: Field, C: np.ndarray,
                           p: int = 1) -> RoundIR:
    """IR of one square universal A2A on K standalone processors (the
    paper's worked examples; `prepare_shoot`'s convenience wrapper)."""
    K = int(C.shape[0])
    b = _Builder(field, p)
    x = {k: b.input(k) for k in range(K)}
    out: dict[int, int] = {}
    rounds = _rounds_from(_ps_frag(b, C, x, list(range(K)), out), "a2a")
    return b.finish("a2a/universal", K, rounds,
                    [(k, out[k]) for k in range(K)])


def build_encode_ir(spec, method: str | None = None, A=None,
                    sgrs=None) -> RoundIR:
    """IR of the full Sec.-III framework encode (or the dft transform) for
    `spec`, transcribing `framework.decentralized_encode` / `dft_a2a`."""
    field = spec.field
    if method is None:
        method = "dft" if spec.kind == "dft" else (
            "rs" if spec.structured() else "universal")
    K, R, p = spec.K, spec.R, spec.p
    b = _Builder(field, p)

    if spec.kind == "dft" or method == "dft":
        procs = list(range(K))
        x = {k: b.input(k) for k in procs}
        out: dict[int, int] = {}
        rounds = _rounds_from(_dft_frag(b, x, procs, spec.P, out), "dft")
        return b.finish("encode/dft", K, rounds,
                        [(k, out[k]) for k in procs])

    if method == "rs" and sgrs is None:
        from .cauchy import StructuredGRS

        sgrs = StructuredGRS.build(field, K, R, P=spec.P,
                                   lagrange=spec.kind == "lagrange")
    if A is None:
        A = (sgrs.grs.A_direct() if method == "rs"
             else spec.default_matrix(field))
    A = field.arr(A)

    from .framework import _pad_rows

    xpid = {k: b.input(k) for k in range(K)}
    jobs: list[ReduceJob] = []

    if K >= R:
        M = math.ceil(K / R)
        Ap = _pad_rows(field, A, M * R)

        def pos_proc(r, m):
            k = r + m * R
            return k if k < K else K + r  # borrowed sink T_r holds 0

        zero_combines: list[Combine] = []
        zero_pid: dict[int, int] = {}

        def zpid(proc):
            if proc not in zero_pid:
                c = b.comb(proc, [])
                zero_pid[proc] = c.out
                zero_combines.append(c)
            return zero_pid[proc]

        # ---- phase 1: column-wise A2A --------------------------------
        partial: dict[int, int] = {}
        frags = []
        for m in range(M):
            procs = [pos_proc(r, m) for r in range(R)]
            vals = {pos_proc(r, m): (xpid[r + m * R] if r + m * R < K
                                     else zpid(pos_proc(r, m)))
                    for r in range(R)}
            if method == "rs":
                frags.append(_cauchy_frag(b, sgrs, m, vals, procs, partial))
            else:
                Am = Ap[m * R: (m + 1) * R, :]
                frags.append(_ps_frag(b, Am, vals, procs, partial))
        phase1 = _rounds_from(_lockstep(*frags), "a2a:0")

        # ---- phase 2: row-wise reduce into sink T_r -------------------
        out = {}
        frags = []
        for r in range(R):
            row = [pos_proc(r, m) for m in range(M)]
            sink = K + r
            procs = [sink] + [g for g in row if g != sink]
            vals = {g: partial[g] for g in row}
            if sink not in vals:
                vals[sink] = zpid(sink)
            frags.append(_reduce_frag(b, vals, procs, out, jobs, seg=0))
        phase2 = _rounds_from(_lockstep(*frags), "reduce:0")
        init = ([Round((), tuple(zero_combines), "init")]
                if zero_combines else [])
        rounds = init + phase1 + phase2
        outputs = [(K + r, out[K + r]) for r in range(R)]
    else:
        M = math.ceil(R / K)

        def pos_proc(k, m):
            r = k + m * K
            return K + r if r < R else k  # borrowed source holds its x_k

        Ap = np.concatenate(
            [field.arr(A), np.zeros((K, M * K - R), np.int64)], axis=1)

        # ---- phase 1: row-wise broadcast of x_k -----------------------
        xk: dict[int, int] = {}
        frags = []
        for k in range(K):
            row = [k] + [pos_proc(k, m) for m in range(M)
                         if pos_proc(k, m) != k]
            frags.append(_bcast_frag(b, xpid[k], row, xk))
        phase1 = _rounds_from(_lockstep(*frags), "bcast:0")

        # ---- phase 2: column-wise A2A on A'_m -------------------------
        out = {}
        frags = []
        for m in range(M):
            procs = [pos_proc(k, m) for k in range(K)]
            vals = {pos_proc(k, m): xk[pos_proc(k, m)] for k in range(K)}
            if method == "rs":
                frags.append(_cauchy_frag(b, sgrs, m, vals, procs, out))
            else:
                Am = Ap[:, m * K: (m + 1) * K]
                frags.append(_ps_frag(b, Am, vals, procs, out))
        phase2 = _rounds_from(_lockstep(*frags), "a2a:0")
        rounds = phase1 + phase2
        outputs = [(pos_proc(r % K, r // K), out[pos_proc(r % K, r // K)])
                   for r in range(R)]

    return b.finish(f"encode/{method}", K + R, rounds, outputs, jobs)


def build_decode_ir(spec, D: np.ndarray, kept) -> RoundIR:
    """IR of the decode-as-encode repair among the K kept survivors,
    transcribing `recover.engine.decentralized_decode` batch by batch."""
    from ..recover.engine import batch_block, decode_batches

    field = spec.field
    D = field.arr(D)
    K, E = D.shape
    kept = [int(g) for g in kept]
    b = _Builder(field, spec.p)
    vpid = {i: b.input(kept[i]) for i in range(K)}
    jobs: list[ReduceJob] = []
    rounds: list[Round] = []
    out_rows: list[tuple[int, int]] = []
    for bi, (eb, ep) in enumerate(decode_batches(K, E)):
        Db = batch_block(D, bi)
        M = K // ep
        partial: dict[int, int] = {}
        frags = []
        for m in range(M):
            procs = [kept[m * ep + j] for j in range(ep)]
            vals = {procs[j]: vpid[m * ep + j] for j in range(ep)}
            frags.append(_ps_frag(b, Db[m * ep: (m + 1) * ep, :], vals,
                                  procs, partial))
        rounds += _rounds_from(_lockstep(*frags), f"a2a:{bi}")
        if M > 1:
            out: dict[int, int] = {}
            frags = []
            for j in range(ep):
                procs = [kept[m * ep + j] for m in range(M)]
                vals = {g: partial[g] for g in procs}
                frags.append(_reduce_frag(b, vals, procs, out, jobs,
                                          seg=bi))
            rounds += _rounds_from(_lockstep(*frags), f"reduce:{bi}")
        else:
            out = partial
        out_rows.extend((kept[j], out[kept[j]]) for j in range(eb))
    return b.finish("decode", spec.N, rounds, out_rows, jobs)


# ---------------------------------------------------------------------------
# tier_commute re-synthesis
# ---------------------------------------------------------------------------

def _greedy_rounds(pending, p, tag):
    """Schedule bundled sends into p-port-legal rounds, greedily and
    deterministically; each round admits at most p sends and p receives
    per processor."""
    rounds: list[Round] = []
    while pending:
        used_s: Counter = Counter()
        used_r: Counter = Counter()
        this, rest = [], []
        for src, dst, pids in pending:
            if used_s[src] < p and used_r[dst] < p:
                this.append(Send(src, dst, tuple(pids)))
                used_s[src] += 1
                used_r[dst] += 1
            else:
                rest.append((src, dst, pids))
        rounds.append(Round(tuple(this), (), tag))
        pending = rest
    return rounds


def _merge_frag_lists(lists, tag):
    """Positionally merge per-instance round lists of (sends, combines)."""
    out: list[Round] = []
    for parts in itertools.zip_longest(*lists, fillvalue=None):
        sends: list[Send] = []
        combines: list[Combine] = []
        for part in parts:
            if part is not None:
                s, c = part
                sends.extend(s)
                combines.extend(c)
        out.append(Round(tuple(sends), tuple(combines), tag))
    return out


def _resynth_reduce(jobs, placement, p, new_pid, cref, seg):
    """Placement-aware replacement rounds for one reduce segment.

    1. per-(job, host) intra reduce trees onto a leader (the root on its
       own host), run in lockstep;
    2. per source host, gather every outgoing partial onto ONE forwarder
       (bundled intra tree — messages carry multiple packets);
    3. bundled forwarder -> sink-host rounds (the only inter traffic);
    4. intra redistribution from the receiving processor to each root;
    5. final combines recreate each job's original `out` packet."""
    host_of = placement.host_of
    one = cref(1)

    # ---- stage 1: per-host partial sums ---------------------------------
    trees = []   # (ji, host, members sorted leader-first)
    for ji, job in enumerate(jobs):
        by_host: dict[int, list] = defaultdict(list)
        for proc, pid in job.members:
            by_host[host_of(proc)].append((proc, pid))
        rh = host_of(job.root)
        for h in sorted(by_host):
            mem = by_host[h]
            if h == rh:
                mem.sort(key=lambda t: (t[0] != job.root, t[0]))
            else:
                mem.sort()
            trees.append((ji, h, mem))

    partials: dict[tuple[int, int], tuple[int, int]] = {}
    tree_frags = []
    for ji, h, mem in trees:
        acc = {i: mem[i][1] for i in range(len(mem))}
        frag = []
        for edges in reversed(_bcast_plan(len(mem), p)):
            sends, combines = [], []
            for i, j in edges:
                sends.append(Send(mem[j][0], mem[i][0], (acc[j],)))
                out = new_pid()
                combines.append(Combine(mem[i][0], out,
                                        ((one, acc[i]), (one, acc[j]))))
                acc[i] = out
            frag.append((sends, combines))
        tree_frags.append(frag)
        partials[(ji, h)] = (mem[0][0], acc[0])
    rounds = _merge_frag_lists(tree_frags, f"commute:tree:{seg}")

    # ---- stage 2: gather outgoing partials onto one forwarder per host --
    outbound: dict[int, list] = defaultdict(list)
    for (ji, h), (leader, pid) in sorted(partials.items()):
        if h != host_of(jobs[ji].root):
            outbound[h].append((ji, leader, pid))
    forwarder: dict[int, int] = {}
    fwd_bundle: dict[int, list] = {}
    gather_frags = []
    for h in sorted(outbound):
        holders: dict[int, list] = defaultdict(list)
        for ji, leader, pid in outbound[h]:
            holders[leader].append((ji, pid))
        hl = sorted(holders, key=lambda g: (-len(holders[g]), g))
        bundles = {i: list(holders[hl[i]]) for i in range(len(hl))}
        frag = []
        for edges in reversed(_bcast_plan(len(hl), p)):
            sends = []
            for i, j in edges:
                sends.append(Send(hl[j], hl[i],
                                  tuple(pid for _, pid in bundles[j])))
                bundles[i].extend(bundles[j])
                bundles[j] = []
            frag.append((sends, []))
        gather_frags.append(frag)
        forwarder[h] = hl[0]
        fwd_bundle[h] = bundles[0]
    rounds += _merge_frag_lists(gather_frags, f"commute:gather:{seg}")

    # ---- stage 3: bundled inter-host rounds -----------------------------
    roots_on: dict[int, list] = defaultdict(list)
    for job in jobs:
        H = host_of(job.root)
        if job.root not in roots_on[H]:
            roots_on[H].append(job.root)
    rr: Counter = Counter()
    inter_pending = []
    for h in sorted(fwd_bundle):
        by_dst: dict[int, list] = defaultdict(list)
        for ji, pid in fwd_bundle[h]:
            by_dst[host_of(jobs[ji].root)].append((ji, pid))
        for H in sorted(by_dst):
            dst = roots_on[H][rr[H] % len(roots_on[H])]
            rr[H] += 1
            inter_pending.append(
                (forwarder[h], dst, tuple(p_ for _, p_ in by_dst[H]),
                 by_dst[H]))
    rounds += _greedy_rounds([(s, d, pids) for s, d, pids, _ in
                              inter_pending], p, f"commute:inter:{seg}")

    # ---- stage 4: intra redistribution to the roots ---------------------
    arrived: dict[int, list] = defaultdict(list)
    redis: dict[tuple[int, int], list] = defaultdict(list)
    for _, dst, _, items in inter_pending:
        for ji, pid in items:
            root = jobs[ji].root
            if dst == root:
                arrived[ji].append(pid)
            else:
                redis[(dst, root)].append((ji, pid))
    for (dst, root), items in sorted(redis.items()):
        for ji, pid in items:
            arrived[ji].append(pid)
    redis_rounds = _greedy_rounds(
        [(d, r, tuple(p_ for _, p_ in items))
         for (d, r), items in sorted(redis.items())],
        p, f"commute:redistribute:{seg}")

    # ---- stage 5: final combines recreate the original out packets ------
    final = []
    for ji, job in enumerate(jobs):
        rh = host_of(job.root)
        terms = []
        if (ji, rh) in partials:
            terms.append((one, partials[(ji, rh)][1]))
        terms.extend((one, pid) for pid in arrived[ji])
        final.append(Combine(job.root, job.out, tuple(terms)))
    if redis_rounds:
        last = redis_rounds[-1]
        redis_rounds[-1] = Round(last.sends,
                                 last.combines + tuple(final), last.tag)
    else:
        redis_rounds.append(Round((), tuple(final),
                                  f"commute:final:{seg}"))
    rounds += redis_rounds
    return rounds
