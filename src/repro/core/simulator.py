"""Round-based p-port network simulator (the paper's communication model).

The network is fully connected; time advances in rounds; in one round every
processor may send one message and receive one message per port (p ports).
Round t costs  alpha + beta * m_t  where m_t is the largest message (in field
elements) exchanged in that round.  Metrics (Sec. I):

    C1 = number of rounds
    C2 = sum_t m_t

Algorithms are written as *schedules*: python generators that yield, once per
round, a list of `Msg(src, dst, n_elems)` records (state changes are applied
by the generator itself — it simulates all processors of its group with
global knowledge, which is legitimate because scheduling and coding schemes
are data-independent, Remark 1).  The network runner:

  * advances any number of schedules in lockstep (parallel instances on
    disjoint processor groups, e.g. the M column-wise A2As of Sec. III),
  * validates the p-port constraint globally per round,
  * accounts C1 / C2 / total element traffic.

Failure model (Sec. I): `fail(procs)` erases processors statically —
schedules planned around the erasure set never touch them, and a schedule
that does raises `FailedProcessorError`.  `fail_at(round, procs)` (or the
`FaultInjector` driver) additionally injects *live* failures between rounds
of a running schedule: once `C1` reaches the registered round, the
processors die, and the first message touching one aborts `run` with a
structured `PartialRunError` carrying the exact C1/C2 of the completed
prefix plus each processor's received-so-far element counts — everything a
repair planner needs to replan against the enlarged erasure set and
account the aborted prefix plus the retry exactly.

All validation raises real exceptions (`ValueError` for malformed
messages/positions, `PortViolationError` for port-constraint breaches) —
never bare `assert`, which `python -O` strips.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field as dc_field

from ..obs.trace import get_tracer


@dataclass(frozen=True)
class RoundEvent:
    """Structured record of one accounted round (the `round_log` entry).

    round — 1-based round index on this network (== C1 after the round)
    n_msgs, m_t — message count and max message size of the round
    sent, recv — per-processor field elements moved this round, as sorted
                 ((proc, elems), ...) tuples

    Unpacks as the legacy `(n_msgs, m_t)` pair, so existing consumers of
    `round_log` (`sum(m for _, m in net.round_log)`) keep working.
    """

    round: int
    n_msgs: int
    m_t: int
    sent: tuple = ()
    recv: tuple = ()

    def __iter__(self):
        return iter((self.n_msgs, self.m_t))

    def __getitem__(self, i):
        return (self.n_msgs, self.m_t)[i]

    def __len__(self):
        return 2


@dataclass(frozen=True)
class Msg:
    src: int
    dst: int
    n_elems: int  # field elements in this message

    def __post_init__(self):
        if self.src == self.dst:
            raise ValueError(
                f"self-message {self.src}->{self.dst}: local ops are not "
                "traffic")
        if self.n_elems < 1:
            raise ValueError(f"messages carry >= 1 field elements, got "
                             f"{self.n_elems}")


class FailedProcessorError(RuntimeError):
    """A schedule tried to route traffic through an erased processor.

    `proc` is the erased processor the message touched (None when raised
    without that context)."""

    def __init__(self, message: str, proc: int | None = None):
        super().__init__(message)
        self.proc = proc


class PortViolationError(RuntimeError):
    """A round exceeded the p-port constraint on some processor (more than
    p sends or p receives)."""


class PartialRunError(FailedProcessorError):
    """`run` aborted because a live-injected kill (`fail_at` /
    `FaultInjector`) landed mid-schedule.

    The aborted round is NOT accounted (its messages were never
    delivered); the attributes snapshot everything the recover planner
    needs to restart the repair against the enlarged erasure set:

        round    — completed rounds when the abort hit (== C1)
        C1, C2   — the network's exact accounting of the completed prefix
                   (cumulative over the network's lifetime)
        proc     — the dead processor whose message aborted the round
        killed   — all processors killed by live injection so far
        failed   — the full failure set (static + injected)
        received — per-processor field elements received so far (only
                   fully-accounted rounds count; cumulative per network)
    """

    def __init__(self, net: "RoundNetwork", proc: int):
        self.round = net.C1
        self.C1 = net.C1
        self.C2 = net.C2
        self.proc = proc
        self.killed = frozenset(net.injected)
        self.failed = frozenset(net.failed)
        self.received = dict(net.received)
        RuntimeError.__init__(
            self,
            f"schedule aborted in round {net.C1 + 1}: processor {proc} was "
            f"killed mid-run (completed prefix C1={net.C1}, C2={net.C2}; "
            f"failed={sorted(net.failed)})")


@dataclass
class RoundNetwork:
    """Validates port constraints and accumulates C1/C2 across schedules.

    `keep_log` enables the per-round `RoundEvent` trace on `round_log`
    (each entry still unpacks as the legacy (n_msgs, m_t) pair); it is off
    by default so long simulations don't grow memory per round.
    `tracer` emits per-round events on per-processor tracks plus
    kill/abort instants to an `obs.trace.Tracer`; it defaults to the
    process-installed tracer (`obs.trace.get_tracer()`, None when tracing
    is off — pass `tracer=False` to silence a network while one is
    installed).
    `fail(procs)` erases processors: they may neither send nor receive, and
    any schedule touching them raises `FailedProcessorError` — repair
    schedules must route around the erasure set (Sec. I fault model).
    `fail_at(round, procs)` registers a *live* kill that fires between
    rounds once C1 reaches `round`; a running schedule that then touches a
    killed processor aborts with `PartialRunError` (see class docstring).
    `received` tracks the field elements delivered to each processor in
    fully-accounted rounds (the received-so-far state a restarted repair
    can inspect).
    `placement` (a `repro.topo.Placement`, duck-typed to avoid the import
    cycle core -> topo -> core) additionally attributes every accounted
    round to a link tier: a round is "inter" if ANY of its messages
    crosses hosts, else "intra" — so the per-tier counters sum exactly to
    C1/C2 by construction.  `by_tier()` reads them back.
    """

    n_procs: int
    p: int = 1
    keep_log: bool = False
    C1: int = 0
    C2: int = 0
    total_elems: int = 0
    placement: object = None
    c1_by_tier: dict = dc_field(default_factory=lambda: {"intra": 0,
                                                         "inter": 0})
    c2_by_tier: dict = dc_field(default_factory=lambda: {"intra": 0,
                                                         "inter": 0})
    round_log: list = dc_field(default_factory=list)
    failed: set = dc_field(default_factory=set)
    received: dict = dc_field(default_factory=dict)
    # live-injection state: pending round -> procs, and everything already
    # killed by injection (distinguishes PartialRunError from the static
    # FailedProcessorError contract)
    pending_kills: dict = dc_field(default_factory=dict, repr=False)
    injected: set = dc_field(default_factory=set, repr=False)
    # obs.trace.Tracer | None | False — resolved once at construction so
    # the per-round hot path is a single attribute check when tracing is
    # off (the zero-overhead-by-default contract)
    tracer: object = dc_field(default=None, repr=False)

    def __post_init__(self):
        if self.tracer is None:
            self.tracer = get_tracer()
        elif self.tracer is False:
            self.tracer = None
        if (self.placement is not None
                and self.placement.n_procs < self.n_procs):
            raise ValueError(
                f"placement covers {self.placement.n_procs} processors, "
                f"network has {self.n_procs}")

    def _check_procs(self, procs) -> set[int]:
        procs = {int(q) for q in procs}
        bad = [q for q in procs if not 0 <= q < self.n_procs]
        if bad:
            raise ValueError(
                f"processors {sorted(bad)} outside [0, {self.n_procs})")
        return procs

    def fail(self, procs) -> None:
        """Mark processors as erased (no sends, no receives, ever after)."""
        procs = self._check_procs(procs)
        if self.tracer is not None:
            for q in sorted(procs - self.failed):
                self.tracer.instant(
                    "fail", pid="simulator", tid=f"proc {q}", cat="sim.fail",
                    args={"round": self.C1, "proc": q})
        self.failed |= procs

    def fail_at(self, round: int, procs) -> None:
        """Register a live kill: `procs` die between rounds, as soon as C1
        reaches `round` (i.e. after `round` rounds have completed).  A
        running schedule that then touches one aborts with
        `PartialRunError`; `round` at or beyond a schedule's length simply
        never fires."""
        procs = self._check_procs(procs)
        if round < 0:
            raise ValueError(f"kill round must be >= 0, got {round}")
        self.pending_kills.setdefault(int(round), set()).update(procs)

    def apply_pending_kills(self) -> set[int]:
        """Fire every registered kill whose round has been reached; returns
        the processors newly killed.  `run` calls this between rounds; a
        repair driver calls it before (re)planning so a kill due exactly at
        the restart boundary enlarges the pattern up front."""
        due = [r for r in self.pending_kills if r <= self.C1]
        fired: set[int] = set()
        for r in due:
            fired |= self.pending_kills.pop(r)
        self.injected |= fired
        self.failed |= fired
        if fired and self.tracer is not None:
            for q in sorted(fired):
                self.tracer.instant(
                    "kill", pid="simulator", tid=f"proc {q}", cat="sim.fail",
                    args={"round": self.C1, "proc": q})
        return fired

    def _account(self, msgs: list[Msg]) -> None:
        tracer = self.tracer
        t0 = tracer.now_us() if tracer is not None else 0.0
        sends: dict[int, int] = {}
        recvs: dict[int, int] = {}
        for m in msgs:
            if not (0 <= m.src < self.n_procs and 0 <= m.dst < self.n_procs):
                raise ValueError(
                    f"message {m.src}->{m.dst} outside the "
                    f"{self.n_procs}-processor network")
            if m.src in self.failed or m.dst in self.failed:
                dead = m.src if m.src in self.failed else m.dst
                # C1 counts *completed* rounds, so the round being executed
                # is round C1 + 1 (1-based)
                raise FailedProcessorError(
                    f"round {self.C1 + 1}: message {m.src}->{m.dst} touches "
                    f"failed processor {dead}", proc=dead)
            sends[m.src] = sends.get(m.src, 0) + 1
            recvs[m.dst] = recvs.get(m.dst, 0) + 1
        over_s = {k: v for k, v in sends.items() if v > self.p}
        over_r = {k: v for k, v in recvs.items() if v > self.p}
        if over_s:
            raise PortViolationError(
                f"port violation (send): {over_s} with p={self.p}")
        if over_r:
            raise PortViolationError(
                f"port violation (recv): {over_r} with p={self.p}")
        m_t = max((m.n_elems for m in msgs), default=0)
        self.C1 += 1
        self.C2 += m_t
        if self.placement is not None:
            host_of = self.placement.host_of
            tier = ("inter" if any(host_of(m.src) != host_of(m.dst)
                                   for m in msgs) else "intra")
            self.c1_by_tier[tier] += 1
            self.c2_by_tier[tier] += m_t
        self.total_elems += sum(m.n_elems for m in msgs)
        for m in msgs:
            self.received[m.dst] = self.received.get(m.dst, 0) + m.n_elems
        if self.keep_log or tracer is not None:
            sent_e: dict[int, int] = {}
            recv_e: dict[int, int] = {}
            for m in msgs:
                sent_e[m.src] = sent_e.get(m.src, 0) + m.n_elems
                recv_e[m.dst] = recv_e.get(m.dst, 0) + m.n_elems
            ev = RoundEvent(self.C1, len(msgs), m_t,
                            tuple(sorted(sent_e.items())),
                            tuple(sorted(recv_e.items())))
            if self.keep_log:
                self.round_log.append(ev)
            if tracer is not None:
                dur = max(tracer.now_us() - t0, 0.001)
                tracer.complete(
                    "round", t0, dur, pid="simulator", tid="rounds",
                    cat="sim.round",
                    args={"round": ev.round, "n_msgs": ev.n_msgs,
                          "m_t": ev.m_t})
                for proc in sorted(set(sent_e) | set(recv_e)):
                    tracer.complete(
                        "round", t0, dur, pid="simulator",
                        tid=f"proc {proc}", cat="sim.proc",
                        args={"round": ev.round, "m_t": ev.m_t,
                              "sent": sent_e.get(proc, 0),
                              "recv": recv_e.get(proc, 0)})

    def run(self, *schedules) -> None:
        """Advance all schedules in lockstep until all are exhausted.

        A schedule that finishes early simply idles (its processors wait,
        Sec. III-B). Rounds where *no* schedule sends anything are free.
        Registered `fail_at` kills fire between rounds; if the next round
        then touches a killed processor, the run aborts with a
        `PartialRunError` snapshot (the aborted round is not accounted).
        """
        gens = [iter(s) for s in schedules]
        while gens:
            self.apply_pending_kills()
            round_msgs: list[Msg] = []
            alive = []
            for g in gens:
                try:
                    round_msgs.extend(next(g))
                    alive.append(g)
                except StopIteration:
                    pass
            gens = alive
            if round_msgs:
                try:
                    self._account(round_msgs)
                except FailedProcessorError as exc:
                    if (not isinstance(exc, PartialRunError)
                            and exc.proc in self.injected):
                        if self.tracer is not None:
                            self.tracer.instant(
                                "abort", pid="simulator",
                                tid=f"proc {exc.proc}", cat="sim.fail",
                                args={"round": self.C1, "proc": exc.proc})
                        raise PartialRunError(self, exc.proc) from exc
                    raise
            elif gens:
                # a schedule yielded an empty round (local-compute round):
                # does not consume network time in the linear cost model
                continue

    def by_tier(self) -> dict:
        """Measured per-tier accounting: {"intra": (C1, C2), "inter":
        (C1, C2)} under the network's placement (empty without one).  The
        tier entries sum exactly to the flat C1/C2."""
        if self.placement is None:
            return {}
        return {t: (self.c1_by_tier[t], self.c2_by_tier[t])
                for t in ("intra", "inter")}

    def cost(self, alpha: float, beta_bits: float) -> float:
        """C = alpha*C1 + (beta*ceil(log2 q))*C2 with beta_bits = beta*log2q."""
        return alpha * self.C1 + beta_bits * self.C2


@dataclass
class FaultInjector:
    """Driver for round-granular failure injection on a `RoundNetwork`.

    Wraps `net.fail_at` with a plan the caller can inspect: `kill_at`
    registers one kill, `random_kills` draws up to `n_kills` distinct
    victims at random round boundaries (the chaos-testing entry point —
    `launch/serve.py --chaos` builds its schedule here).  `plan` lists the
    registered (round, proc) pairs in registration order.
    """

    net: RoundNetwork
    plan: list = dc_field(default_factory=list)

    def kill_at(self, round: int, procs) -> "FaultInjector":
        self.net.fail_at(round, procs)
        procs = procs if hasattr(procs, "__iter__") else (procs,)
        self.plan.extend((int(round), int(q)) for q in procs)
        return self

    def random_kills(self, rng, candidates, n_kills: int,
                     max_round: int) -> list[tuple[int, int]]:
        """Register up to `n_kills` kills of distinct processors drawn from
        `candidates`, each at a uniform round in [0, max_round]; returns
        the registered (round, proc) pairs."""
        candidates = [int(q) for q in candidates]
        n = min(int(n_kills), len(candidates))
        victims = rng.choice(candidates, size=n, replace=False) if n else []
        out = []
        for v in victims:
            r = int(rng.integers(0, max_round + 1))
            self.kill_at(r, (int(v),))
            out.append((r, int(v)))
        return out


def run_lockstep(*gens):
    """Merge several round-schedules into one (their rounds align 1:1).

    Used for nested parallelism: e.g. each DFT stage runs K/P parallel P-sized
    prepare-and-shoot instances; the stage is itself one schedule.
    """
    iters = [iter(g) for g in gens]
    for rounds in itertools.zip_longest(*iters, fillvalue=None):
        merged: list[Msg] = []
        for r in rounds:
            if r:
                merged.extend(r)
        yield merged
