"""Round-based p-port network simulator (the paper's communication model).

The network is fully connected; time advances in rounds; in one round every
processor may send one message and receive one message per port (p ports).
Round t costs  alpha + beta * m_t  where m_t is the largest message (in field
elements) exchanged in that round.  Metrics (Sec. I):

    C1 = number of rounds
    C2 = sum_t m_t

Algorithms are written as *schedules*: python generators that yield, once per
round, a list of `Msg(src, dst, n_elems)` records (state changes are applied
by the generator itself — it simulates all processors of its group with
global knowledge, which is legitimate because scheduling and coding schemes
are data-independent, Remark 1).  The network runner:

  * advances any number of schedules in lockstep (parallel instances on
    disjoint processor groups, e.g. the M column-wise A2As of Sec. III),
  * validates the p-port constraint globally per round,
  * accounts C1 / C2 / total element traffic.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field as dc_field


@dataclass(frozen=True)
class Msg:
    src: int
    dst: int
    n_elems: int  # field elements in this message

    def __post_init__(self):
        assert self.src != self.dst, "self-messages are local ops, not traffic"
        assert self.n_elems >= 1


class FailedProcessorError(RuntimeError):
    """A schedule tried to route traffic through an erased processor."""


@dataclass
class RoundNetwork:
    """Validates port constraints and accumulates C1/C2 across schedules.

    `keep_log` enables the per-round (n_msgs, m_t) trace on `round_log`;
    it is off by default so long simulations don't grow memory per round.
    `fail(procs)` erases processors: they may neither send nor receive, and
    any schedule touching them raises `FailedProcessorError` — repair
    schedules must route around the erasure set (Sec. I fault model).
    """

    n_procs: int
    p: int = 1
    keep_log: bool = False
    C1: int = 0
    C2: int = 0
    total_elems: int = 0
    round_log: list = dc_field(default_factory=list)
    failed: set = dc_field(default_factory=set)

    def fail(self, procs) -> None:
        """Mark processors as erased (no sends, no receives, ever after)."""
        procs = {int(q) for q in procs}
        bad = [q for q in procs if not 0 <= q < self.n_procs]
        assert not bad, f"cannot fail out-of-range processors {bad}"
        self.failed |= procs

    def _account(self, msgs: list[Msg]) -> None:
        sends: dict[int, int] = {}
        recvs: dict[int, int] = {}
        for m in msgs:
            assert 0 <= m.src < self.n_procs and 0 <= m.dst < self.n_procs
            if m.src in self.failed or m.dst in self.failed:
                dead = m.src if m.src in self.failed else m.dst
                raise FailedProcessorError(
                    f"round {self.C1}: message {m.src}->{m.dst} touches "
                    f"failed processor {dead}")
            sends[m.src] = sends.get(m.src, 0) + 1
            recvs[m.dst] = recvs.get(m.dst, 0) + 1
        over_s = {k: v for k, v in sends.items() if v > self.p}
        over_r = {k: v for k, v in recvs.items() if v > self.p}
        assert not over_s, f"port violation (send): {over_s} with p={self.p}"
        assert not over_r, f"port violation (recv): {over_r} with p={self.p}"
        m_t = max((m.n_elems for m in msgs), default=0)
        self.C1 += 1
        self.C2 += m_t
        self.total_elems += sum(m.n_elems for m in msgs)
        if self.keep_log:
            self.round_log.append((len(msgs), m_t))

    def run(self, *schedules) -> None:
        """Advance all schedules in lockstep until all are exhausted.

        A schedule that finishes early simply idles (its processors wait,
        Sec. III-B). Rounds where *no* schedule sends anything are free.
        """
        gens = [iter(s) for s in schedules]
        while gens:
            round_msgs: list[Msg] = []
            alive = []
            for g in gens:
                try:
                    round_msgs.extend(next(g))
                    alive.append(g)
                except StopIteration:
                    pass
            gens = alive
            if round_msgs:
                self._account(round_msgs)
            elif gens:
                # a schedule yielded an empty round (local-compute round):
                # does not consume network time in the linear cost model
                continue

    def cost(self, alpha: float, beta_bits: float) -> float:
        """C = alpha*C1 + (beta*ceil(log2 q))*C2 with beta_bits = beta*log2q."""
        return alpha * self.C1 + beta_bits * self.C2


def run_lockstep(*gens):
    """Merge several round-schedules into one (their rounds align 1:1).

    Used for nested parallelism: e.g. each DFT stage runs K/P parallel P-sized
    prepare-and-shoot instances; the stage is itself one schedule.
    """
    iters = [iter(g) for g in gens]
    for rounds in itertools.zip_longest(*iters, fillvalue=None):
        merged: list[Msg] = []
        for r in rounds:
            if r:
                merged.extend(r)
        yield merged
