"""Mesh execution of the paper's schedules: devices as processors.

Each device along one mesh axis plays one of the paper's processors; each
communication round becomes one `jax.lax.ppermute` (the p-port model maps to
p concurrent ICI links; we emit p ppermutes per round which XLA can overlap).
All payloads are uint32 field elements (F_65537) and all per-device
coefficients are *sharded table inputs* — the schedule itself is
data-independent (Remark 1), so tables are precomputed host-side with the
exact same numpy code paths that the simulator validates.

Functions named `mesh_*` are shard_map *bodies*; `build_*_tables` are their
host-side companions.  `coded_*` wrappers in `repro.coding` wire them into
jitted train/checkpoint steps.

Slot layout (prepare phase): Bruck-style contiguous growth — slot l holds
x_{k - idx(l)} where idx maps digit-string l (base p+1, LSD first) to the
paper's offset sum_s b_s (p+1)^(T_p - s).  This keeps every round's message a
*static contiguous slice*, so lowered collective bytes match the paper's C2
accounting (up to the power-of-(p+1) padding of the shoot slots, documented
below).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.ref import gf_matmul_ref
from .field import fermat_add, fermat_mul, fermat_sub
from .matrices import StructuredPoints, gauss_inverse, vandermonde
from .prepare_shoot import phase_split

# jax < 0.5 ships shard_map under jax.experimental; newer jax at top level
shard_map = getattr(jax, "shard_map", None)
if shard_map is None:  # pragma: no cover - version dependent
    from jax.experimental.shard_map import shard_map


# ---------------------------------------------------------------------------
# grouped ppermute helper
# ---------------------------------------------------------------------------

def _group_perm(N: int, stride: int, size: int, shift: int) -> list[tuple[int, int]]:
    """Cyclic shift by `shift` within groups of `size` members spaced
    `stride` apart (group of device k: same k % stride ... k // (stride*size)).

    Covers columns (stride=Z), rows (stride=1) and the full axis
    (stride=1, size=N).
    """
    perm = []
    for k in range(N):
        base = (k // (stride * size)) * (stride * size) + (k % stride)
        pos = (k % (stride * size)) // stride
        dst = base + ((pos + shift) % size) * stride
        perm.append((k, dst))
    return perm


@dataclass(frozen=True)
class TieredAxis:
    """A (hosts x dph) hierarchical mesh axis, used AS an `axis_name`.

    The mesh bodies below are written against one flat axis of K devices
    in host-major order (device k = host k // dph, position k % dph).
    Passing a `TieredAxis` instead of the flat axis string routes every
    collective through `_tiered_ppermute`, which lowers each round onto
    the tier it actually uses — a dev-axis leg (intra-host ICI), a
    host-axis leg (inter-host DCN), or a joint permute over both axes
    when a round genuinely mixes tiers.  The permutation applied is
    identical either way, so outputs are bitwise-equal to the flat mesh.
    """

    hosts: int
    dph: int
    host_axis: str = "host"
    dev_axis: str = "dev"

    @property
    def axes(self) -> tuple[str, str]:
        return (self.host_axis, self.dev_axis)


def _tiered_ppermute(x, axis: TieredAxis, perm):
    dph = axis.dph
    if all(s // dph == d // dph for s, d in perm):
        # host-local round: one dev-axis ppermute, IF every host sees the
        # same local pair set (otherwise hosts would need distinct perms)
        by_host: dict[int, set] = {}
        for s, d in perm:
            by_host.setdefault(s // dph, set()).add((s % dph, d % dph))
        legs = set(map(frozenset, by_host.values()))
        if len(by_host) == axis.hosts and len(legs) == 1:
            return jax.lax.ppermute(x, axis.dev_axis, sorted(legs.pop()))
    if all(s % dph == d % dph for s, d in perm):
        # cross-host round at fixed device position: one host-axis ppermute
        by_pos: dict[int, set] = {}
        for s, d in perm:
            by_pos.setdefault(s % dph, set()).add((s // dph, d // dph))
        legs = set(map(frozenset, by_pos.values()))
        if len(by_pos) == dph and len(legs) == 1:
            return jax.lax.ppermute(x, axis.host_axis, sorted(legs.pop()))
    # mixed round: joint permute over the flattened (host, dev) index space
    return jax.lax.ppermute(x, axis.axes, perm)


def _ppermute(x, axis_name, perm):
    if isinstance(axis_name, TieredAxis):
        return _tiered_ppermute(x, axis_name, perm)
    return jax.lax.ppermute(x, axis_name, perm)


# ---------------------------------------------------------------------------
# universal prepare-and-shoot on a mesh axis (or sub-groups of it)
# ---------------------------------------------------------------------------

def _slot_index_map(p: int, T_p: int) -> list[int]:
    """idx(l): slot l (digits LSD-first base p+1) -> paper offset delta."""
    m = (p + 1) ** T_p
    idx = []
    for l in range(m):
        digs = []
        ll = l
        for _ in range(T_p):
            digs.append(ll % (p + 1))
            ll //= p + 1
        # digit b_s (s = 1..T_p) contributes b_s * (p+1)^(T_p - s)
        delta = sum(b * (p + 1) ** (T_p - s - 1) for s, b in enumerate(digs))
        idx.append(delta)
    return idx


@dataclass(frozen=True)
class UniversalTables:
    """Per-device constants for mesh prepare-and-shoot of one matrix set."""

    K: int          # group size (paper's K)
    p: int
    T_p: int
    T_s: int
    m: int
    n: int          # ceil(K/m)
    n_pad: int      # (p+1)^T_s slot padding
    coef: np.ndarray  # (N, n_pad, m) uint32 — shoot-packet init coefficients
    corr: np.ndarray  # (N, m) uint32 — eq. (4) overlap correction
    group_stride: int
    group_size: int


def build_universal_tables(
    field, mats: list[np.ndarray], N: int, p: int, group_stride: int = 1
) -> UniversalTables:
    """Tables for parallel prepare-and-shoot instances on groups of size K.

    `mats[g]` is the K x K matrix of group g; groups partition the N devices
    with members spaced `group_stride` apart (see _group_perm). Requires
    m <= K (true whenever K >= p+1 ... asserted).
    """
    K = mats[0].shape[0]
    n_groups = N // K
    assert len(mats) == n_groups
    L, T_p, T_s, m = phase_split(K, p)
    assert m <= K, f"tiny-group corner (m={m} > K={K}) unsupported on mesh"
    n = math.ceil(K / m)
    n_pad = (p + 1) ** T_s
    idx = _slot_index_map(p, T_p)
    coef = np.zeros((N, n_pad, m), np.uint32)
    corr = np.zeros((N, m), np.uint32)
    for dev in range(N):
        pos = (dev % (group_stride * K)) // group_stride  # local index k
        # group id: enumerate groups in the same order as mats
        g = (dev // (group_stride * K)) * group_stride + (dev % group_stride)
        C = np.asarray(mats[g], np.int64) % field.q
        k = pos
        for l_t in range(n):
            s = (k + l_t * m) % K
            for l in range(m):
                coef[dev, l_t, l] = C[(k - idx[l]) % K, s]
        # eq. (4): offsets delta in [0, m*n - K) duplicated once
        dup = m * n - K
        for l in range(m):
            if idx[l] < dup:
                corr[dev, l] = C[(k - idx[l]) % K, k]
    return UniversalTables(K, p, T_p, T_s, m, n, n_pad, coef, corr,
                           group_stride, K)


def mesh_universal_a2a(x, coef, corr, tables: UniversalTables, axis_name: str):
    """shard_map body: x (W,) uint32 per device -> encoded (W,) per device.

    coef (n_pad, m) / corr (m,) are this device's sharded table rows.
    """
    K, p, T_p, T_s, m = tables.K, tables.p, tables.T_p, tables.T_s, tables.m
    N = tables.coef.shape[0]
    W = x.shape[-1] if x.ndim else 1
    x = x.reshape(1, -1).astype(jnp.uint32)

    # ---- prepare: Bruck-contiguous growth --------------------------------
    buf = jnp.zeros((m, x.shape[-1]), jnp.uint32).at[0].set(x[0])
    size = 1
    for t in range(1, T_p + 1):
        stride = (p + 1) ** (T_p - t)
        pieces = [buf[:size]]
        for rho in range(1, p + 1):
            perm = _group_perm(N, tables.group_stride, K, rho * stride)
            pieces.append(_ppermute(buf[:size], axis_name, perm))
        size *= p + 1
        buf = jnp.concatenate(pieces + [buf[size:]], axis=0) if size < m else jnp.concatenate(pieces, axis=0)
        buf = buf[:m]

    # ---- local encode (the gf_matmul hot-spot) ----------------------------
    w = gf_matmul_ref(coef.astype(jnp.uint32), buf)  # (n_pad, W)

    # ---- shoot: (p+1)-nomial reduce of the w slots ------------------------
    for t in range(1, T_s + 1):
        blk = (p + 1) ** t
        sub = (p + 1) ** (t - 1)
        w_r = w.reshape(tables.n_pad // blk, blk, -1)
        acc = w_r[:, 0]
        for rho in range(1, p + 1):
            sel = w_r[:, rho * sub]  # slots this device must send
            perm = _group_perm(N, tables.group_stride, K, rho * sub * m)
            recv = _ppermute(sel, axis_name, perm)
            acc = fermat_add(acc, recv)
        # survivor slots are ltarget multiples of blk: repack contiguously
        keep = jnp.zeros((tables.n_pad // blk, blk, w.shape[-1]), jnp.uint32)
        keep = keep.at[:, 0].set(acc)
        # retain not-yet-consumed lower-digit slots for later rounds
        for r_keep in range(1, blk):
            if r_keep % sub == 0 and r_keep // sub in range(1, p + 1):
                continue  # consumed this round
            keep = keep.at[:, r_keep].set(w_r[:, r_keep])
        w = keep.reshape(tables.n_pad, -1)

    y = w[0]
    # ---- eq. (4) overlap correction ---------------------------------------
    dup_term = gf_matmul_ref(corr.astype(jnp.uint32)[None, :], buf)[0]
    return fermat_sub(y, dup_term)


# ---------------------------------------------------------------------------
# radix-2 DFT stages on a mesh axis (Sec. V-A, P = 2)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DFTTables:
    Z: int          # group size = 2^H
    H: int
    ca: np.ndarray  # (H, N) uint32: own coefficient per stage
    cb: np.ndarray  # (H, N) uint32: partner coefficient per stage
    group_stride: int


def build_dft_tables(
    field, N: int, Z: int, group_stride: int = 1, inverse: bool = False
) -> DFTTables:
    """Radix-2 permuted-DFT stage coefficients for groups of size Z."""
    from .dft_a2a import _stage_matrix

    H = int(round(math.log2(Z)))
    assert 2**H == Z and (field.q - 1) % Z == 0
    ca = np.zeros((H, N), np.uint32)
    cb = np.zeros((H, N), np.uint32)
    stages = range(H)
    for h in stages:
        pos = 2 ** (H - h - 1)
        for dev in range(N):
            j = (dev % (group_stride * Z)) // group_stride  # index in group
            member0 = j & ~pos  # group member with bit cleared
            mat = _stage_matrix(field, Z, 2, H, h, member0)
            if inverse:
                mat = gauss_inverse(field, mat)
            d = (j >> int(math.log2(pos))) & 1
            ca[h, dev] = mat[d, d]
            cb[h, dev] = mat[1 - d, d]
    if inverse:
        ca = ca[::-1].copy()
        cb = cb[::-1].copy()
    return DFTTables(Z, H, ca, cb, group_stride)


def mesh_dft(x, ca, cb, tables: DFTTables, axis_name: str, inverse: bool = False):
    """shard_map body: per-device (W,) -> (W,). ca/cb are (H,) table rows.

    Stage order is baked into the tables (build with inverse=True for the
    inverse transform). Each stage: one pairwise exchange + butterfly.
    """
    N = tables.ca.shape[1]
    Z, H = tables.Z, tables.H
    v = x.astype(jnp.uint32)
    for h in range(H):
        pos = 2 ** (H - h - 1) if not inverse else 2 ** h
        perm = []
        for k in range(N):
            j = (k % (tables.group_stride * Z)) // tables.group_stride
            jp = j ^ pos
            dst = k + (jp - j) * tables.group_stride
            perm.append((k, dst))
        recv = _ppermute(v, axis_name, perm)
        v = fermat_add(fermat_mul(ca[h], v), fermat_mul(cb[h], recv))
    return v


# ---------------------------------------------------------------------------
# draw-and-loose on a mesh axis (Sec. V-B)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DrawLooseTables:
    sp: StructuredPoints
    univ: UniversalTables | None  # draw phase (columns, size M), None if M=1
    dft: DFTTables | None         # loose phase (rows, size Z), None if Z=1
    scale: np.ndarray             # (N,) uint32 alpha_i^j (or inverse)
    inverse: bool


def build_draw_loose_tables(
    field, sp: StructuredPoints, N_devices: int, p: int, inverse: bool = False
) -> DrawLooseTables:
    M, Z = sp.M, sp.Z
    K = M * Z
    n_rep = N_devices // K  # multiple independent grids along the axis
    univ = None
    if M > 1:
        vm = _v_m_matrix(field, sp)
        if inverse:
            vm = gauss_inverse(field, vm)
        univ = build_universal_tables(field, [vm] * (Z * n_rep), N_devices, p,
                                      group_stride=Z)
    dft = None
    if Z > 1:
        dft = build_dft_tables(field, N_devices, Z, group_stride=1,
                               inverse=inverse)
    scale = np.zeros(N_devices, np.uint32)
    for dev in range(N_devices):
        k = dev % K
        i, j = k // Z, k % Z
        s = pow(sp.alpha(i), j, field.q)
        if inverse:
            s = pow(s, field.q - 2, field.q)
        scale[dev] = s
    return DrawLooseTables(sp, univ, dft, scale, inverse)


def _v_m_matrix(field, sp: StructuredPoints) -> np.ndarray:
    alphas_z = np.array([pow(sp.alpha(i), sp.Z, field.q) for i in range(sp.M)],
                        np.int64)
    return vandermonde(field, alphas_z)


def mesh_draw_loose(x, t: DrawLooseTables, table_rows: dict, axis_name: str):
    """shard_map body. table_rows carries this device's sharded rows:
    {'coef','corr','ca','cb','scale'} as applicable."""
    v = x.astype(jnp.uint32)
    if not t.inverse:
        if t.univ is not None:
            v = mesh_universal_a2a(v, table_rows["coef"], table_rows["corr"],
                                   t.univ, axis_name)
        v = fermat_mul(table_rows["scale"], v)
        if t.dft is not None:
            v = mesh_dft(v, table_rows["ca"], table_rows["cb"], t.dft,
                         axis_name, inverse=False)
    else:
        if t.dft is not None:
            v = mesh_dft(v, table_rows["ca"], table_rows["cb"], t.dft,
                         axis_name, inverse=True)
        v = fermat_mul(table_rows["scale"], v)
        if t.univ is not None:
            v = mesh_universal_a2a(v, table_rows["coef"], table_rows["corr"],
                                   t.univ, axis_name)
    return v


# ---------------------------------------------------------------------------
# generic schedule-IR lowering: compile ANY `core.schedule.RoundIR` (in
# particular a `tier_commute`-rewritten one, whose rounds no longer match
# the hand-built table paths above) into per-device slot tables + ppermute
# legs.  The hand-specialized mesh_* bodies above stay the fast path for
# canonical schedules; this is the general one.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class IRLeg:
    """One partial-permutation step of a round: every device sends/receives
    at most once; messages are `width`-lane packet bundles (short bundles
    pad with trash-slot lanes that receivers scatter back to trash)."""

    perm: tuple                 # ((src_dev, dst_dev), ...)
    gather: np.ndarray          # (n_dev, width) int32 slots to read
    scatter: np.ndarray         # (n_dev, width) int32 slots to write


@dataclass(frozen=True)
class IRCombineLayer:
    """One dependency layer of a round's combines (terms only reference
    slots written by earlier rounds/legs/layers), as padded per-device
    tables: out <- sum_t coeff[., t] * buf[term[., t]]."""

    out_idx: np.ndarray         # (n_dev, n_comb) int32 (pad -> trash)
    coeff: np.ndarray           # (n_dev, n_comb, n_term) uint32 (pad -> 0)
    term: np.ndarray            # (n_dev, n_comb, n_term) int32


@dataclass(frozen=True)
class IRMeshProgram:
    """A `RoundIR` compiled for devices-as-processors execution: per-device
    packet slots (slot 0 is the trash slot all padding routes through),
    and per round a list of ppermute legs plus combine layers."""

    n_dev: int
    n_slots: int
    init_slot: np.ndarray       # (n_dev,) int32 slot of the local input row
    out_slot: np.ndarray        # (n_dev,) int32 slot of the local output row
    rounds: tuple               # ((legs, layers), ...) per IR round

    def device_arrays(self) -> dict[str, np.ndarray]:
        """All (n_dev, ...) tables keyed for sharded shard_map args."""
        arrs = {"init": self.init_slot[:, None], "out": self.out_slot[:, None]}
        for r, (legs, layers) in enumerate(self.rounds):
            for i, leg in enumerate(legs):
                arrs[f"g{r}_{i}"] = leg.gather
                arrs[f"s{r}_{i}"] = leg.scatter
            for i, lay in enumerate(layers):
                arrs[f"o{r}_{i}"] = lay.out_idx
                arrs[f"c{r}_{i}"] = lay.coeff
                arrs[f"t{r}_{i}"] = lay.term
        return arrs


def build_ir_mesh_program(ir, dev_of: list[int]) -> IRMeshProgram:
    """Compile `ir` (a `core.schedule.RoundIR`) against the processor ->
    device overlay `dev_of` (encode: source k -> device k, sink K+r ->
    device r, the Sec. III-A grid).  Sends between processors that share a
    device are free (one per-device buffer); cross-device sends decompose
    into partial-permutation legs with at most one send and one receive
    per device; combines split into intra-round dependency layers."""
    n_dev = max(dev_of) + 1
    TRASH = 0
    next_slot = [1] * n_dev                       # slot 0 = trash
    slot_of: dict[tuple[int, int], int] = {}      # (dev, packet) -> slot

    def alloc(dev: int, pid: int) -> int:
        key = (dev, pid)
        if key not in slot_of:
            slot_of[key] = next_slot[dev]
            next_slot[dev] += 1
        return slot_of[key]

    init_slot = np.zeros(n_dev, np.int32)
    for proc, pid in ir.inputs:
        init_slot[dev_of[proc]] = alloc(dev_of[proc], pid)

    rounds = []
    for rnd in ir.rounds:
        # ---- sends -> partial-permutation legs --------------------------
        cross = [s for s in rnd.sends
                 if dev_of[s.src] != dev_of[s.dst]]
        leg_sends: list[list] = []
        for s in cross:
            placed = False
            for leg in leg_sends:
                if all(dev_of[s.src] != dev_of[o.src]
                       and dev_of[s.dst] != dev_of[o.dst] for o in leg):
                    leg.append(s)
                    placed = True
                    break
            if not placed:
                leg_sends.append([s])
        legs = []
        for sends in leg_sends:
            width = max(len(s.packets) for s in sends)
            gather = np.full((n_dev, width), TRASH, np.int32)
            scatter = np.full((n_dev, width), TRASH, np.int32)
            perm = []
            for s in sends:
                sd, dd = dev_of[s.src], dev_of[s.dst]
                perm.append((sd, dd))
                for i, pid in enumerate(s.packets):
                    gather[sd, i] = slot_of[(sd, pid)]
                    scatter[dd, i] = alloc(dd, pid)
            legs.append(IRLeg(tuple(sorted(perm)), gather, scatter))
        for s in rnd.sends:                       # same-device: already held
            if dev_of[s.src] == dev_of[s.dst]:
                for pid in s.packets:
                    slot_of[(dev_of[s.dst], pid)] = slot_of[
                        (dev_of[s.src], pid)]

        # ---- combines -> dependency layers ------------------------------
        layer_of: dict[int, int] = {}             # out pid -> layer index
        grouped: list[list] = []
        for c in rnd.combines:
            lvl = 0
            for _, pid in c.terms:
                if pid in layer_of:
                    lvl = max(lvl, layer_of[pid] + 1)
            layer_of[c.out] = lvl
            while len(grouped) <= lvl:
                grouped.append([])
            grouped[lvl].append(c)
        layers = []
        for combs in grouped:
            per_dev: dict[int, list] = {}
            for c in combs:
                per_dev.setdefault(dev_of[c.proc], []).append(c)
            n_comb = max(len(v) for v in per_dev.values())
            n_term = max((len(c.terms) for c in combs), default=0) or 1
            out_idx = np.full((n_dev, n_comb), TRASH, np.int32)
            coeff = np.zeros((n_dev, n_comb, n_term), np.uint32)
            term = np.full((n_dev, n_comb, n_term), TRASH, np.int32)
            for dev, cs in per_dev.items():
                for i, c in enumerate(cs):
                    out_idx[dev, i] = alloc(dev, c.out)
                    for t, (cref, pid) in enumerate(c.terms):
                        coeff[dev, i, t] = ir.coeffs[cref] % ir.q
                        term[dev, i, t] = slot_of[(dev, pid)]
            layers.append(IRCombineLayer(out_idx, coeff, term))
        rounds.append((tuple(legs), tuple(layers)))

    out_slot = np.zeros(n_dev, np.int32)
    for proc, pid in ir.outputs:
        out_slot[dev_of[proc]] = slot_of[(dev_of[proc], pid)]
    return IRMeshProgram(n_dev, max(next_slot), init_slot, out_slot,
                         tuple(rounds))


def mesh_ir_encode(x, rows: dict, prog: IRMeshProgram, axis_name):
    """shard_map body: per-device (W,) uint32 -> (W,) uint32 running the
    compiled IR program.  `rows` carries this device's rows of
    `prog.device_arrays()` (leading n_dev axis already sharded away)."""
    W = x.shape[-1]
    buf = jnp.zeros((prog.n_slots, W), jnp.uint32)
    buf = buf.at[rows["init"][0]].set(x.astype(jnp.uint32))
    for r, (legs, layers) in enumerate(prog.rounds):
        for i, leg in enumerate(legs):
            sel = buf[rows[f"g{r}_{i}"]]              # (width, W)
            recv = _ppermute(sel, axis_name, list(leg.perm))
            buf = buf.at[rows[f"s{r}_{i}"]].set(recv)
            buf = buf.at[0].set(jnp.zeros((W,), jnp.uint32))  # re-arm trash
        for i, _lay in enumerate(layers):
            coeff = rows[f"c{r}_{i}"]                 # (n_comb, n_term)
            vals = buf[rows[f"t{r}_{i}"]]             # (n_comb, n_term, W)
            acc = jnp.zeros(vals.shape[:1] + vals.shape[2:], jnp.uint32)
            for t in range(coeff.shape[1]):
                acc = fermat_add(acc, fermat_mul(coeff[:, t, None],
                                                 vals[:, t]))
            buf = buf.at[rows[f"o{r}_{i}"]].set(acc)
            buf = buf.at[0].set(jnp.zeros((W,), jnp.uint32))
    return buf[rows["out"][0]]
