"""Core reproduction of *On the Encoding Process in Decentralized Systems*.

Public API — start with the unified planner (`repro.api`), which fronts
everything in this package behind one plan-then-execute call:

    from repro.api import CodeSpec, Encoder
    plan = Encoder.plan(CodeSpec(kind="rs", K=16, R=4), backend="simulator")
    parity = plan.run(x)      # identical sinks on "mesh" and "local" too

`Encoder.plan` picks the cheapest schedule via `cost_model`, caches all
host-side tables per spec, and executes on the round-network simulator, the
shard_map/ppermute mesh, or the local Pallas/jnp kernel.

The round schedule itself is a first-class IR (`schedule.RoundIR`): one
backend-neutral program per plan, produced by per-algorithm builders
(`build_encode_ir` / `build_decode_ir`), checked by `RoundIR.validate()`,
attributed per network tier by `RoundIR.attribute(placement)`, rewritten
host-aware by `RoundIR.tier_commute(placement)`, and lowered to all three
backends (`schedule.execute` on the simulator; `shardmap_exec`'s table
fast paths or the generic `build_ir_mesh_program` on the mesh; the local
tables via `RoundIR.coeff_matrix()`).

Engine-level entry points (stable; the builders transcribe these papers'
schedules, and they remain the right layer for paper-fidelity
experiments):
    Field, FERMAT               — finite fields (field.py)
    RoundNetwork, Msg           — the paper's communication model (simulator.py)
    schedule                    — the RoundIR layer (builders/passes/lowerings)
    prepare_shoot, universal_a2a — Sec. IV universal algorithm
    dft_a2a                     — Sec. V-A permuted-DFT algorithm
    draw_loose, StructuredPoints — Sec. V-B Vandermonde algorithm
    StructuredGRS, cauchy_a2a   — Sec. VI systematic RS / Lagrange
    decentralized_encode        — Sec. III framework (retired generator
                                  entry point; the planners now execute
                                  `schedule` IR — this shim stays for
                                  direct paper-fidelity use)
    nonsystematic_encode        — Appendix B
    cost_model                  — Table I analytic costs + baselines
    parity.build_encode_tables  — mesh tables for any generator block
    shardmap_exec               — shard_map bodies + host table builders

Legacy direct call sites (`decentralized_encode(...)`, per-kind generator
dispatch, `shardmap_exec.build_*_tables(...)` at every use) are superseded
by `Encoder.plan` + the `schedule` IR — the planner caches tables and
programs and selects algorithms; prefer it in new code.
"""
from . import cost_model, schedule
from .cauchy import (
    StructuredGRS as StructuredGRSCode,
    cauchy_a2a,
    cost_cauchy,
    lagrange_a2a,
)
from .dft_a2a import cost_dft, dft_a2a
from .draw_loose import cost_draw_loose, draw_loose
from .field import FERMAT, FERMAT_Q, Field
from .framework import decentralized_encode, nonsystematic_encode
from .matrices import (
    StructuredPoints,
    SystematicGRS,
    dft_matrix,
    gauss_inverse,
    lagrange_matrix,
    permuted_dft_matrix,
    vandermonde,
)
from .prepare_shoot import cost_universal, prepare_shoot, universal_a2a
from .schedule import (
    RoundIR,
    ScheduleValidationError,
    build_decode_ir,
    build_encode_ir,
    build_universal_a2a_ir,
)
from .schedule import execute as execute_schedule
from .simulator import (
    FailedProcessorError,
    FaultInjector,
    Msg,
    PartialRunError,
    PortViolationError,
    RoundNetwork,
    run_lockstep,
)

__all__ = [
    "FERMAT", "FERMAT_Q", "Field", "FailedProcessorError", "Msg",
    "RoundNetwork", "run_lockstep",
    "FaultInjector", "PartialRunError", "PortViolationError",
    "schedule", "RoundIR", "ScheduleValidationError",
    "build_encode_ir", "build_decode_ir", "build_universal_a2a_ir",
    "execute_schedule",
    "prepare_shoot", "universal_a2a", "cost_universal",
    "dft_a2a", "cost_dft", "draw_loose", "cost_draw_loose",
    "StructuredPoints", "SystematicGRS", "StructuredGRSCode",
    "dft_matrix", "permuted_dft_matrix", "vandermonde", "gauss_inverse",
    "lagrange_matrix", "cauchy_a2a", "cost_cauchy", "lagrange_a2a",
    "decentralized_encode", "nonsystematic_encode", "cost_model",
]
