"""Core reproduction of *On the Encoding Process in Decentralized Systems*.

Public API — start with the unified planner (`repro.api`), which fronts
everything in this package behind one plan-then-execute call:

    from repro.api import CodeSpec, Encoder
    plan = Encoder.plan(CodeSpec(kind="rs", K=16, R=4), backend="simulator")
    parity = plan.run(x)      # identical sinks on "mesh" and "local" too

`Encoder.plan` picks the cheapest schedule via `cost_model`, caches all
host-side tables per spec, and executes on the round-network simulator, the
shard_map/ppermute mesh, or the local Pallas/jnp kernel.

Engine-level entry points (what the planner schedules; stable, and still
the right layer for new algorithms or paper-fidelity experiments):
    Field, FERMAT               — finite fields (field.py)
    RoundNetwork, Msg           — the paper's communication model (simulator.py)
    prepare_shoot, universal_a2a — Sec. IV universal algorithm
    dft_a2a                     — Sec. V-A permuted-DFT algorithm
    draw_loose, StructuredPoints — Sec. V-B Vandermonde algorithm
    StructuredGRS, cauchy_a2a   — Sec. VI systematic RS / Lagrange
    decentralized_encode        — Sec. III framework (simulator backend body)
    nonsystematic_encode        — Appendix B
    cost_model                  — Table I analytic costs + baselines
    parity.build_encode_tables  — mesh tables for any generator block
    shardmap_exec               — shard_map bodies + host table builders

Legacy direct call sites (`decentralized_encode(...)`,
`shardmap_exec.build_*_tables(...)` at every use) are superseded by
`Encoder.plan` — the planner is the only layer that caches tables and
selects algorithms; prefer it in new code.
"""
from . import cost_model
from .cauchy import (
    StructuredGRS as StructuredGRSCode,
    cauchy_a2a,
    cost_cauchy,
    lagrange_a2a,
)
from .dft_a2a import cost_dft, dft_a2a
from .draw_loose import cost_draw_loose, draw_loose
from .field import FERMAT, FERMAT_Q, Field
from .framework import decentralized_encode, nonsystematic_encode
from .matrices import (
    StructuredPoints,
    SystematicGRS,
    dft_matrix,
    gauss_inverse,
    lagrange_matrix,
    permuted_dft_matrix,
    vandermonde,
)
from .prepare_shoot import cost_universal, prepare_shoot, universal_a2a
from .simulator import (
    FailedProcessorError,
    FaultInjector,
    Msg,
    PartialRunError,
    PortViolationError,
    RoundNetwork,
    run_lockstep,
)

__all__ = [
    "FERMAT", "FERMAT_Q", "Field", "FailedProcessorError", "Msg",
    "RoundNetwork", "run_lockstep",
    "FaultInjector", "PartialRunError", "PortViolationError",
    "prepare_shoot", "universal_a2a", "cost_universal",
    "dft_a2a", "cost_dft", "draw_loose", "cost_draw_loose",
    "StructuredPoints", "SystematicGRS", "StructuredGRSCode",
    "dft_matrix", "permuted_dft_matrix", "vandermonde", "gauss_inverse",
    "lagrange_matrix", "cauchy_a2a", "cost_cauchy", "lagrange_a2a",
    "decentralized_encode", "nonsystematic_encode", "cost_model",
]
