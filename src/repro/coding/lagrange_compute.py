"""Lagrange coded computing (Remark 9 / Yu et al. [9]) over F_65537.

Masterless LCC: K data shards x_0..x_{K-1} in F_q^W are interpolated into a
polynomial g with g(alpha_k) = x_k; each of N workers holds the coded shard
x~_n = g(beta_n) — produced decentralized via the paper's Cauchy-like
all-to-all encode (the Lagrange matrix V_alpha^-1 V_beta, Remark 9).
Workers apply a polynomial f of degree d elementwise; the results
f(g(beta_n)) are evaluations of h = f o g (degree d*(K-1)), so ANY
d*(K-1)+1 worker results reconstruct every f(x_k) — stragglers and even
Byzantine-silent workers are tolerated by construction.

Decoding is an erasure decode, not a bespoke solve: h is a degree-(T-1)
polynomial (T = d*(K-1)+1), so its evaluations over alphas ∪ betas form a
length-(K+N) MDS code with T data symbols.  The alphas (and any dead
betas) are the erasures; `Decoder.plan` repairs them through the same
cached decode-plan path — and the same drift/metrics instrumentation — the
storage stack uses.  Non-Fermat fields fall back to the host interpolation
loop (the uint32 kernels are Fermat-only).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..api import CodedSystem, CodeSpec, EncodePlan
from ..core.field import Field
from ..core.matrices import lagrange_matrix
from .gradient_code import FERMAT_Q, default_backend


@dataclass(frozen=True)
class LagrangeComputer:
    field: Field
    alphas: np.ndarray  # (K,)
    betas: np.ndarray   # (N,)

    @property
    def K(self):
        return self.alphas.size

    @property
    def N(self):
        return self.betas.size

    @staticmethod
    def build(field: Field, K: int, N: int) -> "LagrangeComputer":
        pts = np.arange(1, K + N + 1, dtype=np.int64)
        return LagrangeComputer(field, pts[:K], pts[K:])

    def system(self, *, backend: str | None = None) -> CodedSystem:
        """The `CodedSystem` session for this computer's Lagrange matrix.

        Arbitrary (unstructured) interpolation points, so the planner
        schedules the universal algorithm; the session (and its Lagrange
        matrix) is memoized here and in the shared plan caches across
        encodes.  Default backend: `default_backend(q)` — the local kernel
        for F_65537, the exact simulator for other fields."""
        if backend is None:
            backend = default_backend(self.field.q)
        cached = self.__dict__.get(f"_system_{backend}")
        if cached is None:
            L = lagrange_matrix(self.field, self.alphas, self.betas)
            spec = CodeSpec(kind="lagrange", K=self.K, R=self.N, q=self.field.q)
            cached = CodedSystem(spec, backend=backend, A=L)
            object.__setattr__(self, f"_system_{backend}", cached)
        return cached

    def encode_plan(self, *, backend: str | None = None) -> EncodePlan:
        """The planner-layer `EncodePlan` behind `system(backend=...)`."""
        return self.system(backend=backend).encode_plan

    def encode(self, x: np.ndarray) -> np.ndarray:
        """x: (K, W) -> coded (N, W) = L^T x, L = V_alpha^-1 V_beta.

        Executes via `CodedSystem.encode` on the local kernel backend
        (previously an inline field.matmul)."""
        return self.system().encode(x)

    def recovery_threshold(self, deg: int) -> int:
        return deg * (self.K - 1) + 1

    def _decode_spec(self, deg: int) -> tuple[CodeSpec, np.ndarray]:
        """The virtual erasure code behind a degree-`deg` decode.

        h = f∘g has degree ≤ T-1 (T the recovery threshold), so its
        evaluations at nodes = alphas ∪ betas are a (K+N, T) MDS code:
        any T nodes are data, the rest parity.  Memoized per deg — the
        parity matrix costs an interpolation to build but every repeat
        decode (and every straggler pattern) then shares `Decoder.plan`'s
        LRU cache."""
        key = f"_decode_spec_{deg}"
        cached = self.__dict__.get(key)
        if cached is None:
            T = self.recovery_threshold(deg)
            nodes = np.concatenate([self.field.arr(self.alphas),
                                    self.field.arr(self.betas)])
            if T >= nodes.size:
                raise ValueError(
                    f"degree {deg} needs T={T} of N={self.N} workers — "
                    "no redundancy left to decode around")
            A = lagrange_matrix(self.field, nodes[:T], nodes[T:])
            spec = CodeSpec(kind="lagrange", K=T, R=nodes.size - T,
                            q=self.field.q)
            cached = (spec, A)
            object.__setattr__(self, key, cached)
        return cached

    def decode(self, deg: int, worker_ids: np.ndarray,
               results: np.ndarray) -> np.ndarray:
        """Interpolate h from >= deg*(K-1)+1 worker results, return f(x_k).

        worker_ids: indices into `betas` of the workers that returned;
        `results[i]` is worker `worker_ids[i]`'s f(x~) evaluation.  Routed
        through `Decoder.plan` (the cached decode-plan path shared with the
        storage stack): the alphas and the dead betas are erasures of the
        virtual code from `_decode_spec`, and the repaired alpha symbols
        are exactly f(x_k).  Falls back to `_decode_host` for non-Fermat q.
        """
        f = self.field
        T = self.recovery_threshold(deg)
        worker_ids = np.asarray(worker_ids, dtype=np.int64)
        assert worker_ids.size >= T, "not enough workers returned"
        if f.q != FERMAT_Q:
            return self._decode_host(deg, worker_ids, results)

        from ..recover.planner import Decoder

        spec, A = self._decode_spec(deg)
        live = set(int(w) for w in worker_ids)
        # node positions: alphas at 0..K-1, beta_b at K+b
        erased = tuple(range(self.K)) + tuple(
            self.K + b for b in range(self.N) if b not in live)
        plan = Decoder.plan(spec, erased, backend=default_backend(f.q), A=A)

        vals = f.arr(results)
        row_of = {int(w): i for i, w in enumerate(worker_ids)}
        v = np.stack([vals[row_of[pos - self.K]] for pos in plan.kept])
        tail = v.shape[1:]
        repaired = plan.run(v.reshape(T, -1) if tail else v)
        # plan.erased is sorted and contains every alpha position, so the
        # first K repaired rows are h(alpha_k) = f(x_k)
        out = repaired[:self.K]
        return out.reshape((self.K,) + tail) if tail else out

    def _decode_host(self, deg: int, worker_ids: np.ndarray,
                     results: np.ndarray) -> np.ndarray:
        """Host Lagrange interpolation of h at the alphas — the exact
        fallback for fields the kernel backends don't support."""
        f = self.field
        T = self.recovery_threshold(deg)
        pts = self.betas[worker_ids[:T]]
        vals = f.arr(results[:T])
        out = np.zeros((self.K,) + vals.shape[1:], np.int64)
        for j, a in enumerate(self.alphas):
            acc = np.zeros(vals.shape[1:], np.int64)
            for i in range(T):
                num, den = np.int64(1), np.int64(1)
                for t in range(T):
                    if t == i:
                        continue
                    num = f.mul(num, f.sub(a, pts[t]))
                    den = f.mul(den, f.sub(pts[i], pts[t]))
                acc = f.add(acc, f.mul(vals[i], f.mul(num, f.inv(den))))
            out[j] = acc
        return out
