"""Lagrange coded computing (Remark 9 / Yu et al. [9]) over F_65537.

Masterless LCC: K data shards x_0..x_{K-1} in F_q^W are interpolated into a
polynomial g with g(alpha_k) = x_k; each of N workers holds the coded shard
x~_n = g(beta_n) — produced decentralized via the paper's Cauchy-like
all-to-all encode (the Lagrange matrix V_alpha^-1 V_beta, Remark 9).
Workers apply a polynomial f of degree d elementwise; the results
f(g(beta_n)) are evaluations of h = f o g (degree d*(K-1)), so ANY
d*(K-1)+1 worker results reconstruct every f(x_k) — stragglers and even
Byzantine-silent workers are tolerated by construction.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..api import CodedSystem, CodeSpec, EncodePlan
from ..core.field import Field
from ..core.matrices import lagrange_matrix


@dataclass(frozen=True)
class LagrangeComputer:
    field: Field
    alphas: np.ndarray  # (K,)
    betas: np.ndarray   # (N,)

    @property
    def K(self):
        return self.alphas.size

    @property
    def N(self):
        return self.betas.size

    @staticmethod
    def build(field: Field, K: int, N: int) -> "LagrangeComputer":
        pts = np.arange(1, K + N + 1, dtype=np.int64)
        return LagrangeComputer(field, pts[:K], pts[K:])

    def system(self, backend: str | None = None) -> CodedSystem:
        """The `CodedSystem` session for this computer's Lagrange matrix.

        Arbitrary (unstructured) interpolation points, so the planner
        schedules the universal algorithm; the session (and its Lagrange
        matrix) is memoized here and in the shared plan caches across
        encodes.  Default backend: the local kernel for F_65537, the exact
        simulator for other fields (the uint32 kernels are Fermat-only)."""
        if backend is None:
            backend = "local" if self.field.q == 65537 else "simulator"
        cached = self.__dict__.get(f"_system_{backend}")
        if cached is None:
            L = lagrange_matrix(self.field, self.alphas, self.betas)
            spec = CodeSpec(kind="lagrange", K=self.K, R=self.N, q=self.field.q)
            cached = CodedSystem(spec, backend=backend, A=L)
            object.__setattr__(self, f"_system_{backend}", cached)
        return cached

    def encode_plan(self, backend: str | None = None) -> EncodePlan:
        """The planner-layer `EncodePlan` behind `system(backend)`."""
        return self.system(backend).encode_plan

    def encode(self, x: np.ndarray) -> np.ndarray:
        """x: (K, W) -> coded (N, W) = L^T x, L = V_alpha^-1 V_beta.

        Executes via `CodedSystem.encode` on the local kernel backend
        (previously an inline field.matmul)."""
        return self.system().encode(x)

    def recovery_threshold(self, deg: int) -> int:
        return deg * (self.K - 1) + 1

    def decode(self, deg: int, worker_ids: np.ndarray, results: np.ndarray) -> np.ndarray:
        """Interpolate h from >= deg*(K-1)+1 worker results, return f(x_k)."""
        f = self.field
        T = self.recovery_threshold(deg)
        assert worker_ids.size >= T, "not enough workers returned"
        pts = self.betas[worker_ids[:T]]
        vals = f.arr(results[:T])
        # Lagrange interpolation of h at the alphas
        out = np.zeros((self.K,) + vals.shape[1:], np.int64)
        for j, a in enumerate(self.alphas):
            acc = np.zeros(vals.shape[1:], np.int64)
            for i in range(T):
                num, den = np.int64(1), np.int64(1)
                for t in range(T):
                    if t == i:
                        continue
                    num = f.mul(num, f.sub(a, pts[t]))
                    den = f.mul(den, f.sub(pts[i], pts[t]))
                acc = f.add(acc, f.mul(vals[i], f.mul(num, f.inv(den))))
            out[j] = acc
        return out
