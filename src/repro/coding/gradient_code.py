"""Gradient coding for straggler mitigation (Tandon et al., adapted).

Fractional-repetition scheme: n workers, tolerance s with (s+1) | n.
The global batch is cut into n parts; workers are organized into n/(s+1)
groups of (s+1); every worker in group g computes the gradients of *all*
(s+1) parts owned by g and reports their sum.  Any n - s workers contain at
least one member of every group (s stragglers cannot empty a group of
s+1), so the decoder sums one representative per group to recover the exact
full-batch gradient — no approximation, deterministic latency bound.

This composes with the paper's collectives: on the mesh, the per-group sums
are all-to-one reduces (Def. 3) and the decode is a masked cross-group
reduce; `repro.train.coded_step.make_straggler_train_step` wires it into a
jitted train step where straggler masks arrive as a per-step input.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

FERMAT_Q = 65537


def default_backend(q: int) -> str:
    """The coding layer's shared backend default: the local uint32 kernel
    for the Fermat prime, the exact simulator for every other field (the
    jnp kernels are Fermat-only)."""
    return "local" if q == FERMAT_Q else "simulator"


@dataclass(frozen=True)
class GradientCoder:
    n_workers: int
    s: int  # stragglers tolerated

    def __post_init__(self):
        assert self.n_workers % (self.s + 1) == 0, "(s+1) | n required"

    @property
    def n_groups(self) -> int:
        return self.n_workers // (self.s + 1)

    def parts_for_worker(self, w: int) -> list[int]:
        g = w // (self.s + 1)
        return [g * (self.s + 1) + i for i in range(self.s + 1)]

    def encode_matrix(self) -> np.ndarray:
        """B[w, part] = 1 if worker w computes that part."""
        B = np.zeros((self.n_workers, self.n_workers))
        for w in range(self.n_workers):
            B[w, self.parts_for_worker(w)] = 1.0
        return B

    def system(self, *, backend: str | None = None, q: int = FERMAT_Q):
        """`CodedSystem` session for the fractional-repetition encode.

        `system.encode(parts)` computes worker reports B @ parts over F_q —
        the field-quantized path for running gradient-code group sums
        through the decentralized encoder (sink r = worker r's report, so
        the session matrix is B^T).  Float training keeps using
        `combine`; this is the integer/fixed-point route and the
        mesh-backend schedule for it.

        The session is memoized per (backend, q) — repeated calls reuse one
        `CodedSystem` (and its planner-cache entries) instead of leaking a
        fresh session per call.  Default backend: `default_backend(q)`.
        """
        from ..api import CodedSystem, CodeSpec

        if backend is None:
            backend = default_backend(q)
        key = f"_system_{backend}_{q}"
        cached = self.__dict__.get(key)
        if cached is None:
            spec = CodeSpec(kind="universal", K=self.n_workers,
                            R=self.n_workers, q=q)
            cached = CodedSystem(spec, backend=backend,
                                 A=self.encode_matrix().T.astype(np.int64))
            object.__setattr__(self, key, cached)
        return cached

    def encode_plan(self, *, backend: str | None = None, q: int = FERMAT_Q):
        """The planner-layer `EncodePlan` behind `system(backend=..., q=...)`."""
        return self.system(backend=backend, q=q).encode_plan

    def decode_weights(self, alive: np.ndarray) -> np.ndarray:
        """alive: (n,) bool. Returns a (n,) weight vector a with
        a @ B == ones (full-batch recovery), a_w = 0 for stragglers."""
        a = np.zeros(self.n_workers)
        for g in range(self.n_groups):
            members = [g * (self.s + 1) + i for i in range(self.s + 1)]
            live = [w for w in members if alive[w]]
            if not live:
                raise RuntimeError(f"group {g} fully straggled (> s failures)")
            a[live[0]] = 1.0
        return a

    def combine(self, worker_grads: list, alive: np.ndarray):
        """Combine per-worker (already group-summed) gradient pytrees into
        the exact full-batch gradient; any ≤ s stragglers are decoded
        around via `decode_weights` (>s per group raises loudly).

        Selection is by the 0/1 weight vector on the host, so the
        surviving terms enter the sum unscaled — recovery is bitwise-exact
        in float, not just allclose."""
        a = self.decode_weights(np.asarray(alive))
        total = None
        for w, g in enumerate(worker_grads):
            if a[w] == 0 or g is None:
                continue
            total = g if total is None else jax.tree.map(jnp.add, total, g)
        return jax.tree.map(lambda x: x / self.n_workers, total)


def coded_gradient(coder: GradientCoder, worker_grads: list, alive: np.ndarray):
    """Deprecated shim — use `GradientCoder.combine(worker_grads, alive)`."""
    warnings.warn(
        "coded_gradient() is deprecated; use "
        "GradientCoder.combine(worker_grads, alive)",
        DeprecationWarning, stacklevel=2)
    return coder.combine(worker_grads, alive)
