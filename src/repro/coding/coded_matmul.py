"""Dropout-tolerant coded inference: Y = X @ W over F_q, Lagrange-coded.

A matmul is degree-1 in the data, so encode and compute commute: if the K
row-shards of X are Lagrange-encoded into K+R worker shards (systematic,
via `CodedSystem.codeword`), then each worker's local `shard @ W` is the
SAME codeword position of Y — the results of any K live workers decode to
the exact Y through the existing `recover/` stack (`CodedSystem.read`),
bitwise, for any ≤ R dropouts.  This is the serving-side counterpart of
gradient coding: a replicated layer's matmuls keep their answers while
workers die, with no recomputation.

The session is a plain `CodedSystem`, so every backend (simulator oracle,
local uint32 kernel, mesh) and every instrumentation hook (decode-plan
cache, drift ledger, obs metrics) applies unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass, field as dc_field

import numpy as np

from ..api import CodedSystem, CodeSpec
from ..core.field import Field
from .gradient_code import FERMAT_Q, default_backend


@dataclass
class CodedMatmul:
    """K data shards, R parity workers, N = K + R total.

    `X` is (K*b, d): b rows per shard.  Workers hold (b, d) shards; each
    computes its `shard @ W (mod q)`; `decode` recovers Y = X @ W exactly
    from any K live results.  Mesh backend requires R | K (the structured
    all-to-all schedule) and K host devices.
    """

    K: int
    R: int
    backend: str | None = None
    q: int = FERMAT_Q
    system: CodedSystem = dc_field(init=False, repr=False)

    def __post_init__(self):
        if self.backend is None:
            self.backend = default_backend(self.q)
        spec = CodeSpec(kind="lagrange", K=self.K, R=self.R, q=self.q)
        self.system = CodedSystem(spec, backend=self.backend)

    @property
    def field(self) -> Field:
        return self.system.spec.field

    @property
    def N(self) -> int:
        return self.K + self.R

    def encode(self, X: np.ndarray) -> np.ndarray:
        """X: (K*b, d) -> (N, b, d) worker shards: data shards 0..K-1
        verbatim (systematic), parity shards via the session encode."""
        X = np.asarray(X)
        if X.ndim != 2 or X.shape[0] % self.K:
            raise ValueError(f"X must be (K*b, d) with K={self.K}, "
                             f"got {X.shape}")
        b = X.shape[0] // self.K
        flat = X.reshape(self.K, b * X.shape[1])
        cw = self.system.codeword(flat)  # (N, b*d)
        return cw.reshape(self.N, b, X.shape[1])

    def worker_compute(self, shards: np.ndarray, W: np.ndarray,
                       workers=None) -> np.ndarray:
        """Each (live) worker's local product: shards[n] @ W mod q."""
        workers = range(self.N) if workers is None else workers
        return np.stack([self.field.matmul(shards[n], W) for n in workers])

    def decode(self, results: np.ndarray, dead=()) -> np.ndarray:
        """results: (N, b, out) per-worker products (rows of dead workers
        ignored) -> Y = X @ W mod q, (K*b, out), decoding around the dead
        set via the session's erasure-aware `read`."""
        dead = sorted(int(d) for d in dead)
        if len(dead) > self.R:
            raise ValueError(f"{len(dead)} dropouts exceed R={self.R}")
        n, b, out = results.shape
        flat = np.ascontiguousarray(results).reshape(n, b * out)
        self.system.fail(dead)
        try:
            Y = self.system.read(flat)  # (K, b*out), repaired
        finally:
            self.system.heal(dead)
        return Y.reshape(self.K * b, out)

    def __call__(self, X: np.ndarray, W: np.ndarray, dead=()) -> np.ndarray:
        """End-to-end coded matmul: encode, drop `dead` workers' results,
        decode.  Bitwise-equal to `field.matmul(X, W)` for ≤ R dropouts."""
        shards = self.encode(X)
        results = self.worker_compute(shards, self.field.arr(W))
        return self.decode(results, dead)

    def close(self) -> None:
        self.system.close()

    def __enter__(self) -> "CodedMatmul":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
