"""Coded computation on top of the session/planner stack — ONE surface.

Every entry point here is a thin, memoized front onto `repro.api`
(`CodedSystem` sessions, shared plan caches, drift/metrics hooks); the
signatures are unified — construction takes shape parameters, `system()`
takes keyword-only `backend=`/`q=` with the shared default
(`default_backend(q)`: local kernel for F_65537, simulator otherwise).

    GradientCoder(n_workers, s)       — Tandon-style gradient coding
        .combine(worker_grads, alive) — exact full-batch gradient around
                                        ≤ s stragglers (bitwise in float)
        .decode_weights(alive)        — the 0/1 recovery vector (a @ B = 1)
        .system(*, backend=, q=)      — field-quantized encode session
        (training integration: repro.train.coded_step)

    LagrangeComputer.build(field, K, N) — Lagrange coded computing (LCC)
        .encode(x)                    — (K, W) -> (N, W) coded shards
        .decode(deg, ids, results)    — any deg*(K-1)+1 results -> f(x_k),
                                        via the cached decode-plan path
        .system(*, backend=)          — the session behind encode/decode

    CodedMatmul(K, R, backend=, q=)   — dropout-tolerant coded inference:
        cm(X, W, dead=...)            — Y = X @ W exactly, ≤ R dropouts

    coded_gradient(coder, grads, alive) — deprecated; GradientCoder.combine
"""
from .coded_matmul import CodedMatmul
from .gradient_code import (FERMAT_Q, GradientCoder, coded_gradient,
                            default_backend)
from .lagrange_compute import LagrangeComputer

__all__ = ["GradientCoder", "LagrangeComputer", "CodedMatmul",
           "coded_gradient", "default_backend", "FERMAT_Q"]
