"""Coding-layer wrappers over the unified `repro.api` encoder.

Both coders plan their encodes through `Encoder.plan` (see
`LagrangeComputer.encode_plan` / `GradientCoder.encode_plan`); the re-exports
below are kept as the stable entry points for train/serve code.
"""
from .gradient_code import GradientCoder, coded_gradient
from .lagrange_compute import LagrangeComputer

__all__ = ["GradientCoder", "coded_gradient", "LagrangeComputer"]
