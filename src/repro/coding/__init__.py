from .gradient_code import GradientCoder, coded_gradient
from .lagrange_compute import LagrangeComputer

__all__ = ["GradientCoder", "coded_gradient", "LagrangeComputer"]
