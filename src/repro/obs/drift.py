"""Predicted-vs-measured ledger: does the closed-form cost model still
match what the simulator measures?

The repo's correctness story rests on exact accounting: the Table-I /
Theorem-7 closed forms (`EncodePlan.cost()`, `recover.engine.decode_cost`,
`cost_universal_exact`) must equal the `RoundNetwork`'s measured (C1, C2)
bit for bit.  Tests assert this for fixed specs; the ledger asserts it
*continuously*: every simulator-backed run (`PlanStats._record_net`)
compares its measured counts against the model re-evaluated at the run's
actual payload width and records exact-match or drift per
(spec, backend, op, method).  Any drift is a broken schedule or a broken
model — `LEDGER.drifted()` surfaces it, `describe()` renders the ledger,
and tier-1 fails loudly on a nonzero drift count.

Leaf-module discipline: the cost model is imported lazily per call (the
`api`/`recover` planners import the obs package, not the other way
round at module scope).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field as dc_field

from .metrics import REGISTRY

_MODEL_RUNS = REGISTRY.counter(
    "cost_model_runs_total",
    "simulator runs checked against the closed-form cost model")

# expected-(C1, C2) memo: the model is pure in (spec, op-detail, width),
# so re-deriving it per chunk would dominate small simulator runs
_EXPECTED: dict[tuple, tuple[int, int]] = {}
_EXPECTED_MAX = 4096


@dataclass
class DriftEntry:
    """Ledger line for one (spec, backend, op, detail) cell — `detail` is
    the resolved encode method, or the erasure-pattern size for decode."""

    spec: object
    backend: str
    op: str
    detail: str
    runs: int = 0
    exact: int = 0
    drifted: int = 0
    last_mismatch: dict | None = dc_field(default=None, repr=False)

    def snapshot(self) -> dict:
        s = self.spec
        return {
            "spec": f"{s.kind} K={s.K} R={s.R} p={s.p}",
            "backend": self.backend, "op": self.op, "detail": self.detail,
            "runs": self.runs, "exact": self.exact, "drifted": self.drifted,
            "last_mismatch": self.last_mismatch,
        }


class DriftLedger:
    """Aggregated predicted-vs-measured results (see module docstring)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._entries: dict[tuple, DriftEntry] = {}

    def record(self, spec, backend: str, op: str, detail: str,
               expected: tuple[int, int], measured: tuple[int, int],
               *, width: int) -> None:
        key = (spec, backend, op, detail)
        exact = expected == measured
        with self._lock:
            e = self._entries.get(key)
            if e is None:
                e = self._entries[key] = DriftEntry(spec, backend, op,
                                                    detail)
            e.runs += 1
            if exact:
                e.exact += 1
            else:
                e.drifted += 1
                e.last_mismatch = {"expected": expected,
                                   "measured": measured, "width": width}
        _MODEL_RUNS.inc(1, kind=spec.kind, op=op,
                        status="exact" if exact else "drift")

    def entries(self) -> list[DriftEntry]:
        with self._lock:
            return list(self._entries.values())

    def drifted(self) -> list[DriftEntry]:
        """Every cell where the model and the simulator EVER disagreed —
        empty is the healthy (and tier-1-asserted) state."""
        return [e for e in self.entries() if e.drifted]

    def snapshot(self) -> dict:
        ents = self.entries()
        return {
            "runs": sum(e.runs for e in ents),
            "exact": sum(e.exact for e in ents),
            "drifted": sum(e.drifted for e in ents),
            "entries": [e.snapshot() for e in ents],
        }

    def describe(self) -> str:
        ents = self.entries()
        if not ents:
            return "drift ledger: no simulator-backed runs recorded"
        total = sum(e.runs for e in ents)
        bad = sum(e.drifted for e in ents)
        lines = [f"drift ledger: {total} run(s), "
                 f"{'ZERO drift' if not bad else f'{bad} DRIFTED'} "
                 f"across {len(ents)} (spec, op) cell(s)"]
        for e in sorted(ents, key=lambda e: (-e.drifted, e.op)):
            s = e.spec
            line = (f"  {e.op:6s} {s.kind:9s} K={s.K} R={s.R} p={s.p} "
                    f"[{e.detail}]: {e.exact}/{e.runs} exact")
            if e.drifted:
                line += f"  DRIFT x{e.drifted}: {e.last_mismatch}"
            lines.append(line)
        return "\n".join(lines)

    def reset(self) -> None:
        with self._lock:
            self._entries.clear()


LEDGER = DriftLedger()


def _expected(plan, op: str, width: int) -> tuple[tuple[int, int], str]:
    """The closed-form (C1, C2) for one run of `plan` at payload width
    `width`, plus the ledger detail string.  Width matters: streamed runs
    execute chunk-by-chunk, so the model is re-evaluated at each chunk's
    actual width (C2 scales linearly; C1 does not)."""
    spec = plan.spec
    if op == "encode":
        if getattr(plan, "commute", False):
            # a tier_commute-rewritten schedule has no Table-I closed form;
            # its exact expectation is the rewritten IR's own accounting
            key = (spec, plan.method, width, plan.placement, "ir")
            hit = _EXPECTED.get(key)
            if hit is None:
                c1, c2 = plan.schedule_ir().cost()
                hit = (c1, c2 * width)
                if len(_EXPECTED) >= _EXPECTED_MAX:
                    _EXPECTED.clear()
                _EXPECTED[key] = hit
            return hit, f"{plan.method}/ir"
        key = (spec, plan.method, width)
        hit = _EXPECTED.get(key)
        if hit is None:
            from dataclasses import replace

            from ..api.planner import method_costs

            c = method_costs(replace(spec, W=width), plan.sgrs)[plan.method]
            hit = (c.C1, c.C2)
            if len(_EXPECTED) >= _EXPECTED_MAX:
                _EXPECTED.clear()
            _EXPECTED[key] = hit
        return hit, plan.method
    n_erased = len(plan.erased)
    key = (spec.K, spec.p, n_erased, width, "dec")
    hit = _EXPECTED.get(key)
    if hit is None:
        from ..recover.engine import decode_cost

        c = decode_cost(spec.K, n_erased, spec.p)
        hit = (c.C1, c.C2 * width)
        if len(_EXPECTED) >= _EXPECTED_MAX:
            _EXPECTED.clear()
        _EXPECTED[key] = hit
    return hit, f"|E|={n_erased}"


def _expected_tiers(plan, width: int, placement):
    """Per-tier closed form (intra C1, intra C2, inter C1, inter C2) for
    one encode at `width` under `placement`, memoized; None when the
    placement profile has no closed form (measured-only, not drift)."""
    commuted = getattr(plan, "commute", False)
    key = (plan.spec, plan.method, width, placement,
           "ir-tiers" if commuted else "tiers")
    hit = _EXPECTED.get(key, "unset")
    if hit == "unset":
        if commuted:
            # per-tier expectation of the rewritten program itself
            a = plan.schedule_ir().attribute(placement)
            hit = (a["intra"][0], a["intra"][1] * width,
                   a["inter"][0], a["inter"][1] * width)
        else:
            from dataclasses import replace

            from ..topo import tiered_encode_cost

            tc = tiered_encode_cost(replace(plan.spec, W=width), plan.method,
                                    placement, sgrs=plan.sgrs)
            hit = None if tc is None else (tc.intra.C1, tc.intra.C2,
                                           tc.inter.C1, tc.inter.C2)
        if len(_EXPECTED) >= _EXPECTED_MAX:
            _EXPECTED.clear()
        _EXPECTED[key] = hit
    return hit


def record_run(plan, net, op: str, width: int) -> None:
    """Compare one simulator-backed run against the model and ledger it.

    Called from `PlanStats._record_net` with the run's fresh
    `RoundNetwork` (its C1/C2 are exactly this run's counts) and the
    payload width the run actually executed.  Runs under a placement
    additionally assert the per-tier split (see `repro.topo`) whenever
    its closed form applies."""
    try:
        expected, detail = _expected(plan, op, width)
    except Exception as exc:  # noqa: BLE001 — a model we cannot evaluate
        # is drift too (never let ledger bookkeeping fail the run itself);
        # the unequal "expected" carries the error into last_mismatch
        expected, detail = ("model-error", str(exc)), "model-error"
    LEDGER.record(plan.spec, plan.backend, op, detail, expected,
                  (net.C1, net.C2), width=width)
    placement = getattr(net, "placement", None)
    if placement is None or op != "encode":
        return
    try:
        tiers = _expected_tiers(plan, width, placement)
        tier_detail = f"{plan.method}/tiers@{placement.policy}"
    except Exception as exc:  # noqa: BLE001 — same contract as above
        tiers, tier_detail = ("model-error", str(exc)), "tiers/model-error"
    if tiers is None:
        return
    measured = (net.c1_by_tier["intra"], net.c2_by_tier["intra"],
                net.c1_by_tier["inter"], net.c2_by_tier["inter"])
    LEDGER.record(plan.spec, plan.backend, op, tier_detail, tiers, measured,
                  width=width)
