"""Unified observability: tracing, metrics, and the cost-model drift ledger.

The paper states its whole contribution as exact communication accounting
— C1 rounds and C2 max-message-size under the linear network model — and
this package is how the repo *shows* those numbers instead of merely
asserting them in tests:

    trace   — a low-overhead span/event tracer with Chrome trace-event
              JSON export (perfetto / chrome://tracing).  The simulator
              emits per-round events on per-processor tracks, the stream
              engine emits H2D/compute pipeline spans, and the queue /
              service layers emit per-op spans tagged tenant/tag/group.
    metrics — ONE labeled counter/gauge/histogram registry the layer
              stats classes (`RunStats`, `PlanStats`, `StreamStats`,
              `QueueStats`, `ServiceStats`) publish into, snapshottable
              as a tree and rendered in text exposition format.
    drift   — a predicted-vs-measured ledger: every simulator-backed run
              compares its measured (C1, C2) against the closed-form
              cost model and records exact-match or drift per
              (spec, backend, op, method).

This package is a LEAF: it imports nothing from the rest of `repro` at
module scope (the drift ledger pulls the cost model lazily, per call), so
`core.simulator` and `api.registry` may import it without cycles.
"""
from . import drift, metrics, trace
from .drift import LEDGER, DriftLedger
from .metrics import REGISTRY, MetricsRegistry
from .trace import Tracer, get_tracer, install, uninstall

__all__ = [
    "trace", "metrics", "drift",
    "Tracer", "get_tracer", "install", "uninstall",
    "REGISTRY", "MetricsRegistry",
    "LEDGER", "DriftLedger",
]
