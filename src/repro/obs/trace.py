"""Low-overhead span/event tracer with Chrome trace-event JSON export.

One `Tracer` collects timestamped events from every layer — simulator
rounds, stream pipeline stages, queue executions, service ops — onto
named (process, thread) tracks and exports the standard Chrome
trace-event format, loadable in perfetto (https://ui.perfetto.dev) or
chrome://tracing:

    from repro.obs import trace

    tracer = trace.install(trace.Tracer())
    ...                        # anything that runs emits onto it
    trace.uninstall(tracer)
    tracer.save("out.json")

Instrumented call sites key off the *installed* tracer (`get_tracer()`),
so tracing needs no parameter plumbing through cached plans or networks
constructed deep inside framework code — and when nothing is installed
every hook is a single `is None` check: tracing off costs nothing
measurable.

Track names are strings (`pid="simulator"`, `tid="proc 3"`); the trace
format wants integers, so the tracer interns them and emits the
`process_name` / `thread_name` metadata events perfetto uses for labels.
Timestamps are wall-clock microseconds from one process-wide epoch, so
simulator rounds, kernel launches, and service op spans line up on a
single timeline.
"""
from __future__ import annotations

import json
import threading
from contextlib import contextmanager
from time import perf_counter_ns


class Tracer:
    """Thread-safe in-memory event collector (Chrome trace-event model).

    Events: `complete(...)` is a closed span ("X": ts + dur), `span(...)`
    a context manager measuring one, `instant(...)` a zero-duration mark
    ("i") — kills, aborts, state flips.  All take `pid`/`tid` track names
    (str or raw int) plus optional `cat` and an `args` dict shown in the
    viewer's detail pane.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._events: list[dict] = []
        self._pids: dict[str, int] = {}
        self._tids: dict[tuple[int, str], int] = {}
        # one process-wide epoch so every layer's timestamps align
        self._t0 = perf_counter_ns()

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def now_us(self) -> float:
        """Microseconds since this tracer's epoch (wall clock)."""
        return (perf_counter_ns() - self._t0) / 1e3

    # -- track interning -----------------------------------------------------
    def _pid(self, pid) -> int:
        if isinstance(pid, int):
            return pid
        n = self._pids.get(pid)
        if n is None:
            n = self._pids[pid] = len(self._pids) + 1
            self._events.append({
                "name": "process_name", "ph": "M", "pid": n, "tid": 0,
                "args": {"name": pid}})
        return n

    def _tid(self, pid: int, tid) -> int:
        if isinstance(tid, int):
            return tid
        key = (pid, tid)
        n = self._tids.get(key)
        if n is None:
            n = self._tids[key] = len(self._tids) + 1
            self._events.append({
                "name": "thread_name", "ph": "M", "pid": pid, "tid": n,
                "args": {"name": tid}})
        return n

    # -- emission ------------------------------------------------------------
    def complete(self, name: str, ts_us: float, dur_us: float, *,
                 pid="main", tid="main", cat: str = "",
                 args: dict | None = None) -> None:
        """A closed span: began at `ts_us`, lasted `dur_us` (both in
        microseconds on this tracer's clock — see `now_us`)."""
        ev = {"name": name, "ph": "X", "ts": ts_us,
              "dur": max(dur_us, 0.001)}
        if cat:
            ev["cat"] = cat
        if args:
            ev["args"] = args
        with self._lock:
            p = self._pid(pid)
            ev["pid"], ev["tid"] = p, self._tid(p, tid)
            self._events.append(ev)

    def instant(self, name: str, *, ts_us: float | None = None,
                pid="main", tid="main", cat: str = "",
                args: dict | None = None) -> None:
        """A zero-duration mark (kill, abort, state flip)."""
        ev = {"name": name, "ph": "i", "s": "t",
              "ts": self.now_us() if ts_us is None else ts_us}
        if cat:
            ev["cat"] = cat
        if args:
            ev["args"] = args
        with self._lock:
            p = self._pid(pid)
            ev["pid"], ev["tid"] = p, self._tid(p, tid)
            self._events.append(ev)

    @contextmanager
    def span(self, name: str, *, pid="main", tid="main", cat: str = "",
             args: dict | None = None):
        """Measure the with-block as one complete event."""
        t0 = self.now_us()
        try:
            yield self
        finally:
            self.complete(name, t0, self.now_us() - t0, pid=pid, tid=tid,
                          cat=cat, args=args)

    # -- export --------------------------------------------------------------
    def events(self, *, cat: str | None = None,
               name: str | None = None) -> list[dict]:
        """A snapshot of collected events, optionally filtered (metadata
        events excluded) — the programmatic side of the export, used by
        trace-correctness tests."""
        with self._lock:
            evs = list(self._events)
        out = []
        for e in evs:
            if e["ph"] == "M":
                continue
            if cat is not None and e.get("cat") != cat:
                continue
            if name is not None and e.get("name") != name:
                continue
            out.append(e)
        return out

    def to_dict(self) -> dict:
        """The full trace as the Chrome trace-event JSON object."""
        with self._lock:
            return {"traceEvents": [dict(e) for e in self._events],
                    "displayTimeUnit": "ms"}

    def save(self, path) -> str:
        """Write the trace JSON to `path`; returns the path written."""
        with open(path, "w") as fh:
            json.dump(self.to_dict(), fh)
        return str(path)


# ---------------------------------------------------------------------------
# the installed-tracer stack (what instrumented call sites consult)
# ---------------------------------------------------------------------------

_INSTALLED: list[Tracer] = []


def install(tracer: Tracer) -> Tracer:
    """Make `tracer` the active tracer every instrumented call site emits
    to (a stack — nesting installs is fine); returns it for chaining."""
    _INSTALLED.append(tracer)
    return tracer


def uninstall(tracer: Tracer) -> None:
    """Remove `tracer` from the active stack (no-op if absent)."""
    for i in range(len(_INSTALLED) - 1, -1, -1):
        if _INSTALLED[i] is tracer:
            del _INSTALLED[i]
            return


def get_tracer() -> Tracer | None:
    """The currently installed tracer, or None (the common, free case)."""
    return _INSTALLED[-1] if _INSTALLED else None


def resolve(trace) -> tuple[Tracer | None, str | None]:
    """Normalize a user-facing `trace=` argument — the shape
    `CodedSystem(trace=...)` / `CodedService(trace=...)` accept:

        None/False     -> (None, None)         tracing off
        True           -> (new Tracer, None)   collect, caller exports
        a Tracer       -> (it, None)           caller-owned
        a path (str)   -> (new Tracer, path)   saved on close()
    """
    if trace is None or trace is False:
        return None, None
    if trace is True:
        return Tracer(), None
    if isinstance(trace, Tracer):
        return trace, None
    return Tracer(), str(trace)


@contextmanager
def installed(tracer: Tracer | None = None):
    """`with trace.installed() as t:` — install for the block's duration."""
    t = tracer or Tracer()
    install(t)
    try:
        yield t
    finally:
        uninstall(t)


@contextmanager
def kernel_span(name: str, **args):
    """Wrap a kernel launch: a tracer span AND a
    `jax.profiler.TraceAnnotation`, so our spans line up with XLA's own
    profile when both are captured.  Free (and jax-import-free) when no
    tracer is installed."""
    tracer = get_tracer()
    if tracer is None:
        yield
        return
    from jax.profiler import TraceAnnotation

    with tracer.span(name, pid="backend", tid="kernels", cat="kernel",
                     args=args or None), TraceAnnotation(name):
        yield
