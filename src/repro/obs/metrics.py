"""One labeled counter/gauge/histogram registry for every layer's stats.

The repo grew five disconnected stats surfaces (`RunStats`, `PlanStats`,
`StreamStats`, `QueueStats`, `ServiceStats`); this module is the single
registry they all publish into, so one `snapshot()` answers "what has
this process done" across simulator runs, stream chunks, queue batches,
and tenant ops — surfaced via `CodedSystem.stats()["metrics"]`,
`CodedService.stats()["metrics"]`, and `serve --metrics` (text
exposition format, `render_text`).

    from repro.obs import metrics

    RUNS = metrics.REGISTRY.counter("coded_runs_total", "plan executions")
    RUNS.inc(1, backend="simulator", op="encode")
    metrics.REGISTRY.snapshot()   # {"coded_runs_total": {...}, ...}

Metric objects are cheap label-resolving handles; values live in the
registry under (name, sorted-label-items) keys behind one lock, so a
concurrent `snapshot()` always sees a consistent point-in-time tree
(asserted by the tier-1 consistency hammer).
"""
from __future__ import annotations

import threading


class _Metric:
    """One named metric family; label values are passed per call."""

    kind = "untyped"

    def __init__(self, registry: "MetricsRegistry", name: str, help: str):
        self._reg = registry
        self.name = name
        self.help = help
        # (sorted label items) -> value; guarded by the registry lock
        self._values: dict[tuple, object] = {}

    @staticmethod
    def _key(labels: dict) -> tuple:
        return tuple(sorted(labels.items()))


class Counter(_Metric):
    """Monotonically increasing count (ops, rounds, elements, bytes)."""

    kind = "counter"

    def inc(self, n: float = 1, **labels) -> None:
        key = self._key(labels)
        with self._reg._lock:
            self._values[key] = self._values.get(key, 0) + n


class Gauge(_Metric):
    """A value that goes both ways (in-flight ops, pool sizes)."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        with self._reg._lock:
            self._values[self._key(labels)] = value

    def inc(self, n: float = 1, **labels) -> None:
        key = self._key(labels)
        with self._reg._lock:
            self._values[key] = self._values.get(key, 0) + n


class Histogram(_Metric):
    """Streaming count/sum/min/max per labelset (latencies, widths,
    group sizes) — enough for means and extremes without bucket config."""

    kind = "histogram"

    def observe(self, value: float, **labels) -> None:
        key = self._key(labels)
        with self._reg._lock:
            agg = self._values.get(key)
            if agg is None:
                self._values[key] = [1, value, value, value]
            else:
                agg[0] += 1
                agg[1] += value
                if value < agg[2]:
                    agg[2] = value
                if value > agg[3]:
                    agg[3] = value


class MetricsRegistry:
    """Process-wide named metric families behind one lock (see module
    docstring).  `counter`/`gauge`/`histogram` get-or-create a family —
    re-asking for a name returns the same handle, so call sites can keep
    module-level references with zero lookup on the hot path."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: dict[str, _Metric] = {}

    def _get(self, cls, name: str, help: str) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(self, name, help)
            elif not isinstance(m, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._get(Histogram, name, help)

    # -- export --------------------------------------------------------------
    @staticmethod
    def _label_str(key: tuple) -> str:
        return ",".join(f"{k}={v}" for k, v in key)

    def snapshot(self) -> dict:
        """A consistent point-in-time tree:
        {name: {"kind", "help", "values": {label-string: value}}} with
        histogram values as {"count", "sum", "min", "max", "mean"}."""
        with self._lock:
            out: dict = {}
            for name, m in sorted(self._metrics.items()):
                vals: dict = {}
                for key, v in m._values.items():
                    ls = self._label_str(key)
                    if m.kind == "histogram":
                        cnt, s, lo, hi = v
                        vals[ls] = {"count": cnt, "sum": s, "min": lo,
                                    "max": hi, "mean": s / cnt}
                    else:
                        vals[ls] = v
                out[name] = {"kind": m.kind, "help": m.help, "values": vals}
            return out

    def render_text(self, prefix: str = "repro_") -> str:
        """Text exposition format (the `serve --metrics` dump):
        `# HELP` / `# TYPE` headers plus one `name{labels} value` line per
        labelset; histograms expose `_count`/`_sum`/`_min`/`_max`."""
        lines: list[str] = []
        for name, fam in self.snapshot().items():
            full = prefix + name
            if fam["help"]:
                lines.append(f"# HELP {full} {fam['help']}")
            lines.append(f"# TYPE {full} {fam['kind']}")
            for ls, v in sorted(fam["values"].items()):
                lbl = ("{" + ",".join(
                    f'{p.split("=", 1)[0]}="{p.split("=", 1)[1]}"'
                    for p in ls.split(",")) + "}") if ls else ""
                if fam["kind"] == "histogram":
                    for suffix in ("count", "sum", "min", "max"):
                        lines.append(f"{full}_{suffix}{lbl} {v[suffix]}")
                else:
                    lines.append(f"{full}{lbl} {v}")
        return "\n".join(lines) + "\n"

    def reset(self) -> None:
        """Drop every value (keeps the registered families) — tests and
        bench sections that need a clean ledger start here."""
        with self._lock:
            for m in self._metrics.values():
                m._values.clear()


# the process-wide registry every instrumented layer publishes into
REGISTRY = MetricsRegistry()
