"""Architecture configuration — one dataclass covers all 10 assigned archs."""
from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int = 0            # 0 => d_model // n_heads
    qk_norm: bool = False        # qwen3-style per-head RMSNorm on q, k
    qkv_bias: bool = False       # qwen1.5-style
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act: str = "silu"

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    n_shared_experts: int = 0

    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 64
    ssm_conv: int = 4

    # hybrid / attention variants
    sliding_window: int = 0      # 0 = full attention
    global_attn_every: int = 0   # hymba: every k-th layer is global

    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    n_frames: int = 1500         # encoder sequence (stub frontend output)

    # VLM (llava)
    n_patches: int = 0           # vision tokens (stub frontend output)

    # minicpm tricks
    scale_depth: float = 0.0     # residual scale: scale_depth / sqrt(n_layers)
    scale_emb: float = 1.0
    logit_scale: float = 1.0     # minicpm divides logits by d_model/256

    # large-scale training choices
    optimizer: str = "adamw"     # kimi-k2 -> adafactor (HBM envelope, DESIGN.md)
    remat: bool = True
    dtype: str = "bfloat16"

    # serving: int8 KV cache (per-token-per-head absmax scales) — halves the
    # decode memory bound (§Perf iteration 7)
    quantize_kv: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k shape (O(S) decode state)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decode step

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        return replace(
            self,
            n_layers=2,
            n_enc_layers=min(self.n_enc_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads * 4 // max(self.n_heads, 1))),
            head_dim=16,
            d_ff=128,
            vocab=256,
            n_experts=min(self.n_experts, 8),
            top_k=min(self.top_k, 2),
            # generous capacity: no token dropping in smoke tests, so the
            # stepwise-decode vs full-forward consistency check is exact
            capacity_factor=8.0,
            ssm_state=min(self.ssm_state, 16),
            ssm_headdim=16 if self.ssm_state else self.ssm_headdim,
            ssm_chunk=16,
            sliding_window=min(self.sliding_window, 32) if self.sliding_window else 0,
            n_frames=32,
            n_patches=min(self.n_patches, 8),
            remat=False,
        )


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
