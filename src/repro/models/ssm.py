"""Mamba2 — SSD (state-space duality) blocks, chunked scan (arXiv:2405.21060).

TPU adaptation notes (DESIGN.md §4): the CUDA SSD kernel's warp-level
tiling does not transfer; we keep the *algorithm* (chunked quadratic
intra-chunk term + O(S) inter-chunk state recurrence) expressed as batched
einsums + one `jax.lax.scan` over chunks — XLA maps the einsums onto the MXU
and the scan carries the (H, N, P) state through HBM-resident buffers.

Decode maintains O(1) state: (B, H, N, P) SSM state + (B, conv-1, C) conv tail.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import Params, dense_init, rmsnorm


def init_mamba2(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 8)
    D, DI, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    C = DI + 2 * N  # conv acts on x, B, C streams
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        # projections: [z | x | B | C | dt]
        "in_proj": dense_init(ks[0], (D, 2 * DI + 2 * N + H), dtype=dt),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, C), scale=0.5, dtype=dt),
        "conv_b": jnp.zeros((C,), dt),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.ones((DI,), jnp.float32),
        "out_proj": dense_init(ks[2], (DI, D), dtype=dt),
    }


def _split_proj(p, cfg: ArchConfig, u):
    DI, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = u @ p["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [DI, 2 * DI + 2 * N], axis=-1)
    return z, xbc, dt


def _causal_conv(p, cfg: ArchConfig, xbc, conv_state=None):
    """Depthwise causal conv over the sequence axis.

    xbc: (B, S, C). conv_state: (B, conv-1, C) tail of previous tokens.
    Returns (out, new_conv_state)."""
    K = cfg.ssm_conv
    B, S, C = xbc.shape
    if conv_state is None:
        pad = jnp.zeros((B, K - 1, C), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)  # (B, S+K-1, C)
    out = jnp.zeros_like(xbc)
    for i in range(K):
        out = out + full[:, i : i + S, :] * p["conv_w"][i]
    out = jax.nn.silu(out + p["conv_b"])
    return out, full[:, -(K - 1) :, :]


def _segsum(x):
    """x: (..., Q) -> (..., Q, Q) lower-tri cumulative sums: L[i,j] = sum_{j<t<=i} x_t."""
    Q = x.shape[-1]
    c = jnp.cumsum(x, axis=-1)
    diff = c[..., :, None] - c[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(cfg: ArchConfig, xh, Bm, Cm, dt, A, initial_state=None):
    """Chunked SSD scan.

    xh: (B, S, H, P); Bm, Cm: (B, S, N); dt: (B, S, H) (post-softplus);
    A: (H,) negative decay rates. Returns (y (B,S,H,P), final_state (B,H,N,P)).
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(cfg.ssm_chunk, S)
    if S % Q:  # pad tail (causal: outputs before the pad are unaffected;
        # the returned final state assumes chunk-aligned prefill lengths)
        pad = Q - S % Q
        zf = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        y, st = ssd_chunked(cfg, zf(xh), zf(Bm), zf(Cm), zf(dt), A, initial_state)
        return y[:, :S], st
    nc = S // Q

    xc = xh.reshape(Bsz, nc, Q, H, P)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)
    dtc = dt.reshape(Bsz, nc, Q, H)
    dA = dtc * A  # (B, nc, Q, H) negative

    # ---- intra-chunk (quadratic within Q) ---------------------------------
    L = jnp.exp(_segsum(jnp.moveaxis(dA, -1, -2)))  # (B, nc, H, Q, Q)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)[:, :, None] * L
    y_intra = jnp.einsum("bchqk,bckh,bckhp->bcqhp", scores, dtc, xc)

    # ---- chunk states + inter-chunk recurrence ---------------------------
    dA_cum = jnp.cumsum(dA, axis=2)                     # (B, nc, Q, H)
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)  # (B, nc, Q, H)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchnp",
                        Bc, dtc * decay_to_end, xc)     # (B, nc, H, N, P)
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])          # (B, nc, H)

    if initial_state is None:
        initial_state = jnp.zeros((Bsz, H, N, P), jnp.float32)

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None] + st
        return new, carry  # emit state *entering* the chunk

    xs = (jnp.moveaxis(states, 1, 0).astype(jnp.float32),
          jnp.moveaxis(chunk_decay, 1, 0).astype(jnp.float32))
    final, entering = jax.lax.scan(step, initial_state.astype(jnp.float32), xs)
    entering = jnp.moveaxis(entering, 0, 1)             # (B, nc, H, N, P)

    decay_from_start = jnp.exp(dA_cum)                  # (B, nc, Q, H)
    y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp",
                         Cc, decay_from_start, entering.astype(Cc.dtype))
    y = (y_intra + y_inter.astype(y_intra.dtype)).reshape(Bsz, S, H, P)
    return y, final


def mamba2_forward(p: Params, cfg: ArchConfig, u, state=None):
    """u: (B, S, D). state: None (train/prefill) or
    {'conv': (B, K-1, C), 'ssm': (B, H, N, P)} for chunk-continuation.
    Returns (out, new_state)."""
    B, S, D = u.shape
    DI, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    z, xbc, dtr = _split_proj(p, cfg, u)
    conv_in = state["conv"] if state else None
    xbc, conv_tail = _causal_conv(p, cfg, xbc, conv_in)
    xh, Bm, Cm = jnp.split(xbc, [DI, DI + N], axis=-1)
    xh = xh.reshape(B, S, H, P)
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    ssm_in = state["ssm"] if state else None
    y, final = ssd_chunked(cfg, xh, Bm, Cm, dt, A, ssm_in)
    y = y + xh * p["D"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(B, S, DI)
    y = rmsnorm(y * jax.nn.silu(z.astype(y.dtype)), p["norm"], cfg.norm_eps)
    out = (y.astype(u.dtype) @ p["out_proj"]).astype(u.dtype)
    return out, {"conv": conv_tail, "ssm": final}


def mamba2_decode_step(p: Params, cfg: ArchConfig, u, state):
    """Single-token decode: u (B, 1, D), O(1) state update."""
    B, _, D = u.shape
    DI, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    K = cfg.ssm_conv
    z, xbc, dtr = _split_proj(p, cfg, u)
    # conv: state holds the last K-1 inputs
    full = jnp.concatenate([state["conv"].astype(xbc.dtype), xbc], axis=1)  # (B, K, C)
    conv_out = jnp.einsum("bkc,kc->bc", full, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)[:, None, :]
    new_conv = full[:, 1:, :]
    xh, Bm, Cm = jnp.split(conv_out, [DI, DI + N], axis=-1)
    xh = xh.reshape(B, H, P)
    dt = jax.nn.softplus(dtr[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B, H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A)  # (B, H)
    st = state["ssm"]
    st = st * decay[..., None, None] + jnp.einsum(
        "bn,bh,bhp->bhnp", Bm[:, 0].astype(jnp.float32), dt, xh.astype(jnp.float32))
    y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(jnp.float32), st)
    y = y.astype(u.dtype) + xh * p["D"][None, :, None].astype(xh.dtype)
    y = y.reshape(B, 1, DI)
    y = rmsnorm(y * jax.nn.silu(z.astype(y.dtype)), p["norm"], cfg.norm_eps)
    return (y.astype(u.dtype) @ p["out_proj"]).astype(u.dtype), {
        "conv": new_conv, "ssm": st}
