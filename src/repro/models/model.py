"""Model assembly for all 10 assigned architectures.

One parameter layout per family; homogeneous layer stacks are *stacked*
(leading axis = layer) and consumed with `jax.lax.scan` so that compile time
stays O(1) in depth (61-layer kimi traces one layer).  `jax.checkpoint`
(remat) wraps the per-layer body for training.

Families:
  dense  — llama-style decoder (qwen3*, minicpm, qwen1.5)
  moe    — dense skeleton with MoE FFN (kimi-k2, phi3.5-moe)
  ssm    — mamba2 SSD stack (attention-free)
  hybrid — hymba: parallel attention + SSM heads per layer, SWA + periodic
           global layers
  encdec — whisper: bidirectional encoder (stub frontend) + causal decoder
           with cross-attention
  vlm    — llava: mistral decoder over [vision-stub | text] sequence
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..dist.ctx import constrain
from .config import ArchConfig
from .layers import (
    Params,
    _dtype,
    attention,
    dense_init,
    init_attention,
    init_mlp,
    init_moe,
    mlp,
    moe,
    rmsnorm,
)
from .ssm import init_mamba2, mamba2_decode_step, mamba2_forward


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ArchConfig, cross: bool = False) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {"ln1": jnp.ones((cfg.d_model,), jnp.float32)}
    if cfg.family == "ssm":
        p["ssm"] = init_mamba2(ks[0], cfg)
        return p
    p["attn"] = init_attention(ks[0], cfg)
    p["ln2"] = jnp.ones((cfg.d_model,), jnp.float32)
    if cfg.family == "hybrid":
        p["ssm"] = init_mamba2(ks[1], cfg)
        p["attn_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["ssm_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
    if cross:
        p["xattn"] = init_attention(ks[2], cfg, cross=True)
        p["ln_x"] = jnp.ones((cfg.d_model,), jnp.float32)
    if cfg.family == "moe":
        p["ffn"] = init_moe(ks[3], cfg)
    else:
        p["ffn"] = init_mlp(ks[3], cfg)
    return p


def _stack(key, cfg: ArchConfig, n: int, cross: bool = False) -> Params:
    keys = jax.random.split(key, n)
    layers = [_init_layer(k, cfg, cross) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


def init_params(cfg: ArchConfig, key) -> Params:
    ks = jax.random.split(key, 8)
    dt = _dtype(cfg)
    p: Params = {
        "embed": dense_init(ks[0], (cfg.vocab, cfg.d_model), scale=0.02, dtype=dt),
        "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
        "layers": _stack(ks[1], cfg, cfg.n_layers, cross=cfg.family == "encdec"),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(ks[2], (cfg.d_model, cfg.vocab), dtype=dt)
    if cfg.family == "encdec":
        enc_cfg = cfg
        p["enc_layers"] = _stack(ks[3], enc_cfg, cfg.n_enc_layers)
        p["enc_ln_f"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["dec_pos"] = dense_init(ks[4], (32768 + 16, cfg.d_model), scale=0.02, dtype=dt)
    if cfg.family == "vlm":
        p["vis_proj"] = dense_init(ks[5], (cfg.d_model, cfg.d_model), dtype=dt)
    return p


def param_count(params: Params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# layer bodies
# ---------------------------------------------------------------------------

def _res_scale(cfg: ArchConfig) -> float:
    if cfg.scale_depth:
        return cfg.scale_depth / math.sqrt(cfg.n_layers)
    return 1.0


def _layer_fwd(cfg: ArchConfig, layer_idx, p: Params, x, positions, enc_out=None):
    """Full-sequence forward for one layer (train / prefill)."""
    s = _res_scale(cfg)
    if cfg.family == "ssm":
        h, _ = mamba2_forward(p["ssm"], cfg, rmsnorm(x, p["ln1"], cfg.norm_eps))
        return x + s * h

    # NOTE: hymba's "3 global layers" are approximated by a uniform sliding
    # window inside the layer-scan (a per-layer static window would break the
    # stacked-scan homogeneity); documented in DESIGN.md §5.
    window = cfg.sliding_window
    xin = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.family == "hybrid":
        a, _ = attention(p["attn"], cfg, xin, positions, window=cfg.sliding_window)
        m, _ = mamba2_forward(p["ssm"], cfg, xin)
        h = 0.5 * (rmsnorm(a, p["attn_norm"], cfg.norm_eps)
                   + rmsnorm(m, p["ssm_norm"], cfg.norm_eps))
        x = x + s * h
    else:
        a, _ = attention(p["attn"], cfg, xin, positions, window=window)
        x = x + s * a
    if enc_out is not None:
        xx = rmsnorm(x, p["ln_x"], cfg.norm_eps)
        c, _ = attention(p["xattn"], cfg, xx, positions, mode="cross", kv_src=enc_out)
        x = x + s * c
    f_in = rmsnorm(x, p["ln2"], cfg.norm_eps)
    f = moe(p["ffn"], cfg, f_in) if cfg.family == "moe" else mlp(p["ffn"], cfg, f_in)
    return x + s * f


def _enc_layer_fwd(cfg: ArchConfig, p: Params, x):
    xin = rmsnorm(x, p["ln1"], cfg.norm_eps)
    a, _ = attention(p["attn"], cfg, xin, jnp.arange(x.shape[1])[None], mode="bidir")
    x = x + a
    f = mlp(p["ffn"], cfg, rmsnorm(x, p["ln2"], cfg.norm_eps))
    return x + f


# ---------------------------------------------------------------------------
# full forward (train / prefill)
# ---------------------------------------------------------------------------

def _run_stack(cfg: ArchConfig, layers: Params, x, positions, enc_out=None):
    def body(carry, inp):
        idx, lp = inp
        y = _layer_fwd(cfg, idx, lp, carry, positions, enc_out)
        return y, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    n = cfg.n_layers
    idxs = jnp.arange(n)
    x, _ = jax.lax.scan(body, x, (idxs, layers))
    return x


def encode_frames(cfg: ArchConfig, params: Params, frames):
    """Whisper encoder over stub frame embeddings (B, n_frames, D)."""
    def body(carry, lp):
        return _enc_layer_fwd(cfg, lp, carry), None

    x, _ = jax.lax.scan(body, frames, params["enc_layers"])
    return rmsnorm(x, params["enc_ln_f"], cfg.norm_eps)


def forward(cfg: ArchConfig, params: Params, batch: dict) -> jnp.ndarray:
    """Returns logits (B, S_text, vocab).

    batch: tokens (B, S_text) int32; optional vision_embeds (B, P, D) [vlm],
    frames (B, F, D) [encdec].
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = params["embed"][tokens] * cfg.scale_emb
    positions = jnp.arange(S)[None]
    enc_out = None

    if cfg.family == "vlm" and "vision_embeds" in batch:
        v = batch["vision_embeds"].astype(x.dtype) @ params["vis_proj"]
        x = jnp.concatenate([v, x], axis=1)
        positions = jnp.arange(x.shape[1])[None]
    if cfg.family == "encdec":
        enc_out = encode_frames(cfg, params, batch["frames"].astype(x.dtype))
        x = x + params["dec_pos"][:S][None]

    x = constrain(x, "batch", None, None)
    x = _run_stack(cfg, params["layers"], x, positions, enc_out)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    if cfg.family == "vlm" and "vision_embeds" in batch:
        x = x[:, -S:]  # logits over text positions only
    w_out = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = (x @ w_out) / cfg.logit_scale
    # vocab-sharded logits: keeps the (B, S, V) tensor (the largest activation
    # by far) distributed over the model axis through the loss
    logits = constrain(logits, "batch", None, "model")
    return logits


def loss_fn(cfg: ArchConfig, params: Params, batch: dict) -> jnp.ndarray:
    logits = forward(cfg, params, batch)
    labels = batch["labels"]
    # CE via gather + logsumexp: never materializes a second (B, S, V) f32
    # tensor (log_softmax would); reductions stay vocab-sharded.
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ll = picked - lse
    mask = batch.get("loss_mask", jnp.ones_like(labels, jnp.float32))
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# decode (serve_step): one new token against a KV/SSM cache
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int, enc_out=None) -> Params:
    """Stacked per-layer cache pytree."""
    dt = _dtype(cfg)
    KV, hd = cfg.n_kv_heads, cfg.hd
    n = cfg.n_layers
    cache: Params = {}
    if cfg.family != "ssm":
        # sliding-window archs only ever attend to the last `window` tokens:
        # allocate a ring buffer of exactly that length (layers.py decode)
        L = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
        kv_dt = jnp.int8 if cfg.quantize_kv else dt
        cache["k"] = jnp.zeros((n, batch, L, KV, hd), kv_dt)
        cache["v"] = jnp.zeros((n, batch, L, KV, hd), kv_dt)
        if cfg.quantize_kv:
            cache["k_scale"] = jnp.zeros((n, batch, L, KV, 1), jnp.bfloat16)
            cache["v_scale"] = jnp.zeros((n, batch, L, KV, 1), jnp.bfloat16)
    if cfg.family in ("ssm", "hybrid"):
        H, N, P = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_headdim
        C = cfg.d_inner + 2 * N
        cache["ssm"] = jnp.zeros((n, batch, H, N, P), jnp.float32)
        cache["conv"] = jnp.zeros((n, batch, cfg.ssm_conv - 1, C), dt)
    # encdec: cross-attention KV is recomputed from enc_out inside each
    # decode step (it is small: 1500 frames) — no cache entry needed.
    return cache


def _layer_decode(cfg: ArchConfig, layer_idx, p: Params, x, pos, cache_slice,
                  enc_out=None):
    """x: (B, 1, D); cache_slice: this layer's cache entries."""
    s = _res_scale(cfg)
    new_cache = {}
    positions = jnp.broadcast_to(jnp.asarray(pos).reshape(1, 1), (x.shape[0], 1))
    if cfg.family == "ssm":
        h, st = mamba2_decode_step(
            p["ssm"], cfg, rmsnorm(x, p["ln1"], cfg.norm_eps),
            {"conv": cache_slice["conv"], "ssm": cache_slice["ssm"]})
        return x + s * h, {"conv": st["conv"], "ssm": st["ssm"]}

    kv_keys = [k for k in ("k", "v", "k_scale", "v_scale") if k in cache_slice]
    kv_cache = {k: cache_slice[k] for k in kv_keys}
    xin = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.family == "hybrid":
        a, kv = attention(p["attn"], cfg, xin, positions,
                          window=cfg.sliding_window,
                          cache=kv_cache,
                          cache_pos=pos)
        m, st = mamba2_decode_step(
            p["ssm"], cfg, xin,
            {"conv": cache_slice["conv"], "ssm": cache_slice["ssm"]})
        h = 0.5 * (rmsnorm(a, p["attn_norm"], cfg.norm_eps)
                   + rmsnorm(m, p["ssm_norm"], cfg.norm_eps))
        x = x + s * h
        new_cache.update(kv)
        new_cache.update({"conv": st["conv"], "ssm": st["ssm"]})
    else:
        a, kv = attention(p["attn"], cfg, xin, positions,
                          window=cfg.sliding_window,
                          cache=kv_cache,
                          cache_pos=pos)
        x = x + s * a
        new_cache.update(kv)
    if cfg.family == "encdec" and enc_out is not None:
        xx = rmsnorm(x, p["ln_x"], cfg.norm_eps)
        c, _ = attention(p["xattn"], cfg, xx, positions, mode="cross",
                         kv_src=enc_out)
        x = x + s * c
    f_in = rmsnorm(x, p["ln2"], cfg.norm_eps)
    f = moe(p["ffn"], cfg, f_in) if cfg.family == "moe" else mlp(p["ffn"], cfg, f_in)
    return x + s * f, new_cache


def decode_step(cfg: ArchConfig, params: Params, token, pos, cache: Params,
                enc_out=None):
    """token: (B,) int32; pos: scalar int32. Returns (logits (B, V), cache)."""
    B = token.shape[0]
    x = params["embed"][token][:, None, :] * cfg.scale_emb
    if cfg.family == "encdec":
        x = x + params["dec_pos"][pos][None, None]

    def body(carry, inp):
        idx, lp, csl = inp
        y, nc = _layer_decode(cfg, idx, lp, carry, pos, csl, enc_out)
        return y, nc

    idxs = jnp.arange(cfg.n_layers)
    x, new_cache = jax.lax.scan(body, x, (idxs, params["layers"], cache))
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    w_out = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = (x[:, 0] @ w_out) / cfg.logit_scale
    return logits, new_cache
