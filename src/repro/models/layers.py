"""Transformer building blocks: RMSNorm, RoPE, GQA attention (qk-norm /
bias / sliding-window variants), gated MLP, MoE with sort-free bucket
dispatch.  Pure functions over param pytrees (no flax; raw JAX)."""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.ctx import constrain
from .config import ArchConfig

Params = dict[str, Any]


def _dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, scale: float | None = None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms / rope / activation
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, hd, 2, dtype=np.float32) / hd))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# attention (GQA; causal / sliding-window / cross / bidirectional)
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ArchConfig, cross: bool = False) -> Params:
    ks = jax.random.split(key, 8)
    hd, H, KV, D = cfg.hd, cfg.n_heads, cfg.n_kv_heads, cfg.d_model
    dt = _dtype(cfg)
    p = {
        "wq": dense_init(ks[0], (D, H * hd), dtype=dt),
        "wk": dense_init(ks[1], (D, KV * hd), dtype=dt),
        "wv": dense_init(ks[2], (D, KV * hd), dtype=dt),
        "wo": dense_init(ks[3], (H * hd, D), dtype=dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((KV * hd,), dt)
        p["bv"] = jnp.zeros((KV * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _project_qkv(p, cfg: ArchConfig, xq, xkv, q_positions, kv_positions, use_rope):
    B, Sq, _ = xq.shape
    Skv = xkv.shape[1]
    hd, H, KV = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    q = xq @ p["wq"]
    k = xkv @ p["wk"]
    v = xkv @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, Sq, H, hd)
    k = k.reshape(B, Skv, KV, hd)
    v = v.reshape(B, Skv, KV, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        q = apply_rope(q, q_positions, cfg.rope_theta)
        k = apply_rope(k, kv_positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, cfg: ArchConfig):
    """q: (B,Sq,H,hd), k/v: (B,Skv,KV,hd), mask broadcastable (B,1,Sq,Skv)."""
    H, KV = cfg.n_heads, cfg.n_kv_heads
    rep = H // KV
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = 1.0 / math.sqrt(cfg.hd)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


# chunked attention kicks in above this sequence length (S^2 score tensors
# at 4k+ dominate per-device HBM; see EXPERIMENTS.md §Perf iteration 1)
CHUNKED_ATTN_THRESHOLD = 4096
_Q_CHUNK = 512
_KV_CHUNK = 1024


def _chunked_attention(q, k, v, cfg: ArchConfig, causal: bool, window: int):
    """Flash-style blockwise attention: outer scan over q-chunks, inner scan
    over kv-chunks with online softmax. Never materializes (Sq, Skv) scores —
    the live score block is (B, H, q_chunk, kv_chunk).

    window > 0 (sliding window): the kv range per q-chunk is a single static
    dynamic-slice of width window + q_chunk (exact, no wasted FLOPs).
    causal full attention: every kv chunk is visited and masked (<= 2x FLOPs
    overhead vs triangular skipping; see §Perf notes).
    """
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    KV = k.shape[2]
    rep = H // KV
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = 1.0 / math.sqrt(cfg.hd)
    cq = min(_Q_CHUNK, Sq)
    nq = Sq // cq
    assert Sq % cq == 0

    qs = q.reshape(B, nq, cq, H, hd)

    def q_block(_, qi):
        qb = qs[:, qi] * scale  # (B, cq, H, hd)
        q_start = qi * cq

        if window > 0:
            kw = window + cq
            start = jnp.clip(q_start + cq - kw, 0, max(Skv - kw, 0))
            kb = jax.lax.dynamic_slice_in_dim(k, start, min(kw, Skv), axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, start, min(kw, Skv), axis=1)
            s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb).astype(jnp.float32)
            qpos = q_start + jnp.arange(cq)[:, None]
            kpos = start + jnp.arange(kb.shape[1])[None, :]
            msk = (kpos <= qpos) & (kpos > qpos - window)
            s = jnp.where(msk[None, None], s, -1e30)
            w = jax.nn.softmax(s, axis=-1).astype(qb.dtype)
            ob = jnp.einsum("bhqk,bkhd->bqhd", w, vb)
            return None, ob

        ck = min(_KV_CHUNK, Skv)
        nk = Skv // ck
        ks = k.reshape(B, nk, ck, H, hd)
        vs = v.reshape(B, nk, ck, H, hd)

        def kv_block(carry, ki):
            m, l, acc = carry  # running max, denom, unnormalized out
            kb = ks[:, ki]
            vb = vs[:, ki]
            s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb).astype(jnp.float32)
            if causal:
                qpos = q_start + jnp.arange(cq)[:, None]
                kpos = ki * ck + jnp.arange(ck)[None, :]
                s = jnp.where((kpos <= qpos)[None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(qb.dtype), vb).astype(jnp.float32)
            return (m_new, l_new, acc), None

        init = (jnp.full((B, H, cq), -jnp.inf, jnp.float32),
                jnp.zeros((B, H, cq), jnp.float32),
                jnp.zeros((B, H, cq, hd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_block, init, jnp.arange(nk))
        ob = (acc / l[..., None]).astype(qb.dtype)  # (B, H, cq, hd)
        return None, jnp.moveaxis(ob, 1, 2)

    _, blocks = jax.lax.scan(q_block, None, jnp.arange(nq))  # (nq,B,cq,H,hd)
    return jnp.moveaxis(blocks, 0, 1).reshape(B, Sq, H, hd)


def causal_mask(Sq: int, Skv: int, q_offset, window: int = 0):
    """(1, 1, Sq, Skv) bool; window > 0 = sliding window attention."""
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    m = kpos <= qpos
    if window > 0:
        m = m & (kpos > qpos - window)
    return m[None, None]


def attention(
    p: Params,
    cfg: ArchConfig,
    x,
    positions,
    *,
    mode: str = "causal",       # causal | bidir | cross
    window: int = 0,
    kv_src=None,                # cross-attention source
    cache: Params | None = None,
    cache_pos=None,             # scalar int32: decode write position
):
    """Returns (out, new_cache). Full-sequence when cache is None; otherwise
    single-token decode that updates the (B, max_len, KV, hd) cache in place."""
    B, Sq, _ = x.shape
    if mode == "cross":
        if cache is not None:
            k, v = cache["k"], cache["v"]  # precomputed encoder KV
            q = (x @ p["wq"]).reshape(B, Sq, cfg.n_heads, cfg.hd)
            if cfg.qk_norm:
                q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
            out = _sdpa(q, k, v, None, cfg)
            return out.reshape(B, Sq, -1) @ p["wo"], cache
        kv_pos = jnp.arange(kv_src.shape[1])[None]
        q, k, v = _project_qkv(p, cfg, x, kv_src, positions, kv_pos, use_rope=False)
        out = _sdpa(q, k, v, None, cfg)
        return out.reshape(B, Sq, -1) @ p["wo"], {"k": k, "v": v}

    use_rope = True
    if cache is None:
        q, k, v = _project_qkv(p, cfg, x, x, positions, positions, use_rope)
        if Sq > CHUNKED_ATTN_THRESHOLD and Sq % _Q_CHUNK == 0 and mode != "bidir":
            out = _chunked_attention(q, k, v, cfg, causal=True, window=window)
        else:
            mask = None if mode == "bidir" else causal_mask(Sq, Sq, 0, window)
            out = _sdpa(q, k, v, mask, cfg)
        return out.reshape(B, Sq, -1) @ p["wo"], {"k": k, "v": v}

    # ---- decode: Sq == 1, append to cache --------------------------------
    # Ring-buffer support: when the cache length L is shorter than the
    # stream (sliding-window archs allocate L == window), slot = pos mod L
    # and every filled slot is, by construction, within the window — a
    # 500k-token hymba decode carries a 1k-slot cache (§Perf iteration 6).
    # int8 KV (cfg.quantize_kv): per-token-per-head absmax scales; halves
    # the cache-read bound (§Perf iteration 7).
    q, k, v = _project_qkv(p, cfg, x, x, positions, positions, use_rope)
    L = cache["k"].shape[1]
    ring = window > 0 and L <= window
    slot = jax.lax.rem(cache_pos, L) if ring else cache_pos
    quant = cfg.quantize_kv and "k_scale" in cache
    if quant:
        def q8(t):
            s = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
            s = jnp.maximum(s, 1e-8)
            return jnp.clip(jnp.round(t.astype(jnp.float32) / s), -127, 127
                            ).astype(jnp.int8), s.astype(jnp.bfloat16)
        k8, ks = q8(k)
        v8, vs = q8(v)
        upd = lambda buf, val: jax.lax.dynamic_update_slice_in_dim(
            buf, val.astype(buf.dtype), slot, axis=1)
        new_cache = {"k": upd(cache["k"], k8), "v": upd(cache["v"], v8),
                     "k_scale": upd(cache["k_scale"], ks),
                     "v_scale": upd(cache["v_scale"], vs)}
        k_cache = (new_cache["k"].astype(jnp.bfloat16)
                   * new_cache["k_scale"].astype(jnp.bfloat16))
        v_cache = (new_cache["v"].astype(jnp.bfloat16)
                   * new_cache["v_scale"].astype(jnp.bfloat16))
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        new_cache = {"k": k_cache, "v": v_cache}
    kpos = jnp.arange(L)[None, :]
    if ring:
        valid = kpos < jnp.minimum(cache_pos + 1, L)
    else:
        valid = kpos <= cache_pos
        if window > 0:
            valid = valid & (kpos > cache_pos - window)
    mask = valid[None, None]  # (1, 1, 1, L) after broadcast with Sq=1
    out = _sdpa(q, k_cache, v_cache, mask, cfg)
    return out.reshape(B, Sq, -1) @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# dense gated MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ArchConfig, d_ff: int | None = None) -> Params:
    ks = jax.random.split(key, 3)
    dff = d_ff or cfg.d_ff
    dt = _dtype(cfg)
    return {
        "wg": dense_init(ks[0], (cfg.d_model, dff), dtype=dt),
        "wu": dense_init(ks[1], (cfg.d_model, dff), dtype=dt),
        "wd": dense_init(ks[2], (dff, cfg.d_model), dtype=dt),
    }


def mlp(p: Params, cfg: ArchConfig, x):
    return (act_fn(cfg.act)(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]


# ---------------------------------------------------------------------------
# MoE: sort-free bucket dispatch with static capacity (dropping)
# ---------------------------------------------------------------------------
# The Mesh-TF one-hot dispatch einsum costs O(T*E*C*d) matmul FLOPs — for
# kimi-k2 (E = 384) that is ~5000x the useful expert FLOPs and would poison
# the roofline.  Instead: top-k routing -> position-in-expert via a single
# one-hot cumsum (elementwise, no matmul) -> scatter into (E, C, d) buckets
# -> 3 batched expert matmuls -> gather + weighted combine.  Overflow
# (pos >= C) drops the assignment, standard capacity-factor semantics.

def init_moe(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 5)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    dt = _dtype(cfg)
    p = {
        "router": dense_init(ks[0], (D, E), scale=0.02, dtype=jnp.float32),
        "wg": dense_init(ks[1], (E, D, F), dtype=dt),
        "wu": dense_init(ks[2], (E, D, F), dtype=dt),
        "wd": dense_init(ks[3], (E, F, D), dtype=dt),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=cfg.d_ff * cfg.n_shared_experts)
    return p


def moe(p: Params, cfg: ArchConfig, x):
    """x: (B, S, D) -> (B, S, D).

    GROUPED dispatch: routing, position-in-expert and the bucket scatter are
    all computed per batch row (vmapped), so under batch=data sharding every
    dispatch op stays data-local — no cross-data all-reduce of the scatter —
    and the expert einsums carry a data-sharded group axis, dividing expert
    FLOPs by the data-parallel degree.  (The original ungrouped dispatch
    replicated the (E, cap, D) buckets across the data axis: 16x wasted
    expert compute and a ~15 TB/device all-reduce storm on kimi-k2; see
    EXPERIMENTS.md §Perf iteration 2.)  Capacity is per-group:
    cap_g = ceil(cf * S * k / E): overflow drops, standard semantics.
    """
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    cap = max(1, int(cfg.capacity_factor * S * k / E))

    def dispatch_group(xg):
        """xg: (S, D) one batch row — everything here is data-local."""
        logits = xg.astype(jnp.float32) @ p["router"]       # (S, E)
        topv, topi = jax.lax.top_k(logits, k)
        weights = jax.nn.softmax(topv, axis=-1)             # (S, k)
        flat_e = topi.reshape(-1)                           # (S*k,)
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - onehot
        pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
        keep = pos < cap
        tok_idx = jnp.arange(S * k) // k
        e_idx = jnp.where(keep, flat_e, 0)
        p_idx = jnp.where(keep, pos, cap - 1)
        src = jnp.where(keep[:, None], xg[tok_idx], 0)
        buckets = jnp.zeros((E, cap, D), x.dtype).at[e_idx, p_idx].add(src)
        return buckets, (e_idx, p_idx, keep, weights)

    buckets, meta = jax.vmap(dispatch_group)(x)             # (B, E, cap, D)
    # group axis on data, expert axis on model: expert compute is fully
    # partitioned over the whole mesh
    buckets = constrain(buckets, "batch", "model", None, None)

    h = jnp.einsum("gecd,edf->gecf", buckets, p["wg"])
    h = act_fn(cfg.act)(h) * jnp.einsum("gecd,edf->gecf", buckets, p["wu"])
    out_buckets = jnp.einsum("gecf,efd->gecd", h, p["wd"])  # (B, E, cap, D)
    out_buckets = constrain(out_buckets, "batch", "model", None, None)

    def combine_group(ob, m):
        e_idx, p_idx, keep, weights = m
        gathered = ob[e_idx, p_idx]                          # (S*k, D)
        gathered = jnp.where(keep[:, None], gathered, 0)
        w = weights.reshape(-1)[:, None].astype(x.dtype)
        return jnp.sum((gathered * w).reshape(S, k, D), axis=1)

    y = jax.vmap(combine_group)(out_buckets, meta)           # (B, S, D)
    if cfg.n_shared_experts:
        y = y + mlp(p["shared"], cfg, x)
    return y
