from . import layers, model, ssm
from .config import SHAPES, ArchConfig, ShapeConfig

__all__ = ["SHAPES", "ArchConfig", "ShapeConfig", "model", "layers", "ssm"]
