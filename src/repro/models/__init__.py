from .config import SHAPES, ArchConfig, ShapeConfig
from . import model, layers, ssm

__all__ = ["SHAPES", "ArchConfig", "ShapeConfig", "model", "layers", "ssm"]
