"""Deterministic synthetic LM data pipeline.

Produces a reproducible token stream (per-step, per-shard seeded — any host
can regenerate any shard independently, which is what makes the pipeline
restart- and elastic-safe: there is no dataloader state to checkpoint beyond
the step counter).  Batches mimic a Zipf-ish unigram mixture with induced
bigram structure so that a ~100M model shows a real learning curve (the
quickstart example trains on it).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ArchConfig, ShapeConfig


@dataclass(frozen=True)
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def host_batch(self, step: int, shard: int = 0, n_shards: int = 1) -> dict:
        """numpy batch for this host's shard of the global batch."""
        per = self.global_batch // n_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard]))
        # structured stream: markov-ish chain over a Zipf unigram base
        base = rng.zipf(1.3, size=(per, self.seq_len + 1)) % self.vocab
        shift = rng.integers(0, 17, size=(per, 1))
        mix = rng.random((per, self.seq_len + 1)) < 0.7
        chain = (np.roll(base, 1, axis=1) * 31 + shift) % self.vocab
        toks = np.where(mix, chain, base).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def device_batch(self, step: int) -> dict:
        b = self.host_batch(step)
        return {k: jnp.asarray(v) for k, v in b.items()}


def make_batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStructs for every model input of this (arch, shape) cell —
    the dry-run stand-ins (no allocation)."""
    B = shape.global_batch
    if shape.kind == "decode":
        specs = {
            "token": jax.ShapeDtypeStruct((B,), jnp.int32),
        }
        return specs
    S = shape.seq_len
    S_text = S
    specs = {}
    if cfg.family == "vlm":
        S_text = max(S - cfg.n_patches, 1)
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.n_frames, cfg.d_model), jnp.bfloat16)
    specs["tokens"] = jax.ShapeDtypeStruct((B, S_text), jnp.int32)
    if shape.is_train:
        specs["labels"] = jax.ShapeDtypeStruct((B, S_text), jnp.int32)
    return specs
