"""Decentralized decode & repair: the recovery dual of `repro.api`.

    from repro.api import CodeSpec
    from repro.recover import Decoder

    spec = CodeSpec(kind="rs", K=16, R=4)
    plan = Decoder.plan(spec, erased=(2, 17), backend="simulator")
    lost = plan.run(v)       # v: symbols at plan.kept -> symbols at plan.erased
    x    = plan.data(v)      # full original data (degraded read)

Erasure decode of the systematic codeword [x | x^T A] dualizes to an
all-to-all *encode* among the >= K survivors with the repair matrix
D = S^-1 G[:, E] (S the survivor submatrix of G = [I | A]) — so the same
three backends execute it with bitwise-identical results: `"simulator"`
(RoundNetwork with the erased processors `fail()`-ed; measured C1/C2),
`"mesh"` (shard_map/ppermute over survivor devices), `"local"`
(Pallas/jnp `decode_blocks` kernel).  Host tables — submatrix inverse,
repair matrix, batch blocks, compiled mesh executables — are cached per
(spec, erasure pattern); see `planner` for the cache contract and
`engine` for the round-network schedule and its exact closed-form cost.
"""
from .engine import decentralized_decode, decode_batches, decode_cost
from .planner import (
    DecodePlan,
    Decoder,
    RepairAttempt,
    RepairReport,
    UndecodableError,
    repair_with_faults,
)

__all__ = [
    "Decoder", "DecodePlan", "UndecodableError",
    "RepairAttempt", "RepairReport", "repair_with_faults",
    "decentralized_decode", "decode_batches", "decode_cost",
]
