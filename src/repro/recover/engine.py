"""All-to-all decode on the p-port round network (simulator backend body).

Erasure decode *dualizes* to the encode framework (Sec. III): once the
erasure pattern E is fixed, the lost symbols are a linear map of the K
chosen survivor symbols,

    y_E = D^T v        with  D = S^-1 G[:, E],  S = G[:, kept]  (K x K),

so the survivors can recompute them collectively with exactly the encode
machinery — sources are the K kept survivors (holding their codeword
symbols), "sinks" are the repaired positions, and the generator block is D.
Because D is a product with an inverse it carries no Vandermonde structure,
so the universal prepare-and-shoot schedule is the one that applies
(Sec. IV-B; the RS draw-and-loose factorization does not survive the
inversion).

Schedule (mirrors `core.framework.decentralized_encode`, case K >= R, with
the sinks *overlaid* on the survivors — no helper processors exist after a
failure, so nothing can be borrowed):

  * the |E| repair targets are processed in batches of at most K columns;
    a batch of width e is zero-padded to E' = the smallest divisor of K
    with E' >= e (zero columns ride along for free in prepare-and-shoot's
    C2 — message sizes depend only on the group size)
  * phase 1: the K kept survivors form an E' x M grid (M = K/E'); group m
    runs a square E' x E' prepare-and-shoot on its row block D'_m,
    leaving the partial sum for target j on its j-th member
  * phase 2: for each target j, a (p+1)-nomial reduce over the M group
    members onto kept[j] — the repaired symbol for erased position E[j]
    lands on the j-th kept survivor (rotating-parity style double duty).

Costs are closed-form (`decode_cost`, asserted against measured
`RoundNetwork` C1/C2 in tests): per batch, Thm. 3's universal A2A cost at
group size E' plus ceil(log_{p+1} M) reduce rounds.

The simulator backend now executes this schedule as a `core.schedule`
decode `RoundIR` (`schedule.build_decode_ir` transcribes the batched
grid above round-for-round); `decentralized_decode` remains the
paper-fidelity generator body and the shim for direct callers.
"""
from __future__ import annotations

import numpy as np

from ..core import collectives
from ..core.collectives import _n_rounds
from ..core.cost_model import LinearCost
from ..core.field import Field
from ..core.prepare_shoot import cost_universal_exact, prepare_shoot
from ..core.simulator import RoundNetwork, run_lockstep


def pad_width(K: int, e: int) -> int:
    """Smallest divisor of K that is >= e (the padded batch width E')."""
    assert 1 <= e <= K
    for d in range(e, K + 1):
        if K % d == 0:
            return d
    raise AssertionError("unreachable: K divides K")


def decode_batches(K: int, n_erased: int) -> list[tuple[int, int]]:
    """Column batches [(width, padded_width)] covering n_erased targets."""
    out = []
    left = n_erased
    while left > 0:
        e = min(left, K)
        out.append((e, pad_width(K, e)))
        left -= e
    return out


def batch_block(D: np.ndarray, b: int) -> np.ndarray:
    """Zero-padded (K, E') column block b of the repair matrix D.

    The single place the batching contract lives: both the simulator
    schedule and the mesh table builder consume exactly these blocks."""
    K = D.shape[0]
    widths = decode_batches(K, D.shape[1])
    eb, ep = widths[b]
    col = sum(w for w, _ in widths[:b])
    blk = np.zeros((K, ep), np.int64)
    blk[:, :eb] = D[:, col : col + eb]
    return blk


def decode_cost(K: int, n_erased: int, p: int = 1) -> LinearCost:
    """Closed-form (C1, C2) of the all-to-all decode at W = 1.

    Per batch: one universal A2A at the padded group size E'
    (`cost_universal_exact` — the M = K/E' grid groups run in lockstep, so
    the parallel instances do not change the per-round maximum) plus
    ceil(log_{p+1} M) reduce rounds of one element each.  Exact: tests
    assert measured RoundNetwork counts equal this.
    """
    c1 = c2 = 0
    for _, ep in decode_batches(K, n_erased):
        u1, u2 = cost_universal_exact(ep, p)
        t = _n_rounds(K // ep, p)
        c1 += u1 + t
        c2 += u2 + t
    return LinearCost(c1, c2)


def decentralized_decode(
    field: Field,
    D: np.ndarray,
    v: np.ndarray,
    kept: list[int],
    p: int = 1,
    net: RoundNetwork | None = None,
) -> tuple[np.ndarray, RoundNetwork]:
    """Run the all-to-all decode; returns (repaired (|E|, W), network).

    D: (K, |E|) repair matrix; v: (K, W) survivor symbols ordered like
    `kept` (the global processor ids of the K chosen survivors — on a
    network with failures, none of them may be failed).
    """
    D = field.arr(D)
    v = field.arr(v)
    K, E = D.shape
    assert v.shape[0] == K == len(kept)
    net = net or RoundNetwork((max(kept) + 1) if kept else 1, p)

    rows: list[np.ndarray] = []
    for b, (eb, ep) in enumerate(decode_batches(K, E)):
        Db = batch_block(D, b)
        M = K // ep

        # ---- phase 1: M parallel square A2As on the row blocks D'_m -----
        partial: dict[int, np.ndarray] = {}
        gens = []
        for m in range(M):
            procs = [kept[m * ep + j] for j in range(ep)]
            vals = {procs[j]: v[m * ep + j] for j in range(ep)}
            gens.append(
                prepare_shoot(field, Db[m * ep : (m + 1) * ep, :], vals,
                              procs, p, partial))
        net.run(run_lockstep(*gens))

        # ---- phase 2: per-target reduce across the M groups -------------
        if M > 1:
            out: dict[int, np.ndarray] = {}
            gens = []
            for j in range(ep):
                procs = [kept[m * ep + j] for m in range(M)]  # root kept[j]
                vals = {q: partial[q] for q in procs}
                gens.append(collectives.reduce(field, vals, procs, p, out))
            net.run(run_lockstep(*gens))
        else:
            out = partial

        rows.extend(out[kept[j]] for j in range(eb))

    if not rows:
        return np.zeros((0,) + v.shape[1:], np.int64), net
    return np.stack(rows), net
