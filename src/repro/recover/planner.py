"""Decoder: the decode/repair planner, mirroring `repro.api.Encoder`.

    spec = CodeSpec(kind="rs", K=16, R=4)
    plan = Decoder.plan(spec, erased=(2, 17), backend="simulator")
    lost = plan.run(v)        # v: (K, W) symbols at plan.kept -> (|E|, W)
    x    = plan.data(v)       # full original data (K, W)

The systematic codeword of a spec is [x | EncodePlan.run(x)] — data symbol
k lives on processor k, parity symbol r on processor K + r.  `erased` is a
set of codeword positions in [0, K + R); `plan.run` recomputes exactly the
erased symbols from the K survivors `plan.kept` (chosen greedily as the
first survivor positions whose generator columns are linearly independent
— for MDS kinds that is simply the first K survivors; the DFT transform's
[I | A] is *not* MDS, and a pattern whose survivors span less than the
full message space raises `UndecodableError`).

Like the encoder, everything host-side happens once at plan time and is
cached: the survivor submatrix inverse S^-1, the repair matrix
D = S^-1 G[:, E], the padded batch blocks, and (mesh backend) the compiled
shard_map executables.  Three backends return bitwise-identical symbols:

    simulator — all-to-all decode among the survivors on a RoundNetwork
                with the erased processors `fail()`-ed (measured C1/C2 on
                `plan.sim_net`)
    mesh      — devices-as-survivors shard_map/ppermute execution
    local     — single-device Pallas/jnp `decode_blocks` kernel
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field as dc_field
from typing import Any

import numpy as np

from ..api.planner import ALPHA_DEFAULT, BETA_BITS_DEFAULT, _digest, _host_tables
from ..api.registry import PlanStats, get_backend
from ..api.spec import CodeSpec
from ..core.cost_model import LinearCost
from ..core.field import FERMAT_Q, Field
from ..core.matrices import gauss_inverse
from ..core.simulator import PartialRunError, RoundNetwork
from .engine import batch_block, decode_batches, decode_cost


class UndecodableError(ValueError):
    """The erasure pattern is information-losing: a nonzero codeword is
    supported entirely on the erased positions (only possible for non-MDS
    kinds, e.g. the DFT transform's [I | A] codeword)."""


def _choose_kept(field: Field, G: np.ndarray, survivors: list[int], K: int) -> tuple[int, ...]:
    """First K survivor positions with linearly independent generator
    columns (greedy Gaussian elimination over F_q)."""
    basis: list[tuple[int, np.ndarray]] = []  # (pivot row, normalized col)
    kept: list[int] = []
    for s in survivors:
        vec = G[:, s] % field.q
        for piv, r in basis:
            if vec[piv]:
                vec = (vec - vec[piv] * r) % field.q
        nz = np.nonzero(vec)[0]
        if nz.size == 0:
            continue
        piv = int(nz[0])
        basis.append((piv, (vec * int(field.inv(vec[piv]))) % field.q))
        kept.append(s)
        if len(kept) == K:
            return tuple(kept)
    raise UndecodableError(
        f"survivors span a {len(kept)}-dimensional space < K={K}: the "
        "erasure pattern is undecodable for this (non-MDS) code")


# ---------------------------------------------------------------------------
# host-side decode tables (cached per spec x erasure pattern, W-independent)
# ---------------------------------------------------------------------------

@dataclass
class DecodeTables:
    """Everything host-side a decode plan needs, built once per
    (spec, erased) and shared across backends and payload widths."""

    spec: CodeSpec
    field: Field
    erased: tuple[int, ...]      # sorted codeword positions, |E| <= R
    kept: tuple[int, ...]        # the K chosen survivor positions
    D: np.ndarray                # (K, |E|) repair matrix  S^-1 G[:, E]
    Dd: np.ndarray               # (K, K)  data matrix     S^-1
    _mesh: dict[int, Any] = dc_field(default_factory=dict)
    _ir: Any = None              # lazy core.schedule.RoundIR

    def ir(self):
        """The decode `core.schedule.RoundIR` among the kept survivors,
        built and `validate()`d (against the erasure set) once per table
        set — the simulator executes exactly this program."""
        if self._ir is None:
            from ..core.schedule import build_decode_ir

            self._ir = build_decode_ir(
                self.spec, self.D, list(self.kept)).validate(
                    failed=set(self.erased))
        return self._ir

    def batches(self) -> list[tuple[int, int]]:
        return decode_batches(self.spec.K, len(self.erased))

    def batch_block(self, b: int) -> np.ndarray:
        """Zero-padded (K, E') column block of D for batch b (the same
        blocks the simulator schedule runs — see `engine.batch_block`)."""
        return batch_block(self.D, b)

    def mesh_tables(self, b: int):
        """ParityTables for batch b's universal mesh A2A, built once."""
        if b not in self._mesh:
            from ..core.parity import build_encode_tables

            self._mesh[b] = build_encode_tables(
                self.field, self.batch_block(b), p=self.spec.p,
                method="universal")
        return self._mesh[b]


# Unlike the encoder's caches (keyed by a handful of specs), decode keys
# range over erasure *patterns* — a combinatorial space on a long-running
# server that decodes around ever-changing failure sets — so both caches
# are LRU-bounded instead of unbounded dicts.
_DTABLES: "OrderedDict[tuple, DecodeTables]" = OrderedDict()
_DPLANS: "OrderedDict[tuple, DecodePlan]" = OrderedDict()
_DTABLES_MAX = 256
_DPLANS_MAX = 512
_DSTATS = {"table_hits": 0, "table_misses": 0,
           "plan_hits": 0, "plan_misses": 0}


def _lru_get(cache: OrderedDict, key):
    hit = cache.get(key)
    if hit is not None:
        cache.move_to_end(key)
    return hit


def _lru_put(cache: OrderedDict, key, value, maxsize: int) -> None:
    cache[key] = value
    while len(cache) > maxsize:
        cache.popitem(last=False)


def _decode_tables(spec: CodeSpec, erased: tuple[int, ...],
                   A: np.ndarray | None, digest: str | None) -> DecodeTables:
    key = spec.table_key() + (digest, erased)
    hit = _lru_get(_DTABLES, key)
    if hit is not None:
        _DSTATS["table_hits"] += 1
        return hit
    _DSTATS["table_misses"] += 1
    host = _host_tables(spec, A, digest)   # shares the Encoder's table cache
    f = host.field
    K = spec.K
    G = np.concatenate([np.eye(K, dtype=np.int64), host.A % f.q], axis=1)
    survivors = [i for i in range(spec.N) if i not in set(erased)]
    kept = _choose_kept(f, G, survivors, K)
    sub = G[:, list(kept)]
    inv_sub = gauss_inverse(f, sub)
    D = f.matmul(inv_sub, G[:, list(erased)])
    tables = DecodeTables(spec, f, erased, kept, D, inv_sub)
    _lru_put(_DTABLES, key, tables, _DTABLES_MAX)
    return tables


# ---------------------------------------------------------------------------
# DecodePlan
# ---------------------------------------------------------------------------

@dataclass
class DecodePlan(PlanStats):
    """An executable erasure decode: spec + erasure pattern + backend +
    cached host tables.  Obtained from `Decoder.plan`; cached — hold on to
    it and call `.run` per payload.

    Per-run measurements (`last_stats`, `sim_net`, `stream_stats`) are
    thread-local (see `api.registry.PlanStats`): plans are shared across
    threads and each thread reads only its own last run.
    """

    op = "decode"  # stream/backend dispatch discriminator (not a field)

    spec: CodeSpec
    backend: str
    tables: DecodeTables
    _mesh_fns: list | None = None
    _local_fn: Any = None
    # thread-local per-run stats storage (PlanStats reads/writes this)
    _tls: Any = dc_field(default_factory=threading.local, repr=False)

    @property
    def field(self) -> Field:
        return self.tables.field

    @property
    def erased(self) -> tuple[int, ...]:
        """Sorted erased codeword positions; `run` returns their symbols."""
        return self.tables.erased

    @property
    def kept(self) -> tuple[int, ...]:
        """The K survivor positions whose symbols `run`/`data` consume,
        in input-row order."""
        return self.tables.kept

    @property
    def survivors(self) -> tuple[int, ...]:
        """All non-erased codeword positions."""
        dead = set(self.tables.erased)
        return tuple(i for i in range(self.spec.N) if i not in dead)

    @property
    def D(self) -> np.ndarray:
        """(K, |E|) repair matrix: erased symbols are v^T D per column."""
        return self.tables.D

    def _check(self, v) -> tuple[np.ndarray, bool]:
        v = np.asarray(v)
        if v.shape[0] != self.spec.K:
            raise ValueError(
                f"v must carry the K={self.spec.K} survivor symbols of "
                f"plan.kept along its leading dim, got {v.shape}")
        return (v[:, None], True) if v.ndim == 1 else (v, False)

    def run(self, v) -> np.ndarray:
        """Recompute the erased symbols: v (K,)/(K, W) survivor symbols
        ordered like `plan.kept` -> (|E|,)/(|E|, W) repaired symbols
        ordered like `plan.erased`."""
        v, squeeze = self._check(v)
        if not self.erased:
            y = np.zeros((0, v.shape[1]), np.int64)
        else:
            y = get_backend(self.backend).decode(self, v)
        return y[:, 0] if squeeze else y

    def run_stream(self, payload, *, chunk_w: int | None = None):
        """Streamed repair: generator of (|E|, w) blocks of recomputed
        symbols; same chunking/pipelining/bitwise contract as
        `EncodePlan.run_stream` (see api/stream.py).  `payload` carries the
        K survivor symbols of `plan.kept` along its leading dim."""
        from ..api import stream

        if not self.erased:
            def _zeros():
                for c in stream.iter_chunks(payload, self.spec.K, chunk_w):
                    yield np.zeros((0, c.shape[1]), np.int64)
            return _zeros()
        return stream.run_stream(self, payload, chunk_w=chunk_w)

    def run_batched(self, vs, *, chunk_w: int | None = None) -> list[np.ndarray]:
        """Repair a batch of survivor payloads (each (K,) or (K, W_i)) in
        one coalesced streamed execution."""
        from ..api import stream

        if not self.erased:
            return [np.zeros((0,) + np.asarray(v).shape[1:], np.int64)
                    for v in vs]
        return stream.run_batched(self, vs, chunk_w=chunk_w)

    # -- streaming adapter (see api/stream.py) ------------------------------
    def _stream_sim_chunk(self, v: np.ndarray):
        from .backends import run_simulator

        return run_simulator(self, v)  # (y, RoundNetwork) pair

    def _stream_device_fn(self):
        import jax
        import numpy as _np

        from .backends import _mesh_callables, local_decode_callable

        q = self.field.q

        def to_device(c):
            return jax.device_put(
                _np.ascontiguousarray(c % q).astype(_np.uint32))

        if self.backend == "mesh":
            fns = _mesh_callables(self)
            widths = self.tables.batches()

            def dev_fn(vg):
                return [fn(vg) for fn in fns]

            def finalize(ys):
                return np.concatenate(
                    [np.asarray(y, np.int64)[:eb]
                     for y, (eb, _) in zip(ys, widths)], axis=0)

            return to_device, dev_fn, finalize
        fn = local_decode_callable(self)
        return to_device, fn, lambda y: np.asarray(y, np.int64)

    def data(self, v) -> np.ndarray:
        """Decode the full original data x (K, W) from the survivors (the
        degraded-read path).  Runs on the kernel solve path for the Fermat
        field, the exact host matmul otherwise — bitwise identical."""
        v, squeeze = self._check(v)
        f = self.field
        if f.q == FERMAT_Q:
            import jax.numpy as jnp

            from ..kernels.ops import decode_blocks

            x = np.asarray(decode_blocks(
                jnp.asarray(v % f.q, jnp.uint32),
                jnp.asarray(self.tables.Dd % f.q, jnp.uint32)), np.int64)
        else:
            x = f.matmul(self.tables.Dd.T, v)
        return x[:, 0] if squeeze else x

    def cost(self) -> LinearCost:
        """Closed-form (C1, C2) of the simulator decode schedule, with the
        spec's payload width W folded into C2 (Encoder convention)."""
        c = decode_cost(self.spec.K, len(self.erased), self.spec.p)
        return LinearCost(c.C1, c.C2 * self.spec.W)

    def schedule_ir(self):
        """The decode `core.schedule.RoundIR` this plan's simulator path
        executes (shared, via the tables, across backends/widths)."""
        return self.tables.ir()

    def describe(self) -> str:
        s = self.spec
        c = self.cost()
        model_us = c.total(ALPHA_DEFAULT, BETA_BITS_DEFAULT) * 1e6
        batches = self.tables.batches()
        sched = (self.schedule_ir().summary() if self.erased
                 else "empty (nothing erased)")
        return "\n".join([
            f"DecodePlan[{s.kind}] K={s.K} R={s.R} p={s.p} W={s.W} q={s.q}",
            f"  backend : {self.backend}",
            f"  erased  : {list(self.erased)} ({len(self.erased)} of <= {s.R})",
            f"  kept    : {list(self.kept)}",
            f"  batches : {batches} (width, padded to divisor of K)",
            f"  cost    : C1={c.C1} rounds, C2={c.C2} elems/port "
            f"(model C ~ {model_us:.1f} us)",
            f"  schedule: {sched}",
        ])


# ---------------------------------------------------------------------------
# live-failure repair: restart the decode against the enlarged erasure set
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RepairAttempt:
    """One (re)planned decode attempt inside `repair_with_faults`: the
    pattern it targeted, the exact rounds/traffic it consumed on the shared
    network (for an aborted attempt, the completed prefix only), and — when
    aborted — the processors whose mid-run death enlarged the pattern."""

    erased: tuple[int, ...]
    C1: int
    C2: int
    completed: bool
    killed: tuple[int, ...] = ()


@dataclass
class RepairReport:
    """Result of `repair_with_faults`: the fully healed codeword, the plan
    of the final (largest) erasure pattern, the network whose cumulative
    C1/C2 account every aborted prefix plus the successful retry exactly
    (tests assert `net.C1 == sum(a.C1 for a in attempts)` and the last
    attempt's C1 equals the closed-form `decode_cost`), and the per-attempt
    trace."""

    codeword: np.ndarray
    plan: "DecodePlan"
    net: RoundNetwork
    attempts: list[RepairAttempt]

    @property
    def erased(self) -> tuple[int, ...]:
        """The final erasure pattern the repair recomputed."""
        return self.plan.erased

    @property
    def restarts(self) -> int:
        return sum(1 for a in self.attempts if not a.completed)


def repair_with_faults(spec: CodeSpec, cw, erased=(), *,
                       net: RoundNetwork | None = None,
                       A: np.ndarray | None = None) -> RepairReport:
    """Repair `erased` on the round network under live failure injection.

    Runs the decode-as-encode schedule among the survivors of `erased` on
    `net` (a fresh `RoundNetwork(spec.N, spec.p)` by default — pass one
    with `fail_at` kills registered, e.g. via `core.FaultInjector`, to
    inject chaos).  When a kill lands mid-schedule, the resulting
    `PartialRunError` is caught, the erasure set enlarged by the newly
    dead processors, and the repair *restarted* against the superset
    pattern on the SAME network — so `net.C1`/`net.C2` account the aborted
    prefix plus the retry exactly.  A kill that hits an idle survivor
    (one the schedule never touches) still loses that symbol: a follow-up
    pass recomputes it before returning.

    `cw` is the full (N, W) (or (N,)) codeword; rows at erased positions
    are ignored.  Returns a `RepairReport` whose `codeword` is the fully
    healed (N, W) — bitwise-equal to the original for any total failure
    count <= R (beyond R, `Decoder.plan` refuses with the usual
    `ValueError`; information-losing dft patterns raise
    `UndecodableError`).
    """
    cw = np.asarray(cw)
    squeeze = cw.ndim == 1
    v2 = cw[:, None] if squeeze else cw
    if v2.shape[0] != spec.N:
        raise ValueError(
            f"cw must carry the full N={spec.N} codeword rows, got "
            f"{cw.shape}")
    net = net or RoundNetwork(spec.N, spec.p)
    net.fail({int(e) for e in erased})
    attempts: list[RepairAttempt] = []
    while True:
        # a kill due exactly at this round boundary enlarges the pattern
        # BEFORE planning (it would abort the very first round otherwise)
        net.apply_pending_kills()
        pattern = tuple(sorted(net.failed))
        plan = Decoder.plan(spec, erased=pattern, backend="simulator", A=A)
        c1_0, c2_0 = net.C1, net.C2
        f = plan.field
        v = f.arr(v2[list(plan.kept)])
        try:
            from ..core import schedule

            y = schedule.execute(plan.schedule_ir(), f, v, net)
        except PartialRunError as exc:
            attempts.append(RepairAttempt(
                pattern, net.C1 - c1_0, net.C2 - c2_0, completed=False,
                killed=tuple(sorted(set(exc.failed) - set(pattern)))))
            continue
        attempts.append(RepairAttempt(
            pattern, net.C1 - c1_0, net.C2 - c2_0, completed=True))
        if net.failed - set(pattern):
            # an idle survivor died mid-run without aborting the schedule;
            # its symbol is lost all the same — repair the superset too
            continue
        healed = (v2 % spec.q).astype(np.int64)
        if pattern:
            healed[list(pattern)] = np.asarray(y, np.int64)
        return RepairReport(healed[:, 0] if squeeze else healed, plan, net,
                            attempts)


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------

class Decoder:
    """Namespace for the decode plan-then-execute API (all classmethods)."""

    ALPHA = ALPHA_DEFAULT
    BETA_BITS = BETA_BITS_DEFAULT

    @classmethod
    def plan(cls, spec: CodeSpec, erased, backend: str = "simulator",
             A: np.ndarray | None = None) -> DecodePlan:
        """Plan a decode of the given erasure pattern.

        erased : iterable of codeword positions in [0, K + R); data symbol
                 k is position k, parity symbol r is position K + r.
                 At most R positions may be erased.
        backend: a registered backend name ("simulator" | "mesh" | "local"
                 built in; see `api.register_backend`), capability-checked
                 here at plan time
        A      : explicit generator block for kind="universal"/"lagrange"
                 specs — must match the block the data was encoded with.
        """
        get_backend(backend).validate(spec, op="decode")
        erased = tuple(sorted({int(e) for e in erased}))
        if erased and not (0 <= erased[0] and erased[-1] < spec.N):
            raise ValueError(
                f"erased positions must lie in [0, {spec.N}), got {erased}")
        if len(erased) > spec.R:
            raise ValueError(
                f"{len(erased)} erasures exceed the code's R={spec.R}")
        digest = _digest(A)
        plan_key = (spec, erased, backend, digest)
        hit = _lru_get(_DPLANS, plan_key)
        if hit is not None:
            _DSTATS["plan_hits"] += 1
            return hit
        _DSTATS["plan_misses"] += 1
        tables = _decode_tables(spec, erased, A, digest)
        plan = DecodePlan(spec, backend, tables)
        _lru_put(_DPLANS, plan_key, plan, _DPLANS_MAX)
        return plan

    @classmethod
    def cache_info(cls) -> dict[str, int]:
        return dict(_DSTATS, plans=len(_DPLANS), tables=len(_DTABLES))

    @classmethod
    def cache_clear(cls) -> None:
        """Drop the decode-side caches (plans + decode tables).  Safe on
        its own — decode tables reference encode host tables, not the
        other way round; for a full coordinated clear of both stacks use
        `repro.api.cache_clear()` / `Encoder.cache_clear()`."""
        _clear_decoder_state()


def _clear_decoder_state() -> None:
    _DPLANS.clear()
    _DTABLES.clear()
    for k in _DSTATS:
        _DSTATS[k] = 0
