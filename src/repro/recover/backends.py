"""The decode halves of the three built-in backends (the `Backend`
objects binding these to the registry live in `api.backends`).

    simulator — all-to-all decode among the K kept survivors on the
                round network, with the erased processors fail()-ed
                (exact numpy oracle; measured C1/C2 recorded
                thread-locally on `plan.last_stats` / `plan.sim_net`)
    mesh      — devices-as-survivors shard_map execution: device i holds
                the symbol of survivor `plan.kept[i]`; each batch of
                repair columns runs the same universal mesh A2A as the
                encode path, with the repaired symbols landing on devices
                0..E'-1
    local     — single-device `kernels.ops.decode_blocks` (Pallas/jnp)

All three return the erased symbols bitwise-equal: row j holds
v^T D[:, j] over F_q for erased position `plan.erased[j]`.
"""
from __future__ import annotations

from functools import partial

import numpy as np

from ..core import schedule
from ..core.simulator import RoundNetwork
from ..obs.trace import kernel_span


def run_simulator(plan, v: np.ndarray) -> tuple[np.ndarray, RoundNetwork]:
    """Decode on the paper's p-port round network: the erased processors
    are failed (any schedule touching them would raise); returns the
    repaired symbols and the network with its measured C1/C2.  Executes
    the plan's decode `RoundIR` (`plan.schedule_ir()`) generically — the
    same rounds the retired `decentralized_decode` generators produced."""
    spec, f = plan.spec, plan.field
    net = RoundNetwork(spec.N, spec.p)
    net.fail(plan.erased)
    y = schedule.execute(plan.schedule_ir(), f, f.arr(v), net)
    return np.asarray(y, np.int64), net


def local_decode_callable(plan):
    """The plan's single jitted local-decode executable (K, w) uint32 ->
    (|E|, w) uint32, cached for the plan's lifetime (jit's shape cache
    gives one compiled variant per chunk width — see api/stream.py)."""
    if plan._local_fn is None:
        import jax.numpy as jnp

        from ..api.stream import maybe_donate_jit
        from ..kernels.ops import decode_blocks

        D = jnp.asarray(plan.tables.D % plan.field.q, jnp.uint32)
        plan._local_fn = maybe_donate_jit(lambda v: decode_blocks(v, D),
                                          donate=False)
    return plan._local_fn


def run_local(plan, v: np.ndarray) -> np.ndarray:
    """Single-device decode on the Pallas/jnp kernel path (no network)."""
    import jax.numpy as jnp

    q = plan.field.q
    v32 = jnp.asarray(np.asarray(v) % q, jnp.uint32)
    with kernel_span("local_decode", kind=plan.spec.kind, K=plan.spec.K,
                     E=len(plan.erased), w=int(v32.shape[1])):
        y = local_decode_callable(plan)(v32)
    return np.asarray(y, np.int64)


def _mesh_callables(plan) -> list:
    """One jitted shard_map executable per repair batch, kept for the
    plan's lifetime (same caching contract as `EncodePlan.mesh_callable`).

    Each executable maps the global (K, W) uint32 survivor array (device i
    <-> survivor `plan.kept[i]`) to a (K, W) array whose rows 0..E'-1 hold
    the batch's repaired symbols.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P

    from ..api.backends import _require_devices
    from ..core.parity import mesh_parity_encode
    from ..core.shardmap_exec import shard_map

    if plan._mesh_fns is not None:
        return plan._mesh_fns

    spec = plan.spec
    devs = _require_devices(spec.K)
    mesh = Mesh(np.array(devs), ("dec",))

    def _batch_fn(t):
        arrs = t.device_arrays()
        keys = list(arrs)

        @partial(shard_map, mesh=mesh,
                 in_specs=(P("dec"),) + tuple(P("dec") for _ in keys),
                 out_specs=P("dec"))
        def step(xb, *tb):
            rows = {k: a[0] for k, a in zip(keys, tb)}
            return mesh_parity_encode(xb[0], rows, t, "dec")[None]

        args = tuple(jnp.asarray(arrs[k]) for k in keys)
        return jax.jit(lambda xg: step(xg, *args))

    fns = [_batch_fn(plan.tables.mesh_tables(b))
           for b in range(len(plan.tables.batches()))]
    plan._mesh_fns = fns
    return fns


def run_mesh(plan, v: np.ndarray) -> np.ndarray:
    import jax.numpy as jnp

    q = plan.field.q
    vg = jnp.asarray(np.asarray(v) % q, jnp.uint32)
    out = []
    with kernel_span("mesh_decode", kind=plan.spec.kind, K=plan.spec.K,
                     E=len(plan.erased), w=int(vg.shape[1])):
        for fn, (eb, _) in zip(_mesh_callables(plan), plan.tables.batches()):
            y = np.asarray(fn(vg), np.int64)
            out.append(y[:eb])
    return np.concatenate(out, axis=0)
