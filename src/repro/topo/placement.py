"""Processor -> (host, device)-slot placements and per-tier closed forms.

A `Placement` assigns each framework processor (sources 0..K-1, sinks
K..N-1) a slot of a `Topology`; the host of processor i is then
`slots[i] // devices_per_host`.  Policies:

  * "flat"     — topology-oblivious round-robin across hosts (the strawman
                 a scheduler that ignores the hierarchy produces): adjacent
                 processors land on different hosts, so group-local
                 prepare-and-shoot traffic crosses hosts.
  * "affinity" — pack each phase-one A2A group onto a single host whenever
                 the group size fits `devices_per_host` (first-fit), then
                 spread the remaining processors emptiest-host-first so
                 the sinks get a host of their own when one is free.

`tiered_encode_cost` gives the exact per-tier (C1, C2) split of the
Table-I model under a placement, when the placement is *uniform* per
phase (every list co-hosted, or every list spread across distinct hosts).
The split leans on the round structure of the schedules:

  * Phase-level split: the framework cost is a2a + broadcast
    (`cost_model.framework`), and the broadcast/reduce tree part
    (T, T*W) is exact round-for-round, so the phase boundary is exact
    whenever the flat total is (which the drift ledger already asserts).
  * A2A phases run all groups lockstep with identical schedules, and
    every member sends in every active round — so if ANY group is not
    co-hosted, EVERY round of the phase carries a cross-host message and
    the whole phase is inter; if all groups are co-hosted it is intra.
  * Broadcast/reduce trees are not all-send-every-round, so their rows
    must be uniformly co-hosted (intra) or pairwise cross-host (inter);
    anything mixed has no closed form and returns None (the simulator's
    measured per-tier counters still apply).
  * DFT: stage h moves data at stride P^(H-h-1); each stage is its own
    lockstep A2A phase, so the all-or-nothing rule applies per stage and
    the form is exact for ANY placement.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from ..core.cauchy import cost_cauchy
from ..core.collectives import cost_broadcast
from ..core.cost_model import LinearCost
from ..core.dft_a2a import _stage_groups
from ..core.prepare_shoot import cost_universal
from .model import TieredCost, Topology


@dataclass(frozen=True)
class Placement:
    """An injective map of processors onto topology slots."""

    topology: Topology
    slots: tuple[int, ...]
    policy: str = "custom"

    def __post_init__(self):
        object.__setattr__(self, "slots", tuple(self.slots))
        n = self.topology.n_slots
        if len(set(self.slots)) != len(self.slots):
            raise ValueError("placement slots must be distinct")
        for s in self.slots:
            if not 0 <= s < n:
                raise ValueError(f"slot {s} outside topology [0, {n})")

    @property
    def n_procs(self) -> int:
        return len(self.slots)

    def host_of(self, proc: int) -> int:
        return self.slots[proc] // self.topology.devices_per_host

    def tier(self, src: int, dst: int) -> str:
        return "intra" if self.host_of(src) == self.host_of(dst) else "inter"


# ---------------------------------------------------------------------------
# group structure of the framework schedules (mirrors core/framework.py)
# ---------------------------------------------------------------------------

def _grid(spec) -> tuple[int, list[list[int]], list[list[int]]]:
    """(M, a2a_groups, broadcast_rows) for a framework spec, deduplicated
    exactly as `decentralized_encode` builds them (borrowed processors
    appear once)."""
    K, R = spec.K, spec.R
    if K >= R:
        M = math.ceil(K / R)

        def pos_proc(r: int, m: int) -> int:
            k = r + m * R
            return k if k < K else K + r

        groups = [[pos_proc(r, m) for r in range(R)] for m in range(M)]
        rows = []
        for r in range(R):
            row = [pos_proc(r, m) for m in range(M)]
            sink = K + r
            rows.append([sink] + [q for q in row if q != sink])
        return M, groups, rows

    M = math.ceil(R / K)

    def pos_proc(k: int, m: int) -> int:
        r = k + m * K
        return K + r if r < R else k

    groups = [[pos_proc(k, m) for k in range(K)] for m in range(M)]
    rows = [[k] + [pos_proc(k, m) for m in range(M) if pos_proc(k, m) != k]
            for k in range(K)]
    return M, groups, rows


def encode_groups(spec) -> list[list[int]]:
    """The A2A groups of the framework schedule (phase 1 for K >= R,
    phase 2 for K < R) — the heavy-traffic lists the affinity policy packs
    one-per-host.  Empty for dft (identity placement already keeps every
    stage with stride < devices_per_host host-local)."""
    if spec.kind == "dft":
        return []
    return _grid(spec)[1]


# ---------------------------------------------------------------------------
# placement policies
# ---------------------------------------------------------------------------

def n_procs(spec) -> int:
    """Processors a placement must cover: N = K + R for the framework
    schedules; the dft transform runs in-place on the K sources only."""
    return spec.K if spec.kind == "dft" else spec.K + spec.R


def place(spec, topology: Topology, policy: str = "affinity") -> Placement:
    """Place the spec's processors (see `n_procs`) on the topology."""
    N = n_procs(spec)
    if topology.n_slots < N:
        raise ValueError(
            f"topology has {topology.n_slots} slots < N={N} processors")
    hosts, dph = topology.hosts, topology.devices_per_host
    if policy == "flat":
        # round-robin over hosts, filling device position i // hosts
        slots = tuple((i % hosts) * dph + (i // hosts) for i in range(N))
        return Placement(topology, slots, "flat")
    if policy != "affinity":
        raise ValueError(f"unknown placement policy {policy!r} "
                         "(have 'flat', 'affinity')")
    if spec.kind == "dft":
        # identity keeps every stage with stride < devices_per_host intra
        return Placement(topology, tuple(range(N)), "affinity")
    free = [list(range(h * dph, (h + 1) * dph)) for h in range(hosts)]
    slot_of: dict[int, int] = {}
    for group in encode_groups(spec):
        members = [m for m in dict.fromkeys(group) if m not in slot_of]
        host = next((h for h in range(hosts)
                     if len(free[h]) >= len(members)), None)
        if host is None:
            continue  # group larger than any remaining host: leftover pass
        for m in members:
            slot_of[m] = free[host].pop(0)
    for m in (i for i in range(N) if i not in slot_of):
        # emptiest host first, so the sinks claim a free host when one exists
        host = max(range(hosts), key=lambda h: (len(free[h]), -h))
        slot_of[m] = free[host].pop(0)
    return Placement(topology, tuple(slot_of[i] for i in range(N)), "affinity")


# ---------------------------------------------------------------------------
# per-tier closed form
# ---------------------------------------------------------------------------

def _phase_tier(lists, placement: Placement, all_send: bool) -> str | None:
    """Tier of a lockstep phase over member `lists`.

    all_send=True (A2A phases): every member sends in every active round,
    so one non-co-hosted list makes the whole phase inter — always
    determined.  all_send=False (broadcast/reduce trees): only uniform
    all-intra or all-pairwise-inter placements are attributable; mixed
    returns None.  Returns "any" when no list carries traffic.
    """
    tiers = set()
    for members in lists:
        hs = [placement.host_of(m) for m in dict.fromkeys(members)]
        if len(hs) <= 1:
            continue  # singleton: no messages
        distinct = len(set(hs))
        tiers.add("intra" if distinct == 1
                  else "inter" if distinct == len(hs) else "mixed")
    if not tiers:
        return "any"
    if tiers == {"intra"}:
        return "intra"
    if all_send or tiers == {"inter"}:
        return "inter"
    return None


def tiered_encode_cost(spec, method: str, placement: Placement,
                       sgrs=None) -> TieredCost | None:
    """Exact per-tier split of the Table-I encode cost under a placement.

    Returns None when the placement is not uniform per phase (see module
    docstring); the per-tier sums always equal the flat model's totals
    whenever a split is returned.  C2 is scaled by spec.W, matching
    `method_costs` / the measured `RoundNetwork` counters.
    """
    if placement.n_procs < n_procs(spec):
        raise ValueError(
            f"placement covers {placement.n_procs} processors, "
            f"need {n_procs(spec)}")
    W = spec.W
    parts = {"intra": LinearCost(0, 0), "inter": LinearCost(0, 0)}

    def add(tier: str | None, part: LinearCost) -> bool:
        if tier is None:
            return False
        parts["intra" if tier == "any" else tier] += part
        return True

    if spec.kind == "dft":
        K, P = spec.K, spec.P
        H = 0
        while P ** H < K:
            H += 1
        c1, c2 = cost_universal(P, spec.p)
        stage = LinearCost(c1, c2 * W)
        for h in range(H):
            groups = _stage_groups(K, P, H, h)
            add(_phase_tier(groups, placement, all_send=True), stage)
        return TieredCost(parts["intra"], parts["inter"])

    M, groups, rows = _grid(spec)
    if method == "rs":
        if sgrs is None:
            from ..core.cauchy import StructuredGRS

            sgrs = StructuredGRS.build(spec.field, spec.K, spec.R, P=spec.P,
                                       lagrange=spec.kind == "lagrange")
        c1, c2 = cost_cauchy(sgrs, 0, spec.p)
    else:
        c1, c2 = cost_universal(min(spec.K, spec.R), spec.p)
    a2a_part = LinearCost(c1, c2 * W)
    t_br, c2_br = cost_broadcast(M + 1, spec.p, W)
    br_part = LinearCost(t_br, c2_br)

    ok = add(_phase_tier(groups, placement, all_send=True), a2a_part)
    ok = ok and add(_phase_tier(rows, placement, all_send=False), br_part)
    if not ok:
        return None
    return TieredCost(parts["intra"], parts["inter"])
