"""Hierarchical topology subsystem: tiered links, placement, per-tier costs.

Production fleets are not the paper's uniform p-port clique — they are
hierarchical: fast intra-host links (NVLink/ICI-class) and slow inter-host
links (DCN-class).  This package models that as a two-tier refinement of
the paper's linear cost model, *without touching the schedules*:

    Topology(hosts, devices_per_host) — the machine shape
    TieredLinkModel                   — alpha/beta per tier (Table I, twice)
    Placement / place(spec, topo, policy) — processors -> (host, device)
        slots; "affinity" packs each prepare-and-shoot group onto one host,
        "flat" is the topology-oblivious round-robin strawman
    tiered_encode_cost(...)           — per-tier (C1, C2) closed form,
        asserted bit-for-bit against the simulator's per-tier accounting

The schedules themselves are placement-independent (Remark 1: scheduling
is data-independent, and a placement only relabels which physical link a
message crosses), so outputs are bitwise identical under ANY placement —
only the tier attribution of each round changes.  The `RoundNetwork`
measures that attribution exactly; the drift ledger checks it against
`tiered_encode_cost` whenever the closed form applies.
"""
from .model import TieredCost, TieredLinkModel, Topology
from .placement import (Placement, encode_groups, n_procs, place,
                        tiered_encode_cost)

__all__ = [
    "Topology", "TieredLinkModel", "TieredCost",
    "Placement", "place", "encode_groups", "n_procs", "tiered_encode_cost",
]
