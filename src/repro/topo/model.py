"""Machine shape and two-tier link-cost model.

`Topology` is the physical shape — `hosts` machines with
`devices_per_host` devices each, slot `s` living on host
`s // devices_per_host` (host-major order, matching the hierarchical
mesh backend's device grid).

`TieredLinkModel` prices the paper's (C1, C2) pair once per tier: a
round crossing hosts pays the inter-tier alpha/beta, a host-local round
pays the intra pair.  `TieredCost` carries the per-tier split; its
`total` collapses back to the flat `LinearCost` sum so single-tier
`LinkModel.us` keeps working on it unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass

from ..core.cost_model import LinearCost

# Table-I-style defaults, mirrored from api.planner (duplicated here on
# purpose: topo must not import api, or the import cycle closes).
ALPHA_DEFAULT = 1e-5
BETA_BITS_DEFAULT = 17e-9


@dataclass(frozen=True)
class Topology:
    """A two-level machine: `hosts` x `devices_per_host` slots."""

    hosts: int
    devices_per_host: int

    def __post_init__(self):
        if self.hosts < 1 or self.devices_per_host < 1:
            raise ValueError(
                f"Topology needs hosts >= 1 and devices_per_host >= 1, "
                f"got ({self.hosts}, {self.devices_per_host})")

    @property
    def n_slots(self) -> int:
        return self.hosts * self.devices_per_host

    def host_of(self, slot: int) -> int:
        if not 0 <= slot < self.n_slots:
            raise ValueError(f"slot {slot} outside [0, {self.n_slots})")
        return slot // self.devices_per_host


@dataclass(frozen=True)
class TieredCost:
    """Per-tier (C1, C2): `intra` host-local rounds, `inter` crossing ones."""

    intra: LinearCost
    inter: LinearCost

    @property
    def flat(self) -> LinearCost:
        return self.intra + self.inter

    def total(self, alpha: float, beta_bits: float, width_elems: int = 1):
        """Collapse to the single-tier cost — lets plain LinkModel price it."""
        return self.flat.total(alpha, beta_bits, width_elems)


@dataclass(frozen=True)
class TieredLinkModel:
    """Per-tier latency/inverse-bandwidth, Table-I style twice over."""

    alpha_intra: float = ALPHA_DEFAULT
    beta_bits_intra: float = BETA_BITS_DEFAULT
    alpha_inter: float = ALPHA_DEFAULT
    beta_bits_inter: float = BETA_BITS_DEFAULT

    def __post_init__(self):
        for name in ("alpha_intra", "beta_bits_intra",
                     "alpha_inter", "beta_bits_inter"):
            if getattr(self, name) < 0:
                raise ValueError(
                    f"TieredLinkModel.{name} must be >= 0, "
                    f"got {getattr(self, name)!r}")

    @classmethod
    def from_ratio(cls, ratio: float, *, alpha: float = ALPHA_DEFAULT,
                   beta_bits: float = BETA_BITS_DEFAULT) -> "TieredLinkModel":
        """Inter tier `ratio` times more expensive than the intra base."""
        if ratio < 1:
            raise ValueError(f"inter/intra ratio must be >= 1, got {ratio!r}")
        return cls(alpha_intra=alpha, beta_bits_intra=beta_bits,
                   alpha_inter=alpha * ratio, beta_bits_inter=beta_bits * ratio)

    def us(self, cost) -> float:
        """Model time in microseconds for a TieredCost, LinearCost or RunStats.

        Flat inputs carry no tier split, so they are priced conservatively
        at the inter tier (every round may cross hosts).
        """
        if isinstance(cost, TieredCost):
            return (cost.intra.total(self.alpha_intra, self.beta_bits_intra)
                    + cost.inter.total(self.alpha_inter, self.beta_bits_inter)
                    ) * 1e6
        # RunStats and LinearCost both expose .total(alpha, beta_bits)
        return cost.total(self.alpha_inter, self.beta_bits_inter) * 1e6
