"""Assigned architecture registry: one module per arch, `CONFIG` in each."""
from __future__ import annotations

from importlib import import_module

from ..models.config import SHAPES, ArchConfig, ShapeConfig

ARCH_IDS = [
    "llava_next_mistral_7b",
    "qwen3_14b",
    "qwen3_1_7b",
    "minicpm_2b",
    "qwen1_5_32b",
    "whisper_large_v3",
    "kimi_k2_1t_a32b",
    "phi3_5_moe_42b_a6_6b",
    "hymba_1_5b",
    "mamba2_780m",
    "paper_rs",  # the paper's own "architecture": RS-coded storage encode
]


def get_config(arch: str) -> ArchConfig:
    arch = arch.replace("-", "_").replace(".", "_")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch}; available: {ARCH_IDS}")
    return import_module(f"repro.configs.{arch}").CONFIG


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS if a != "paper_rs"}


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cell_applicable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch x shape) cell runs; reason if skipped (DESIGN.md §5)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k needs sub-quadratic attention (skip: full-attention arch)"
    return True, ""
