"""minicpm-2b [dense]: 40L d_model=2304 36H (MHA kv=36) d_ff=5760
vocab=122753 — WSD schedule, depth-scaled residuals (mup-style)
[arXiv:2404.06395; hf]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab=122753,
    head_dim=64,
    rope_theta=1e4,
    tie_embeddings=True,
    scale_depth=1.4,
    scale_emb=12.0,
    logit_scale=9.0,  # d_model / 256
)
