"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attention + mamba heads per layer,
sliding-window attention (3 global layers approximated by a uniform window
inside the stacked layer scan; DESIGN.md §5). [arXiv:2411.13676; hf]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    head_dim=64,
    rope_theta=1e4,
    ssm_state=16,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=64,
    sliding_window=1024,
)
