"""llava-next-mistral-7b [vlm]: Mistral-7B backbone + anyres vision stub.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
The vision tower is a STUB: input_specs() provides precomputed anyres patch
embeddings (B, 2880, d_model) prepended to the text sequence.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    head_dim=128,
    rope_theta=1e6,
    n_patches=2880,  # anyres 2x2 grid + base: 5 * 576
)
