"""The paper's own workload: systematic Reed-Solomon decentralized encoding
of storage shards across the data axis (Secs. III + VI). Used by the
coded-checkpoint feature and the paper-table benchmarks; parameters here set
the default (N devices -> R parity) code."""
from dataclasses import dataclass


@dataclass(frozen=True)
class PaperRSConfig:
    name: str = "paper-rs"
    R_fraction: float = 0.25     # parity overhead (R = N/4)
    p_ports: int = 1
    method: str = "rs"           # 'rs' (Thm. 7) or 'universal' (Sec. IV)
    shard_bytes: int = 1 << 20   # per-device state shard size to encode


CONFIG = PaperRSConfig()
