"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) d_ff=2048/expert,
MoE 384 experts top-8, vocab=163840 — trillion-param MoE (paper-table).
[arXiv:2501.kimi2; unverified]

Large-scale choice (DESIGN.md §5): Adam fp32 states (8 B/param = 8 TB) exceed
512 x 16 GB v5e HBM; kimi trains with Adafactor (factored second moment) and
fully-sharded bf16 params (FSDP over data x pod, expert-parallel over model).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab=163840,
    head_dim=112,
    rope_theta=5e4,
    n_experts=384,
    top_k=8,
    capacity_factor=1.0,
    n_shared_experts=1,
    optimizer="adafactor",
)
