"""whisper-large-v3 [audio]: enc-dec, 32 encoder + 32 decoder layers,
d_model=1280 20H (MHA) d_ff=5120 vocab=51866 — conv/mel frontend is a STUB:
input_specs() provides precomputed frame embeddings (B, 1500, d_model).
[arXiv:2212.04356; unverified]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,
    n_enc_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    head_dim=64,
    act="gelu",
    n_frames=1500,
)
