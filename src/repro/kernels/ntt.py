"""Pallas TPU kernel: batched radix-2 NTT over F_65537 (the paper's DFT
layer, Sec. V-A, as an on-chip kernel).

Computes, for each of W independent columns, the K-point NTT in
decimation-in-frequency order — the output at position k is X[rev(k)],
which is exactly the paper's *permuted* DFT matrix D_K·Pi (the algorithm of
Sec. V-A produces the same permutation; validated in tests against
`permuted_dft_matrix`).  Used as the local fast-encode path: a W-symbol
payload column is one lane, so a (K, W) tile is transformed in
O(K log K · W) field ops instead of the O(K^2 · W) matmul.

VMEM layout: one (K, bw) tile resident across all log2(K) stages
(K <= 4096, bw = 128 -> 2 MiB uint32); twiddles (H, K/2) ride along.
All arithmetic is the uint32 Fermat-prime path — no 64-bit, TPU-native.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.field import FERMAT, FERMAT_Q
from .gf_matmul import _fermat_add_u32, _fermat_mul_u32


def ntt_twiddles(K: int, inverse: bool = False) -> np.ndarray:
    """(H, K/2) twiddle table for DIF stage h: w_h[j] = root^(j * 2^h)."""
    H = int(math.log2(K))
    assert 2**H == K and (FERMAT_Q - 1) % K == 0
    root = FERMAT.root_of_unity(K)
    if inverse:
        root = pow(root, FERMAT_Q - 2, FERMAT_Q)
    tw = np.zeros((H, K // 2), np.uint32)
    for h in range(H):
        stride = 2**h
        for j in range(K // 2):
            tw[h, j] = pow(root, (j % (K // (2 * stride))) * stride, FERMAT_Q)
    return tw


def _fermat_sub_u32(a, b):
    return jnp.where(a >= b, a - b, a + jnp.uint32(FERMAT_Q) - b)


def _ntt_stages(x, tw, *, K: int, inverse: bool):
    """DIF butterflies forward; stage-wise inverse (DIT form, inverse
    twiddles, reversed stage order) for the inverse transform.

    Shared by the Pallas kernel body and the fused-XLA path (`ntt_xla`):
    all arithmetic is exact uint32 mod-q, so the two are bitwise-equal.
    x: (K, bw) uint32 values; tw: (H, K/2) uint32 twiddles.
    """
    H = int(math.log2(K))
    stages = range(H - 1, -1, -1) if inverse else range(H)
    for h in stages:
        half = K >> (h + 1)
        groups = K // (2 * half)
        xr = x.reshape(groups, 2 * half, -1)
        u = xr[:, :half]
        v = xr[:, half:]
        twr = tw[h, :].reshape(groups, half)[:, :, None]
        if inverse:
            # inverse of the DIF stage: u' = a + b*w^-1, v' = a - b*w^-1
            # (the 1/2-per-stage factors fold into the final K^-1 scale)
            bw_ = _fermat_mul_u32(v, twr)
            s = _fermat_add_u32(u, bw_)
            d = _fermat_sub_u32(u, bw_)
        else:
            # DIF: u' = u + v, v' = (u - v) * w
            s = _fermat_add_u32(u, v)
            d = _fermat_mul_u32(_fermat_sub_u32(u, v), twr)
        x = jnp.concatenate([s, d], axis=1).reshape(K, -1)
    return x


def _ntt_kernel(x_ref, tw_ref, o_ref, *, K: int, inverse: bool):
    o_ref[...] = _ntt_stages(x_ref[...].astype(jnp.uint32), tw_ref[...],
                             K=K, inverse=inverse)


@functools.partial(jax.jit, static_argnames=("inverse", "bw", "interpret"))
def ntt(x: jnp.ndarray, *, inverse: bool = False, bw: int = 128,
        interpret: bool = True) -> jnp.ndarray:
    """Batched NTT along axis 0: x (K, W) uint32 in [0, q).

    Forward: out[k] = sum_j x[j] * beta^(j * rev(k))   (== x @ D_K Pi).
    Inverse: exact inverse of forward (includes the 1/K scaling).
    """
    x = x.astype(jnp.uint32)
    K, W = x.shape
    H = int(math.log2(K))
    assert 2**H == K, "K must be a power of two"
    tw = jnp.asarray(ntt_twiddles(K, inverse=inverse))

    pad = (-W) % bw
    xp = jnp.pad(x, ((0, 0), (0, pad))) if pad else x
    Wp = xp.shape[1]
    out = pl.pallas_call(
        functools.partial(_ntt_kernel, K=K, inverse=inverse),
        grid=(Wp // bw,),
        in_specs=[
            pl.BlockSpec((K, bw), lambda w: (0, w)),
            pl.BlockSpec((H, K // 2), lambda w: (0, 0)),
        ],
        out_specs=pl.BlockSpec((K, bw), lambda w: (0, w)),
        out_shape=jax.ShapeDtypeStruct((K, Wp), jnp.uint32),
        interpret=interpret,
    )(xp, tw)
    out = out[:, :W]
    if inverse:
        kinv = jnp.uint32(pow(K, FERMAT_Q - 2, FERMAT_Q))
        out = _fermat_mul_u32(out, kinv)
    return out


@functools.partial(jax.jit, static_argnames=("inverse",))
def ntt_xla(x: jnp.ndarray, *, inverse: bool = False) -> jnp.ndarray:
    """`ntt` as one fused XLA computation (no pallas_call, no grid).

    Bitwise-identical to the Pallas kernel (same `_ntt_stages` body, exact
    integer arithmetic) but without the per-grid-step interpreter overhead —
    on CPU this is the throughput path; on TPU the Pallas kernel with its
    explicit VMEM residency is preferred (see `ntt_auto`).
    """
    x = x.astype(jnp.uint32)
    K = x.shape[0]
    assert 2 ** int(math.log2(K)) == K, "K must be a power of two"
    tw = jnp.asarray(ntt_twiddles(K, inverse=inverse))
    out = _ntt_stages(x, tw, K=K, inverse=inverse)
    if inverse:
        kinv = jnp.uint32(pow(K, FERMAT_Q - 2, FERMAT_Q))
        out = _fermat_mul_u32(out, kinv)
    return out


def ntt_auto(x: jnp.ndarray, *, inverse: bool = False) -> jnp.ndarray:
    """Backend-appropriate NTT: the Pallas kernel on TPU (compiled, VMEM
    tiling), the fused-XLA path elsewhere.  Traceable under jit."""
    if jax.default_backend() == "tpu":
        return ntt(x, inverse=inverse, interpret=False)
    return ntt_xla(x, inverse=inverse)


def ntt_ref(x: jnp.ndarray, inverse: bool = False) -> np.ndarray:
    """Oracle: direct matmul against the (permuted) DFT matrix."""
    from repro.core.matrices import gauss_inverse, permuted_dft_matrix

    K = x.shape[0]
    D = permuted_dft_matrix(FERMAT, K, 2)
    if inverse:
        D = gauss_inverse(FERMAT, D)
    return (FERMAT.matmul(D.T, np.asarray(x, np.int64))).astype(np.uint32)
