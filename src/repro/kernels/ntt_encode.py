"""O(K log K) local encode via NTTs — the fast path behind the planner.

The dense local encode is `kernels.ops.encode_blocks` (an O(K^2 W) field
matmul).  Two spec families admit an exact O(K log K * W) route through the
radix-2 NTT kernel instead:

* kind="dft" (P = 2): the generator *is* the permuted DFT matrix D_K Pi,
  and `ntt` computes x^T (D_K Pi) directly (validated bitwise in tests).

* kind="rs"/"lagrange" from `StructuredGRS.build`: when every structured
  point set is a *single coset* of the Z-th roots of unity (Z = the small
  side of (K, R), a power of two), the Thm. 6/8 block factorization

      A_m = (V_{alpha,m} Phi_m)^-1 V_beta Psi_m

  turns into scaled NTTs.  With alpha block m = { c_m * zeta^rev(j) } and
  beta set { c_b * zeta^rev(j) }, the Vandermonde at the block is
  V = diag(c^i) (D_Z Pi), so

      y_m = Psi_m . NTT( e_m . INTT( Phi_m^-1 . x_m ) ),
      e_m[i] = (c_b / c_m)^i                       (the coset twist)

  and parity is sum_m y_m (case K >= R) or the concatenation over beta
  blocks (case K < R).  Total: O(K log Z) field ops per payload column
  vs O(K * R) for the matmul.

Everything is exact integer arithmetic mod q, so the fast path is bitwise
identical to `encode_blocks` with `A_direct()` — the planner can switch
freely (`EncodePlan.local_impl`).  Applicability is structural
(`NTTEncodeParams.build` returns None when it does not hold), which in
practice means: min(K, R) is a power of two >= 2 dividing q - 1, P == 2,
and q is the Fermat prime.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core.field import FERMAT_Q, fermat_mul, fermat_reduce
from .ntt import ntt_auto


def _pow_vec(base: int, n: int, q: int) -> np.ndarray:
    """[base^0, base^1, ..., base^(n-1)] mod q."""
    out = np.empty(n, np.int64)
    acc = 1
    for i in range(n):
        out[i] = acc
        acc = acc * base % q
    return out


def _single_coset(sp) -> bool:
    """One alpha row (M == 1), radix-2, nontrivial transform size."""
    return sp.M == 1 and sp.P == 2 and sp.Z >= 2


@dataclass(frozen=True)
class NTTEncodeParams:
    """Host-side constants of the NTT fast path (cached on HostTables).

    kind="dft": the transform is one forward NTT; every other field unused.
    kind="grs": Z is the block transform size (min(K, R)), M the block
    count; phi_inv/psi/twist are (M, Z) per-block scale vectors and
    `case_kge` selects the K >= R (sum over blocks) vs K < R (concatenate
    over beta blocks) combination rule.
    """

    kind: str                       # "dft" | "grs"
    K: int
    R: int
    Z: int = 0
    M: int = 1
    case_kge: bool = True
    phi_inv: np.ndarray | None = None   # (M, Z) int64
    psi: np.ndarray | None = None       # (M, Z) int64
    twist: np.ndarray | None = None     # (M, Z) int64  e_m[i] = (c_b/c_m)^i

    @staticmethod
    def build(spec, sgrs) -> "NTTEncodeParams | None":
        """Params for the spec's local fast path, or None if inapplicable."""
        if spec.q != FERMAT_Q:
            return None
        if spec.kind == "dft":
            if spec.P != 2 or spec.K < 2:
                return None
            return NTTEncodeParams("dft", spec.K, spec.R)
        if sgrs is None:
            return None
        f = sgrs.field
        g = f.generator
        blocks = sgrs.alpha_blocks + sgrs.beta_blocks
        if not all(_single_coset(sp) for sp in blocks):
            return None
        K, R = sgrs.K, sgrs.R
        Z = min(K, R)
        if any(sp.Z != Z for sp in blocks):
            return None
        case_kge = K >= R
        M = max(K, R) // Z
        phi_inv = np.empty((M, Z), np.int64)
        psi = np.empty((M, Z), np.int64)
        twist = np.empty((M, Z), np.int64)
        if case_kge:
            c_beta = pow(g, sgrs.beta_blocks[0].phi[0], f.q)
            for m, ab in enumerate(sgrs.alpha_blocks):
                p_m, s_m = sgrs.scaling_factors(m)
                phi_inv[m], psi[m] = f.inv(p_m), s_m
                c_m = pow(g, ab.phi[0], f.q)
                twist[m] = _pow_vec(int(f.mul(c_beta, f.inv(np.int64(c_m)))),
                                    Z, f.q)
        else:
            c_alpha = pow(g, sgrs.alpha_blocks[0].phi[0], f.q)
            for m, bb in enumerate(sgrs.beta_blocks):
                p_m, s_m = sgrs.scaling_factors(m)
                phi_inv[m], psi[m] = f.inv(p_m), s_m
                c_b = pow(g, bb.phi[0], f.q)
                twist[m] = _pow_vec(int(f.mul(c_b, f.inv(np.int64(c_alpha)))),
                                    Z, f.q)
        return NTTEncodeParams("grs", K, R, Z, M, case_kge,
                               phi_inv, psi, twist)


def ntt_encode(x: jnp.ndarray, params: NTTEncodeParams) -> jnp.ndarray:
    """Encode payload x (K, W) uint32 -> sink values (R, W) uint32.

    Bitwise-equal to `encode_blocks(x, A_direct())`; traceable under jit
    (all per-spec constants fold in as literals).
    """
    x = x.astype(jnp.uint32)
    if params.kind == "dft":
        return ntt_auto(x)
    Z, M, W = params.Z, params.M, x.shape[1]
    phi_inv = jnp.asarray(params.phi_inv.T, jnp.uint32)[:, :, None]  # (Z,M,1)
    psi = jnp.asarray(params.psi.T, jnp.uint32)[:, :, None]
    twist = jnp.asarray(params.twist.T, jnp.uint32)[:, :, None]
    if params.case_kge:
        # blocks side by side in one batched transform: (Z, M*W) columns
        xb = x.reshape(M, Z, W).transpose(1, 0, 2)                  # (Z, M, W)
        xb = fermat_mul(phi_inv, xb)
        t = ntt_auto(xb.reshape(Z, M * W), inverse=True).reshape(Z, M, W)
        t = fermat_mul(twist, t)
        y = ntt_auto(t.reshape(Z, M * W)).reshape(Z, M, W)
        y = fermat_mul(psi, y)
        # sum_m y_m: addends < q, M < 2^15 => uint32 accumulation is exact
        return fermat_reduce(jnp.sum(y, axis=1, dtype=jnp.uint32))
    # K < R: one interpolation, M twisted evaluations (beta blocks)
    t0 = ntt_auto(fermat_mul(phi_inv[:, 0], x), inverse=True)       # (Z=K, W)
    tb = fermat_mul(twist, t0[:, None, :])                          # (K, M, W)
    y = ntt_auto(tb.reshape(Z, M * W)).reshape(Z, M, W)
    y = fermat_mul(psi, y)
    return y.transpose(1, 0, 2).reshape(params.R, W)
