"""Pallas TPU kernel: blocked matmul over F_65537 — the encode hot-spot.

The local-encode step of every all-to-all encode algorithm (initializing the
shoot-phase packets w_{k,s}, eq. before Remark 6, and the direct fallback
x @ A) is a matrix product over the field. This kernel tiles it for VMEM:

  grid = (M/bm, N/bn, K/bk), K innermost so each (i, j) output tile stays
  resident in VMEM across the K-reduction (revisiting semantics).

Overflow proof (all uint32, no 64-bit — TPU-native):
  * inputs are in [0, q) with q = 2^16 + 1
  * each product is Fermat-reduced *before* accumulation (fermat_mul), so
    every addend is < q <= 2^16 + 1
  * the per-k-step partial sum accumulates bk <= 2^14 addends:
    2^14 * (2^16) < 2^31  — no uint32 wrap, then one fermat_reduce
  * the running output tile is kept reduced (< q) via modular add.

dtype note: TPU Pallas prefers >=2D int32/uint32 tiles with last dim 128; we
use (bm, bk) x (bk, bn) tiles with bm = bn = 128 by default and bk <= 16384
(VMEM: the (bm, bk, bn) broadcast product is materialized per k-slice of 8,
see inner loop — working set ~ (128*8*128)*4B = 512 KiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.field import FERMAT_Q


def _fermat_reduce_u32(x):
    lo = x & jnp.uint32(0xFFFF)
    hi = x >> jnp.uint32(16)
    r = lo + jnp.uint32(FERMAT_Q) - hi
    return jnp.where(r >= jnp.uint32(FERMAT_Q), r - jnp.uint32(FERMAT_Q), r)


def _fermat_mul_u32(a, b):
    safe_a = jnp.where(a == jnp.uint32(65536), jnp.uint32(0), a)
    prod = _fermat_reduce_u32(safe_a * b)
    neg_b = jnp.where(b == jnp.uint32(0), jnp.uint32(0), jnp.uint32(FERMAT_Q) - b)
    return jnp.where(a == jnp.uint32(65536), neg_b, prod)


def _fermat_add_u32(a, b):
    s = a + b
    return jnp.where(s >= jnp.uint32(FERMAT_Q), s - jnp.uint32(FERMAT_Q), s)


def _gf_matmul_kernel(a_ref, b_ref, o_ref, *, bk_inner: int):
    """One (bm, bn) output tile; grid axis 2 sweeps the K reduction."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]  # (bm, bk) uint32
    b = b_ref[...]  # (bk, bn) uint32
    bk = a.shape[1]
    acc = o_ref[...]
    # inner loop over bk in slices of bk_inner to bound the 3D broadcast
    for s in range(0, bk, bk_inner):
        a_sl = a[:, s : s + bk_inner]           # (bm, ki)
        b_sl = b[s : s + bk_inner, :]           # (ki, bn)
        prods = _fermat_mul_u32(a_sl[:, :, None], b_sl[None, :, :])
        # every addend < q <= 2^16+1; ki <= 2^14 => sum < 2^31: no wrap
        part = jnp.sum(prods, axis=1, dtype=jnp.uint32)
        acc = _fermat_add_u32(acc, _fermat_reduce_u32(part))
    o_ref[...] = acc


def _pad_to(x, mult0, mult1):
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "bk_inner", "interpret")
)
def gf_matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    bk_inner: int = 8,
    interpret: bool = True,
) -> jnp.ndarray:
    """(a @ b) mod 65537 with explicit VMEM tiling.

    a: (M, K), b: (K, N), any uint32-compatible dtype with values in [0, q).
    interpret=True executes the kernel body in Python on CPU (this container
    is CPU-only; TPU is the lowering target).
    """
    a = a.astype(jnp.uint32)
    b = b.astype(jnp.uint32)
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    assert bk <= 16384, "accumulation overflow guard (see module docstring)"
    ap = _pad_to(a, bm, bk)
    bp = _pad_to(b, bk, bn)
    Mp, Kp = ap.shape
    _, Np = bp.shape
    grid = (Mp // bm, Np // bn, Kp // bk)
    out = pl.pallas_call(
        functools.partial(_gf_matmul_kernel, bk_inner=bk_inner),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.uint32),
        interpret=interpret,
    )(ap, bp)
    return out[:M, :N]
