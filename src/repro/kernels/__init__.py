from . import ops
from .gf_matmul import gf_matmul
from .gf_solve import gf_gauss_inverse, gf_solve
from .ntt import ntt, ntt_auto, ntt_xla
from .ntt_encode import NTTEncodeParams, ntt_encode
from .ref import gf_matmul_ref

__all__ = ["gf_matmul", "gf_gauss_inverse", "gf_solve", "gf_matmul_ref",
           "ntt", "ntt_auto", "ntt_xla", "NTTEncodeParams", "ntt_encode",
           "ops"]
