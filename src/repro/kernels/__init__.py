from .gf_matmul import gf_matmul
from .gf_solve import gf_gauss_inverse, gf_solve
from .ref import gf_matmul_ref
from . import ops

__all__ = ["gf_matmul", "gf_gauss_inverse", "gf_solve", "gf_matmul_ref", "ops"]
