from .gf_matmul import gf_matmul
from .ref import gf_matmul_ref
from . import ops

__all__ = ["gf_matmul", "gf_matmul_ref", "ops"]
