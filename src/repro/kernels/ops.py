"""Jitted public wrappers around the Pallas kernels.

`encode_blocks` is the entry point used by the coded-checkpoint and
shard_map layers: it picks the Pallas kernel for large operands and the
pure-jnp reference for small ones (kernel launch overhead dominates below
~128x128), keeping one call site for the encode hot-spot.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .gf_matmul import gf_matmul
from .ref import gf_matmul_ref

_PALLAS_MIN_DIM = 128


def encode_blocks(x: jnp.ndarray, coeffs: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """y = x^T-style field encode: (S, W) data against (S, T) coefficients.

    Returns (T, W) = coeffs.T @ x over F_65537.
    """
    a = coeffs.T.astype(jnp.uint32)  # (T, S)
    b = x.astype(jnp.uint32)  # (S, W)
    if min(a.shape + b.shape) >= _PALLAS_MIN_DIM:
        return gf_matmul(a, b, interpret=interpret)
    return gf_matmul_ref(a, b)


def decode_blocks(v: jnp.ndarray, dmat: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """Apply a precomputed decode matrix to survivor payloads.

    v: (K, W) survivor symbols, dmat: (K, E) — returns (E, W) = dmat.T @ v
    over F_65537.  The exact dual of `encode_blocks`: decode of an erasure
    pattern is an encode with the repair matrix D = S^-1 G[:, E] (S the
    survivor submatrix), so the same Pallas/jnp kernel serves both hot
    paths; `kernels.gf_solve` builds D's ingredients.
    """
    return encode_blocks(v, dmat, interpret=interpret)


@jax.jit
def field_matmul_small(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return gf_matmul_ref(a.astype(jnp.uint32), b.astype(jnp.uint32))
