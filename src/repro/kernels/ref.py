"""Pure-jnp oracles for the Pallas kernels (exact, uint32, CPU/TPU safe)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.field import fermat_add, fermat_mul, fermat_reduce


def gf_matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(a @ b) mod 65537, exact, no 64-bit: reduce each product, chunked sums.

    a: (M, K) uint32 in [0, q); b: (K, N) uint32 in [0, q).
    Accumulates reduced products (each < 2^17) in uint32 chunks of <= 2^15
    terms (2^15 * 2^17 = 2^32 boundary-safe since products < q <= 2^16+1:
    32768 * 65536 < 2^31 * 2... we use 16384-chunks for a clean margin).
    """
    a = a.astype(jnp.uint32)
    b = b.astype(jnp.uint32)
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    chunk = 16384
    out = jnp.zeros((M, N), jnp.uint32)
    for s in range(0, K, chunk):
        e = min(K, s + chunk)
        prods = fermat_mul(a[:, s:e, None], b[None, s:e, :])  # (M, c, N) < q
        out = fermat_add(out, fermat_reduce(jnp.sum(prods, axis=1, dtype=jnp.uint32)))
    return out


def gf_axpy_ref(coef: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """y + coef * x (mod q), elementwise with broadcast."""
    return fermat_add(y, fermat_mul(coef, x))
