"""Batched GF(65537) solve — the decode/repair hot-spot.

Erasure decode of a systematic code [I | A] is a two-step computation:

  1. invert the K x K survivor submatrix  S = G[:, kept]   (once per
     erasure pattern — Cauchy/Vandermonde-structured for RS/Lagrange codes,
     arbitrary for universal ones), and
  2. apply it to the (K, W) survivor payloads, W up to millions of symbols:
     x = (S^T)^-1 v, and lost symbols y_E = (S^-1 G[:, E])^T v.

Step 2 is a field matmul and runs on the same Pallas `gf_matmul` kernel as
the encode path (VMEM-tiled, uint32-only — see `gf_matmul.py` for the
overflow proof); step 1 is an exact Gauss-Jordan elimination over F_65537
implemented here directly on the jnp uint32 path (no int64 anywhere, so the
same code lowers on TPU), with the numpy `core.matrices.gauss_inverse` as
its host oracle.  The inverse of a nonsingular matrix is unique, so both
paths are bitwise identical.

Sequentiality note: Gauss-Jordan is O(K) dependent pivot steps of O(K^2)
vectorized work — it stays on the eager jnp path (each step is one fused
VPU sweep) rather than a Pallas grid, because the K x K inverse is built
once per erasure pattern and cached by the decode planner; only the (K, W)
application is the per-payload hot path.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.field import FERMAT_Q, fermat_mul, fermat_sub

from .gf_matmul import gf_matmul
from .ref import gf_matmul_ref

_PALLAS_MIN_DIM = 128


def _as_field_u32(x) -> jnp.ndarray:
    """Reduce to [0, q) exactly, then cast uint32.

    The mod runs in numpy int64 *before* the uint32 cast — casting first
    would wrap negatives/large values (uint32(-1) % q == 0, but
    -1 mod q == q - 1), silently diverging from the numpy oracle.
    """
    return jnp.asarray(np.asarray(x, np.int64) % FERMAT_Q, jnp.uint32)


def _fermat_pow(x, e: int):
    """Scalar x**e mod 65537 by square-and-multiply (e a python int)."""
    acc = jnp.uint32(1)
    base = x.astype(jnp.uint32)
    while e:
        if e & 1:
            acc = fermat_mul(acc, base)
        base = fermat_mul(base, base)
        e >>= 1
    return acc


def gf_gauss_inverse(a: jnp.ndarray) -> jnp.ndarray:
    """Exact inverse of a (n, n) matrix over F_65537, pure uint32 jnp.

    Partial pivoting by first nonzero entry (same pivot order as the numpy
    oracle; the result is the unique inverse either way).  Raises
    ``ValueError`` on a singular input — for MDS codes every survivor
    submatrix is nonsingular, but e.g. the DFT transform's [I | A] codeword
    admits singular patterns (see `repro.recover.UndecodableError`).
    """
    a = _as_field_u32(a)
    n = a.shape[0]
    assert a.shape == (n, n), a.shape
    inv = jnp.eye(n, dtype=jnp.uint32)
    for col in range(n):
        nz = a[col:, col] != 0
        if not bool(jnp.any(nz)):
            raise ValueError(f"singular matrix over F_{FERMAT_Q} (column {col})")
        piv = col + int(jnp.argmax(nz))
        if piv != col:
            a = a.at[(col, piv), :].set(a[(piv, col), :])
            inv = inv.at[(col, piv), :].set(inv[(piv, col), :])
        s = _fermat_pow(a[col, col], FERMAT_Q - 2)
        a = a.at[col].set(fermat_mul(a[col], s))
        inv = inv.at[col].set(fermat_mul(inv[col], s))
        f = a[:, col].at[col].set(jnp.uint32(0))  # eliminate every other row
        a = fermat_sub(a, fermat_mul(f[:, None], a[col][None, :]))
        inv = fermat_sub(inv, fermat_mul(f[:, None], inv[col][None, :]))
    return inv


def gf_apply(a: jnp.ndarray, b: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """(a @ b) mod 65537 on the Pallas kernel for large operands, jnp ref
    below the tile threshold (kernel launch overhead dominates there)."""
    a = a.astype(jnp.uint32)
    b = b.astype(jnp.uint32)
    if min(a.shape + b.shape) >= _PALLAS_MIN_DIM:
        return gf_matmul(a, b, interpret=interpret)
    return gf_matmul_ref(a, b)


def gf_solve(a, b, *, interpret: bool = True) -> jnp.ndarray:
    """Solve a @ x = b over F_65537: x = a^-1 b, exact.

    a: (n, n), b: (n, W) — the decode use is a = S^T (survivor submatrix,
    transposed) and b the survivor payloads, giving the original data x.
    """
    return gf_apply(gf_gauss_inverse(a), _as_field_u32(b), interpret=interpret)
