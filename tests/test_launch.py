"""Launcher integration: dry-run cell in subprocess (512 devices), train
driver with failure injection, serve driver."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def _run(args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable] + args, capture_output=True,
                          text=True, env=env, timeout=timeout, cwd=REPO)


@pytest.mark.slow
def test_dryrun_single_cell_subprocess(tmp_path):
    proc = _run(["-m", "repro.launch.dryrun", "--arch", "qwen3_1_7b",
                 "--shape", "decode_32k", "--mesh", "single",
                 "--out-dir", str(tmp_path), "--force"])
    assert proc.returncode == 0, proc.stderr[-2000:]
    cell = json.loads((tmp_path / "qwen3_1_7b__decode_32k__single.json").read_text())
    assert "error" not in cell, cell.get("error")
    assert cell["n_devices"] == 256
    assert cell["hlo_flops_per_device"] > 0
    assert cell["roofline"]["dominant"] in ("compute_s", "memory_s", "collective_s")
    assert cell["memory"]["temp_bytes"] is not None


@pytest.mark.slow
def test_dryrun_multipod_cell_subprocess(tmp_path):
    proc = _run(["-m", "repro.launch.dryrun", "--arch", "mamba2_780m",
                 "--shape", "long_500k", "--mesh", "multi",
                 "--out-dir", str(tmp_path), "--force"])
    assert proc.returncode == 0, proc.stderr[-2000:]
    cell = json.loads((tmp_path / "mamba2_780m__long_500k__multi.json").read_text())
    assert "error" not in cell, cell.get("error")
    assert cell["n_devices"] == 512  # the pod axis sharded


@pytest.mark.slow
def test_train_launcher_failure_injection(tmp_path):
    proc = _run(["-m", "repro.launch.train", "--arch", "qwen3_1_7b",
                 "--steps", "25", "--ckpt-dir", str(tmp_path / "ck"),
                 "--ckpt-every", "10", "--fail-at", "12,1,3",
                 "--peak-lr", "5e-3", "--seq-len", "64", "--batch", "4"])
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "reconstructed from parity" in proc.stdout
    assert "done: final loss" in proc.stdout


@pytest.mark.slow
def test_serve_launcher():
    proc = _run(["-m", "repro.launch.serve", "--arch", "hymba_1_5b",
                 "--batch", "2", "--prompt-len", "8", "--gen-len", "8"])
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "generated 8 tokens/seq" in proc.stdout
