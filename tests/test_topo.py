"""Hierarchical topology subsystem: tiered links, placement policies,
exact per-tier accounting, topology-aware planning.

The mesh-backend half (hierarchical grid bitwise == flat mesh) runs in
the `topo_mesh_checks.py` subprocess on 8 forced host devices.
"""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest
from conftest_hypothesis import HAVE_HYPOTHESIS, given, settings, st

from repro.api import (CodedSystem, CodeSpec, Encoder, LinkModel, Placement,
                       RunStats, TieredCost, TieredLinkModel, Topology, place,
                       tiered_encode_cost)
from repro.core.cost_model import LinearCost
from repro.core.dft_a2a import dft_a2a
from repro.core.framework import decentralized_encode
from repro.core.simulator import RoundNetwork
from repro.obs.drift import LEDGER
from repro.topo import encode_groups, n_procs

REPO = Path(__file__).resolve().parent.parent
RNG = np.random.default_rng(29)

SPECS = [
    CodeSpec("universal", 4, 2, W=3, seed=5),
    CodeSpec("rs", 4, 2, W=3),
    CodeSpec("lagrange", 4, 2, W=3),
    CodeSpec("dft", 4, 4, W=3),
]


def _run_core(spec, placement):
    """One simulator encode of `spec` under `placement` via the core
    schedules (bypassing the plan cache so property tests don't pollute
    it); returns (y, net)."""
    plan = Encoder.plan(spec, backend="simulator")  # cached tables only
    f = spec.field
    x = f.rand((spec.K, spec.W), np.random.default_rng(13))
    if spec.kind == "dft":
        net = RoundNetwork(spec.K, spec.p, placement=placement)
        out = {}
        net.run(dft_a2a(f, {k: x[k] for k in range(spec.K)},
                        list(range(spec.K)), spec.p, spec.P, out))
        return np.stack([out[k] for k in range(spec.K)]), net
    net = RoundNetwork(spec.N, spec.p, placement=placement)
    method = "rs" if plan.method == "rs" else "universal"
    y, net = decentralized_encode(f, plan.A, x, p=spec.p, method=method,
                                  sgrs=plan.sgrs, net=net)
    return y, net


# ---------------------------------------------------------------------------
# model.py: Topology / TieredLinkModel / TieredCost
# ---------------------------------------------------------------------------

def test_topology_validation():
    with pytest.raises(ValueError):
        Topology(0, 4)
    with pytest.raises(ValueError):
        Topology(4, 0)
    t = Topology(3, 4)
    assert t.n_slots == 12
    assert [t.host_of(s) for s in (0, 3, 4, 11)] == [0, 0, 1, 2]
    with pytest.raises(ValueError):
        t.host_of(12)


def test_link_model_validation():
    with pytest.raises(ValueError):
        LinkModel(alpha=-1e-6)
    with pytest.raises(ValueError):
        LinkModel(beta_bits=-1.0)
    for bad in ({"alpha_intra": -1.0}, {"beta_bits_intra": -1.0},
                {"alpha_inter": -1.0}, {"beta_bits_inter": -1.0}):
        with pytest.raises(ValueError):
            TieredLinkModel(**bad)
    with pytest.raises(ValueError):
        TieredLinkModel.from_ratio(0.5)
    lm = TieredLinkModel.from_ratio(4.0)
    assert lm.alpha_inter == pytest.approx(4 * lm.alpha_intra)
    assert lm.beta_bits_inter == pytest.approx(4 * lm.beta_bits_intra)


def test_tiered_us_accepts_linear_cost_and_run_stats():
    """Satellite: TieredLinkModel.us prices LinearCost AND RunStats like
    the single-tier LinkModel, single-sourced through `.total` — flat
    inputs at the (conservative) inter tier."""
    lm = TieredLinkModel(alpha_intra=1e-6, beta_bits_intra=1e-9,
                         alpha_inter=5e-6, beta_bits_inter=5e-9)
    lc = LinearCost(3, 7)
    rs = RunStats(3, 7, backend="simulator", op="encode")
    want = lc.total(lm.alpha_inter, lm.beta_bits_inter) * 1e6
    assert lm.us(lc) == pytest.approx(want)
    assert lm.us(rs) == pytest.approx(want)
    tc = TieredCost(intra=LinearCost(2, 4), inter=LinearCost(1, 3))
    want_tc = (LinearCost(2, 4).total(lm.alpha_intra, lm.beta_bits_intra)
               + LinearCost(1, 3).total(lm.alpha_inter, lm.beta_bits_inter)
               ) * 1e6
    assert lm.us(tc) == pytest.approx(want_tc)
    # a TieredCost collapses to its flat sum under the single-tier model
    flat = LinkModel(alpha=2e-6, beta_bits=3e-9)
    assert flat.us(tc) == pytest.approx(flat.us(LinearCost(3, 7)))


# ---------------------------------------------------------------------------
# placement.py: policies
# ---------------------------------------------------------------------------

def test_placement_validation():
    t = Topology(2, 2)
    with pytest.raises(ValueError):
        Placement(t, (0, 0, 1))          # duplicate slots
    with pytest.raises(ValueError):
        Placement(t, (0, 1, 4))          # slot out of range
    spec = CodeSpec("rs", 16, 4)
    with pytest.raises(ValueError):
        place(spec, Topology(2, 2))      # 4 slots < 20 processors
    with pytest.raises(ValueError):
        place(spec, Topology(5, 4), "zigzag")


def test_flat_policy_is_round_robin():
    spec = CodeSpec("rs", 16, 4)
    pl = place(spec, Topology(5, 4), "flat")
    assert pl.policy == "flat"
    assert [pl.host_of(i) for i in range(20)] == [i % 5 for i in range(20)]


def test_affinity_packs_groups_per_host():
    """Each phase-one A2A group (size R = 4 = devices_per_host) lands on
    one host; the sinks get the leftover host to themselves."""
    spec = CodeSpec("rs", 16, 4)
    pl = place(spec, Topology(5, 4), "affinity")
    for group in encode_groups(spec):
        hosts = {pl.host_of(m) for m in group}
        assert len(hosts) == 1, (group, hosts)
    sink_hosts = {pl.host_of(16 + r) for r in range(4)}
    assert len(sink_hosts) == 1
    assert sink_hosts.isdisjoint({pl.host_of(k) for k in range(16)})


def test_affinity_without_a_fitting_host_still_places_everyone():
    # groups of 4 never fit devices_per_host=3: the leftover pass places
    # all processors anyway (and the closed form simply may not apply)
    spec = CodeSpec("rs", 8, 4)
    pl = place(spec, Topology(4, 3), "affinity")
    assert sorted(pl.slots) == sorted(range(12))


# ---------------------------------------------------------------------------
# exact per-tier accounting (simulator + closed form + drift ledger)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["affinity", "flat"])
@pytest.mark.parametrize("kind,K,R", [("universal", 16, 4), ("rs", 16, 4),
                                      ("lagrange", 16, 4), ("dft", 8, 8),
                                      ("universal", 3, 6)])
def test_per_tier_exact_and_zero_drift(kind, K, R, policy):
    spec = CodeSpec(kind, K, R, W=16,
                    **({"seed": 7} if kind == "universal" else {}))
    hosts = 5 if K >= R else 3
    dph = -(-n_procs(spec) // hosts)
    topo = Topology(hosts, dph)
    plan = Encoder.plan(spec, backend="simulator", topology=place(
        spec, topo, policy))
    x = spec.field.rand((K, 16), RNG)
    before = {(e.spec, e.detail): (e.exact, e.drifted)
              for e in LEDGER.entries()}
    y = plan.run(x)
    flat_plan = Encoder.plan(spec, backend="simulator")
    assert np.array_equal(y, flat_plan.run(x)), "placement changed outputs"
    net = plan.sim_net
    tiers = net.by_tier()
    # tiers partition the flat totals exactly
    assert tuple(sum(v[i] for v in tiers.values()) for i in (0, 1)) \
        == (net.C1, net.C2)
    tc = plan.tiered_cost()
    if tc is not None:
        assert tiers["intra"] == (tc.intra.C1, tc.intra.C2)
        assert tiers["inter"] == (tc.inter.C1, tc.inter.C2)
        detail = f"{plan.method}/tiers@{policy}"
        cell = [e for e in LEDGER.entries()
                if e.spec == spec and e.detail == detail]
        assert cell and cell[0].drifted == 0
        assert cell[0].exact > before.get((spec, detail), (0, 0))[0]
    assert not [e for e in LEDGER.drifted() if e.spec == spec]


def test_mixed_placement_has_no_closed_form_but_sums_hold():
    """Swapping a sink into a source column makes a reduce row partially
    co-hosted: the closed form declines (None) but the measured tier
    counters still partition C1/C2."""
    spec = CodeSpec("rs", 16, 4, W=8)
    slots = list(range(20))
    slots[3], slots[16] = slots[16], slots[3]
    pl = Placement(Topology(5, 4), tuple(slots))
    assert tiered_encode_cost(spec, "rs", pl) is None
    y, net = _run_core(spec, pl)
    tiers = net.by_tier()
    assert tuple(sum(v[i] for v in tiers.values()) for i in (0, 1)) \
        == (net.C1, net.C2)


def test_single_host_topology_is_all_intra():
    spec = CodeSpec("rs", 16, 4, W=4)
    pl = place(spec, Topology(1, 20), "affinity")
    tc = tiered_encode_cost(spec, "universal", pl)
    assert tc.inter == LinearCost(0, 0)
    y, net = _run_core(spec, pl)
    assert net.by_tier()["inter"] == (0, 0)


def test_round_network_rejects_short_placement():
    pl = place(CodeSpec("rs", 4, 2), Topology(2, 3))
    with pytest.raises(ValueError):
        RoundNetwork(8, 1, placement=pl)  # 8 procs > 6 placed


# ---------------------------------------------------------------------------
# planner / system threading
# ---------------------------------------------------------------------------

def test_plan_cache_keyed_by_topology():
    spec = CodeSpec("rs", 16, 4, W=8)
    base = Encoder.plan(spec, backend="simulator")
    topo = Topology(5, 4)
    topod = Encoder.plan(spec, backend="simulator", topology=topo)
    assert topod is not base
    assert topod.topology == topo and topod.placement is not None
    assert topod.placement.policy == "affinity"
    assert Encoder.plan(spec, backend="simulator",
                        topology=Topology(5, 4)) is topod
    # an explicit placement keys separately from the bare topology
    flat = Encoder.plan(spec, backend="simulator",
                        topology=place(spec, topo, "flat"))
    assert flat is not topod and flat.placement.policy == "flat"


def test_plan_rejects_undersized_topology_on_simulator():
    spec = CodeSpec("rs", 16, 4)
    with pytest.raises(ValueError, match="slots"):
        Encoder.plan(spec, backend="simulator", topology=Topology(2, 2))
    with pytest.raises(TypeError):
        Encoder.plan(spec, backend="simulator", topology="5x4")


def test_auto_selection_scores_by_tiered_cost():
    """method="auto" under a placement + TieredLinkModel must agree with
    the explicit argmin over the per-tier split (flat-cost fallback when
    the closed form declines)."""
    spec = CodeSpec("rs", 16, 4, W=256)
    pl = place(spec, Topology(5, 4), "affinity")
    for ratio in (1.0, 4.0, 16.0):
        link = TieredLinkModel.from_ratio(ratio)
        plan = Encoder.plan(spec, backend="simulator", topology=pl,
                            link=link)
        scores = {}
        for m in plan.costs:
            tc = tiered_encode_cost(spec, m, pl, sgrs=plan.sgrs)
            scores[m] = link.us(tc if tc is not None else plan.costs[m])
        assert plan.method == min(scores, key=lambda m: (
            scores[m], m == "universal"))


def test_auto_selection_uses_flat_link_without_placement():
    """A plain LinkModel (no topology) prices auto through `link.us`."""
    spec = CodeSpec("rs", 16, 4, W=64)
    for link in (LinkModel(alpha=1.0, beta_bits=1e-12),
                 LinkModel(alpha=1e-12, beta_bits=1.0)):
        plan = Encoder.plan(spec, backend="simulator", link=link)
        want = min(plan.costs, key=lambda m: (link.us(plan.costs[m]),
                                              m == "universal"))
        assert plan.method == want


def test_coded_system_tiers_in_stats_and_describe():
    spec = CodeSpec("rs", 16, 4, W=32)
    sys_ = CodedSystem(spec, "simulator", topology=Topology(5, 4),
                       link=TieredLinkModel.from_ratio(4))
    x = spec.field.rand((16, 32), RNG)
    sys_.encode(x)
    tiers = sys_.stats()["encode"]["tiers"]
    assert tiers["placement"] == "affinity"
    model = tiers["model"]
    assert tiers["measured"] == {
        "intra": (model["intra"].C1, model["intra"].C2),
        "inter": (model["inter"].C1, model["inter"].C2)}
    assert tiers["model_us"] > 0
    d = sys_.describe()
    assert "topo    : 5 hosts x 4 devices" in d and "tiers   :" in d
    assert "link    : intra" in d


def test_coded_system_rejects_undersized_topology_on_simulator():
    with pytest.raises(ValueError):
        CodedSystem(CodeSpec("rs", 16, 4), "simulator",
                    topology=Topology(2, 2))


def test_coded_system_flat_placement_policy():
    spec = CodeSpec("rs", 16, 4, W=8)
    sys_ = CodedSystem(spec, "simulator", topology=Topology(5, 4),
                       placement="flat")
    assert sys_.placement.policy == "flat"
    x = spec.field.rand((16, 8), RNG)
    sys_.encode(x)
    tiers = sys_.stats()["encode"]["tiers"]
    assert tiers["measured"]["intra"] == (0, 0)  # round-robin: all inter


# ---------------------------------------------------------------------------
# property test: placement invariance (satellite)
# ---------------------------------------------------------------------------

def _check_placement_invariance(spec, hosts, extra, perm):
    """(a) outputs are bitwise-identical under ANY placement, (b) the
    per-tier C1/C2 counters sum exactly to the flat totals, and (c) the
    closed form — whenever it applies — matches the measured split."""
    n = n_procs(spec)
    topo = Topology(hosts, -(-n // hosts) + extra)
    pl = Placement(topo, tuple(perm[:n]))

    y_flat, net_flat = _run_core(spec, None)
    y, net = _run_core(spec, pl)
    assert np.array_equal(y, y_flat)
    assert (net.C1, net.C2) == (net_flat.C1, net_flat.C2)
    tiers = net.by_tier()
    assert tuple(sum(v[i] for v in tiers.values()) for i in (0, 1)) \
        == (net.C1, net.C2)
    plan = Encoder.plan(spec, backend="simulator")
    method = plan.method if spec.kind != "dft" else "dft"
    tc = tiered_encode_cost(spec, method, pl, sgrs=plan.sgrs)
    if tc is not None:
        assert tiers["intra"] == (tc.intra.C1, tc.intra.C2)
        assert tiers["inter"] == (tc.inter.C1, tc.inter.C2)


if HAVE_HYPOTHESIS:
    @pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.kind)
    @given(data=st.data())
    @settings(max_examples=20, deadline=None)
    def test_placement_invariance(spec, data):
        n = n_procs(spec)
        hosts = data.draw(st.integers(min_value=1, max_value=4),
                          label="hosts")
        extra = data.draw(st.integers(min_value=0, max_value=3),
                          label="extra")
        n_slots = hosts * (-(-n // hosts) + extra)
        perm = data.draw(st.permutations(list(range(n_slots))),
                         label="slots")
        _check_placement_invariance(spec, hosts, extra, perm)
else:  # no hypothesis: a fixed-seed random sweep instead of a skip
    @pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.kind)
    def test_placement_invariance(spec):
        rng = np.random.default_rng(17)
        n = n_procs(spec)
        for _ in range(12):
            hosts = int(rng.integers(1, 5))
            extra = int(rng.integers(0, 4))
            n_slots = hosts * (-(-n // hosts) + extra)
            perm = rng.permutation(n_slots).tolist()
            _check_placement_invariance(spec, hosts, extra, perm)


# ---------------------------------------------------------------------------
# mesh subprocess companion
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_hierarchical_mesh_subprocess_8_devices():
    """Hierarchical (hosts x dph) mesh bitwise == flat mesh, all four
    kinds, on 8 forced host devices."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(REPO / "tests" / "topo_mesh_checks.py")],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "TOPO_MESH_CHECKS_OK" in proc.stdout
