"""Optimizers, schedules, data pipeline, coded checkpointing, gradient
coding, Lagrange coded computing."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest_hypothesis import given, settings, st

from repro.ckpt import CodedCheckpointer
from repro.coding import GradientCoder, LagrangeComputer
from repro.configs import get_config
from repro.core.field import FERMAT
from repro.data import SyntheticLM
from repro.optim import adafactor, adamw, cosine_schedule, wsd_schedule
from repro.train import init_state, make_train_setup, make_train_step

KEY = jax.random.PRNGKey(0)


# ---------------- optimizers ------------------------------------------------

def _quad_problem():
    params = {"w": jnp.array([3.0, -2.0, 1.5]), "b": jnp.array(4.0)}

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    return params, loss


@pytest.mark.parametrize("make", [
    lambda: adamw(lambda s: 0.1, weight_decay=0.0),
    lambda: adafactor(lambda s: 0.5),
])
def test_optimizers_converge_quadratic(make):
    opt = make()
    params, loss = _quad_problem()
    state = opt.init(params)
    l0 = float(loss(params))
    for i in range(200):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params, jnp.int32(i))
    assert float(loss(params)) < 1e-2 * l0


def test_adafactor_state_is_factored():
    opt = adafactor(lambda s: 0.1)
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros(7)}
    st_ = opt.init(params)
    assert st_["w"]["r"].shape == (64,) and st_["w"]["c"].shape == (32,)
    assert st_["b"]["v"].shape == (7,)
    # factored state is ~(64+32)/(64*32) of adamw's per-element state
    adam_state = adamw(lambda s: 0.1).init(params)
    fac = sum(x.size for x in jax.tree.leaves(st_))
    full = sum(x.size for x in jax.tree.leaves(adam_state))
    assert fac < full / 10


def test_schedules():
    cos = cosine_schedule(1.0, warmup=10, total=110)
    assert float(cos(0)) == 0.0
    assert abs(float(cos(10)) - 1.0) < 1e-6
    assert float(cos(110)) < 0.2
    wsd = wsd_schedule(1.0, warmup=10, stable=80, decay=20)
    assert abs(float(wsd(50)) - 1.0) < 1e-6  # stable region
    assert float(wsd(109)) < 0.2             # decayed
    assert float(wsd(5)) == 0.5              # warmup


# ---------------- data ------------------------------------------------------

def test_synthetic_data_deterministic_and_sharded():
    d = SyntheticLM(vocab=1000, seq_len=16, global_batch=8)
    b1 = d.host_batch(step=3, shard=0, n_shards=2)
    b2 = d.host_batch(step=3, shard=0, n_shards=2)
    b3 = d.host_batch(step=3, shard=1, n_shards=2)
    assert np.array_equal(b1["tokens"], b2["tokens"])  # reproducible
    assert not np.array_equal(b1["tokens"], b3["tokens"])  # distinct shards
    assert b1["tokens"].shape == (4, 16)
    assert np.array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


# ---------------- train loop -----------------------------------------------

def test_train_learns_and_microbatch_consistency():
    cfg = get_config("qwen3_1_7b").smoke()
    opt, _ = make_train_setup(cfg, total_steps=100, peak_lr=5e-3)
    state = init_state(cfg, KEY, opt)
    data = SyntheticLM(cfg.vocab, 32, 8)
    step1 = jax.jit(make_train_step(cfg, opt, microbatches=1))
    step2 = jax.jit(make_train_step(cfg, opt, microbatches=2))
    b = data.device_batch(0)
    _, m1 = step1(state, b)
    _, m2 = step2(state, b)
    # same data, same params: microbatched loss equals full-batch loss
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=2e-2)
    losses = []
    for i in range(20):
        state, m = step1(state, data.device_batch(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.05


def test_int8_grad_compression_trains():
    cfg = get_config("qwen3_1_7b").smoke()
    opt, _ = make_train_setup(cfg, total_steps=50, peak_lr=5e-3)
    state = init_state(cfg, KEY, opt)
    step = jax.jit(make_train_step(cfg, opt, compress_grads=True))
    data = SyntheticLM(cfg.vocab, 32, 4)
    losses = []
    for i in range(15):
        state, m = step(state, data.device_batch(i))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all() and losses[-1] < losses[0]


# ---------------- coded checkpointing ---------------------------------------

def _tiny_state():
    cfg = get_config("qwen3_1_7b").smoke()
    opt, _ = make_train_setup(cfg)
    return init_state(cfg, KEY, opt)


def test_coded_checkpoint_roundtrip_and_failures():
    state = _tiny_state()
    with tempfile.TemporaryDirectory() as td:
        ck = CodedCheckpointer(td, n_shards=8, n_parity=4)
        ck.save(7, state)
        assert ck.latest_step() == 7
        for failures in [set(), {0}, {1, 6}, {0, 3, 5, 7}]:
            rest = ck.restore(7, state, failed_shards=failures)
            same = jax.tree.map(
                lambda a, b: bool(np.array_equal(
                    np.asarray(a, np.float32), np.asarray(b, np.float32))),
                state, rest)
            assert all(jax.tree.leaves(same)), failures


def test_coded_checkpoint_too_many_failures_raises():
    state = _tiny_state()
    with tempfile.TemporaryDirectory() as td:
        ck = CodedCheckpointer(td, n_shards=8, n_parity=2)
        ck.save(1, state)
        with pytest.raises(AssertionError):
            ck.restore(1, state, failed_shards={0, 1, 2})


def test_async_save_and_elastic_reshard():
    state = _tiny_state()
    with tempfile.TemporaryDirectory() as td:
        ck = CodedCheckpointer(td, n_shards=16, n_parity=4)
        ck.save(2, state, background=True)
        ck.wait()
        ck2 = ck.reshard(2, new_n=4, new_r=2)
        rest = ck2.restore(2, state, failed_shards={3})
        same = jax.tree.map(
            lambda a, b: bool(np.array_equal(
                np.asarray(a, np.float32), np.asarray(b, np.float32))),
            state, rest)
        assert all(jax.tree.leaves(same))


@given(nbytes=st.integers(1, 4097), seed=st.integers(0, 2**31))
@settings(max_examples=20, deadline=None)
def test_shard_symbols_roundtrip_property(nbytes, seed):
    rng = np.random.default_rng(seed)
    raw = rng.integers(0, 256, nbytes, dtype=np.uint8)
    import tempfile as tf
    with tf.TemporaryDirectory() as td:
        ck = CodedCheckpointer(td, n_shards=4, n_parity=2)
        shards = ck.shard_symbols(raw)
        parity = ck.encode_parity(shards)
        # any 4 of 6 reconstruct
        from repro.core.parity import reconstruct
        full = np.concatenate([shards, parity])
        kept = np.sort(rng.choice(6, 4, replace=False))
        rec = reconstruct(FERMAT, ck.sgrs, kept, full[kept])
        assert np.array_equal(rec, shards)


# ---------------- gradient coding / LCC -------------------------------------

def test_gradient_coder_all_straggler_patterns():
    gc = GradientCoder(6, s=1)
    true_parts = [{"g": jnp.ones(2) * (i + 1)} for i in range(6)]
    # worker w reports the sum of its group's parts
    worker_out = []
    for w in range(6):
        parts = gc.parts_for_worker(w)
        worker_out.append({"g": sum(true_parts[i]["g"] for i in parts)})
    expected = sum(p["g"] for p in true_parts) / 6
    for dead in [set(), {0}, {1, 2}, {5, 0, 3}]:
        alive = np.array([w not in dead for w in range(6)])
        groups_hit = {w // 2 for w in dead}
        if any(sum(1 for w in dead if w // 2 == g) > 1 for g in groups_hit):
            continue  # > s per group: not covered
        out = gc.combine(worker_out, alive)
        np.testing.assert_allclose(np.asarray(out["g"]), np.asarray(expected))


def test_gradient_coder_group_wipeout_raises():
    gc = GradientCoder(6, s=1)
    alive = np.array([False, False, True, True, True, True])
    with pytest.raises(RuntimeError):
        gc.decode_weights(alive)


@pytest.mark.parametrize("deg", [1, 2, 3])
def test_lcc_polynomial_eval(deg):
    f = FERMAT
    lcc = LagrangeComputer.build(f, K=5, N=16)
    x = f.rand((5, 3), np.random.default_rng(deg))

    def poly(v):
        out = np.zeros_like(v)
        for _ in range(deg):
            out = f.add(f.mul(out, v), v)  # v^deg + ... (some deg-poly)
        return f.add(out, 3)

    coded = lcc.encode(x)
    results = poly(coded)
    T = lcc.recovery_threshold(deg)
    ids = np.arange(16)[-T:]  # any subset works; take the tail
    dec = lcc.decode(deg, ids, results[ids])
    assert np.array_equal(dec, poly(x))
