"""Unified Encoder/EncodePlan API: backend parity, plan caching, auto
method selection (the mesh backend is exercised in-process where one device
suffices and in `api_mesh_checks.py` on 8 forced host devices)."""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.api import CodeSpec, Encoder, method_costs
from repro.api.planner import _host_tables
from repro.core.field import FERMAT

REPO = Path(__file__).resolve().parent.parent
RNG = np.random.default_rng(11)


def _spec(kind, K, R, **kw):
    if kind == "universal":
        kw.setdefault("seed", 5)
    return CodeSpec(kind=kind, K=K, R=R, **kw)


@pytest.mark.parametrize("kind,K,R", [
    ("universal", 16, 4), ("universal", 4, 16), ("rs", 16, 4),
    ("rs", 8, 8), ("lagrange", 16, 4), ("dft", 8, 8),
])
def test_simulator_local_parity(kind, K, R):
    spec = _spec(kind, K, R)
    x = FERMAT.rand((K, 3), RNG)
    ys = Encoder.plan(spec, backend="simulator").run(x)
    yl = Encoder.plan(spec, backend="local").run(x)
    ref = FERMAT.matmul(Encoder.plan(spec, backend="local").A.T, x)
    assert np.array_equal(ys, ref)
    assert np.array_equal(yl, ref)


def test_methods_agree_on_simulator():
    spec = CodeSpec(kind="rs", K=32, R=8)
    x = FERMAT.rand((32, 2), RNG)
    y_u = Encoder.plan(spec, backend="simulator", method="universal").run(x)
    y_r = Encoder.plan(spec, backend="simulator", method="rs").run(x)
    assert np.array_equal(y_u, y_r)


def test_plan_cache_reuses_tables():
    Encoder.cache_clear()
    spec = CodeSpec(kind="rs", K=16, R=4)
    p1 = Encoder.plan(spec, backend="simulator")
    info = Encoder.cache_info()
    assert info["table_misses"] == 1 and info["plan_misses"] == 1

    # identical spec: plan cache hit, same plan object, no table rebuild
    p2 = Encoder.plan(spec, backend="simulator")
    info = Encoder.cache_info()
    assert p2 is p1
    assert info["plan_hits"] == 1 and info["table_misses"] == 1

    # other backend / other payload width: same host tables (W-independent)
    p3 = Encoder.plan(spec, backend="local")
    p4 = Encoder.plan(spec.with_W(4096), backend="local")
    assert p3.tables is p1.tables and p4.tables is p1.tables
    assert Encoder.cache_info()["table_misses"] == 1


def test_run_is_hot_path_no_rebuild():
    Encoder.cache_clear()
    plan = Encoder.plan(CodeSpec(kind="rs", K=8, R=4), backend="local")
    before = Encoder.cache_info()
    for _ in range(3):
        plan.run(FERMAT.rand((8, 5), RNG))
    after = Encoder.cache_info()
    assert after["table_misses"] == before["table_misses"]
    assert after["tables"] == before["tables"]


def test_auto_picks_cost_model_argmin():
    for spec in (CodeSpec(kind="rs", K=16, R=4, W=1),
                 CodeSpec(kind="rs", K=128, R=128, W=1),
                 CodeSpec(kind="rs", K=128, R=128, W=4096)):
        # method_costs folds W into C2 (matches measured RoundNetwork.C2
        # of a W-wide run) — totals are evaluated at W=1
        costs = method_costs(spec, _host_tables(spec, None, None).sgrs)
        expect = min(costs, key=lambda m: (
            costs[m].total(Encoder.ALPHA, Encoder.BETA_BITS),
            m == "universal"))
        plan = Encoder.plan(spec, backend="simulator")
        assert plan.method == expect, (spec, plan.method, expect)
    # bandwidth-dominated regime must flip to the specific algorithm
    assert Encoder.plan(CodeSpec(kind="rs", K=128, R=128, W=4096),
                        backend="simulator").method == "rs"
    assert Encoder.plan(CodeSpec(kind="rs", K=16, R=4, W=1),
                        backend="simulator").method == "universal"


def test_explicit_matrix_and_1d_payloads():
    K, R = 5, 16  # no divisibility — universal schedule on explicit A
    A = FERMAT.rand((K, R), RNG)
    spec = CodeSpec(kind="universal", K=K, R=R)
    ys = Encoder.plan(spec, backend="simulator", A=A).run(FERMAT.arr(np.arange(K)))
    yl = Encoder.plan(spec, backend="local", A=A).run(FERMAT.arr(np.arange(K)))
    ref = FERMAT.matmul(A.T, np.arange(K)[:, None])[:, 0]
    assert ys.shape == (R,) and np.array_equal(ys, ref)
    assert np.array_equal(yl, ref)
    # distinct matrices of the same spec must not collide in the cache
    A2 = FERMAT.rand((K, R), RNG)
    y2 = Encoder.plan(spec, backend="local", A=A2).run(FERMAT.arr(np.arange(K)))
    assert not np.array_equal(y2, ref)


def test_spec_validation():
    with pytest.raises(ValueError):
        CodeSpec(kind="nope", K=4, R=4)
    with pytest.raises(ValueError):
        CodeSpec(kind="dft", K=6, R=6)  # not a power of P
    with pytest.raises(ValueError):
        CodeSpec(kind="dft", K=8, R=4)  # dft is square
    with pytest.raises(ValueError):
        Encoder.plan(CodeSpec(kind="universal", K=4, R=4), backend="local")
    with pytest.raises(ValueError):
        Encoder.plan(CodeSpec(kind="rs", K=8, R=4), backend="warp-drive")
    with pytest.raises(ValueError):  # rs derives A itself
        Encoder.plan(CodeSpec(kind="rs", K=8, R=4), A=FERMAT.rand((8, 4), RNG))
    with pytest.raises(ValueError):  # uint32 kernels are Fermat-only
        Encoder.plan(CodeSpec(kind="rs", K=8, R=4, q=7681), backend="local")


def test_non_fermat_field_stays_exact():
    """q != 65537 runs on the simulator oracle (kernel backends refuse)."""
    from repro.core.field import Field

    f = Field(7681)
    spec = CodeSpec(kind="rs", K=8, R=4, q=7681)
    x = f.rand((8, 2), RNG)
    plan = Encoder.plan(spec, backend="simulator")
    assert np.array_equal(plan.run(x), f.matmul(plan.A.T, x))


def test_describe_mentions_selection():
    plan = Encoder.plan(CodeSpec(kind="rs", K=16, R=4), backend="simulator")
    text = plan.describe()
    assert "rs" in text and "simulator" in text and str(plan.cost().C1) in text


def test_simulator_records_network_costs():
    from repro.core.prepare_shoot import cost_universal

    spec = CodeSpec(kind="universal", K=8, R=8, seed=1)
    plan = Encoder.plan(spec, backend="simulator")
    plan.run(FERMAT.rand((8, 1), RNG))
    assert plan.sim_net is not None and plan.sim_net.C1 > 0
    # single square block: phase-1 A2A matches Thm. 3 exactly
    c1_a2a, _ = cost_universal(8, 1)
    assert plan.sim_net.C1 >= c1_a2a


def test_gradient_coder_plan_matches_matrix():
    from repro.coding import GradientCoder

    coder = GradientCoder(n_workers=8, s=1)
    parts = FERMAT.rand((8, 3), RNG)
    plan = coder.encode_plan()
    got = plan.run(parts)
    B = coder.encode_matrix().astype(np.int64)
    assert np.array_equal(got, FERMAT.matmul(B, parts))


def test_lagrange_computer_routes_through_api():
    from repro.coding import LagrangeComputer

    lcc = LagrangeComputer.build(FERMAT, K=5, N=16)
    x = FERMAT.rand((5, 4), RNG)
    coded = lcc.encode(x)
    from repro.core.matrices import lagrange_matrix

    L = lagrange_matrix(FERMAT, lcc.alphas, lcc.betas)
    assert np.array_equal(coded, FERMAT.matmul(L.T, x))
    assert lcc.encode_plan() is lcc.encode_plan()  # memoized


@pytest.mark.slow
def test_backend_parity_subprocess_8_devices():
    """simulator == local == mesh bitwise, on 8 forced host devices."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(REPO / "tests" / "api_mesh_checks.py")],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "API_MESH_CHECKS_OK" in proc.stdout
