"""All-to-all encode algorithms vs direct matmul oracles + cost theorems."""
import math

import numpy as np
import pytest
from conftest_hypothesis import given, settings, st

from repro.core import (
    FERMAT,
    Field,
    RoundNetwork,
    StructuredPoints,
    cost_dft,
    cost_draw_loose,
    cost_universal,
    dft_a2a,
    draw_loose,
    permuted_dft_matrix,
    universal_a2a,
    vandermonde,
)
from repro.core.prepare_shoot import phase_split

RNG = np.random.default_rng(42)


# ---------------- universal prepare-and-shoot -------------------------------

@pytest.mark.parametrize("p", [1, 2, 3, 4])
@pytest.mark.parametrize("K", [2, 3, 4, 5, 8, 9, 13, 16, 27, 40, 64, 65, 100])
def test_universal_correct_and_c1_optimal(K, p):
    f = FERMAT
    C = f.rand((K, K), RNG)
    x = f.rand(K, RNG)
    net = RoundNetwork(K, p)
    y = universal_a2a(f, C, x, p=p, net=net)
    assert np.array_equal(y, f.matmul(x[None, :], C)[0])
    c1, c2 = cost_universal(K, p)
    assert net.C1 == c1  # C1-optimal (Lemma 1)
    # Thm. 3 C2 is exact for K = (p+1)^L and an upper bound otherwise
    # (partial trees carry smaller messages)
    assert net.C2 <= c2
    if K == (p + 1) ** c1:
        assert net.C2 == c2


def test_universal_vector_payload():
    f = FERMAT
    K, W = 65, 5
    C = f.rand((K, K), RNG)
    x = f.rand((K, W), RNG)
    y = universal_a2a(f, C, x, p=2)
    assert np.array_equal(y, f.matmul(C.T, x))


def test_universal_other_field():
    f = Field(12289)
    K = 31
    C = f.rand((K, K), RNG)
    x = f.rand(K, RNG)
    assert np.array_equal(universal_a2a(f, C, x, p=1), f.matmul(x[None, :], C)[0])


@given(K=st.integers(2, 60), p=st.integers(1, 4), seed=st.integers(0, 2**31))
@settings(max_examples=40, deadline=None)
def test_universal_property(K, p, seed):
    """Property: prepare-and-shoot computes x*C for random K, p, C, x."""
    f = FERMAT
    rng = np.random.default_rng(seed)
    C = f.rand((K, K), rng)
    x = f.rand(K, rng)
    assert np.array_equal(universal_a2a(f, C, x, p=p), f.matmul(x[None, :], C)[0])


def test_c2_lower_bound_respected():
    """Thm. 3 C2 is within sqrt(2) of the Lemma 2 lower bound (Remark 7)."""
    from repro.core.cost_model import lower_bound_c2

    for p in (1, 2):
        for L in (4, 6, 8):
            K = (p + 1) ** L
            _, c2 = cost_universal(K, p)
            lb = lower_bound_c2(K, p)
            assert c2 >= lb - 1
            assert c2 <= math.sqrt(2) * math.sqrt(2 * K) / p + 2 * (p + 1)


def test_phase_split_invariants():
    for p in (1, 2, 3):
        for K in range(2, 300):
            L, T_p, T_s, m = phase_split(K, p)
            assert T_p + T_s == L
            assert (p + 1) ** L >= K > (p + 1) ** (L - 1)
            assert m == (p + 1) ** T_p


# ---------------- DFT-specific (Sec. V-A) ----------------------------------

@pytest.mark.parametrize("K,P", [(4, 2), (8, 2), (16, 2), (16, 4), (64, 4), (256, 16)])
def test_dft_a2a_vs_matrix(K, P):
    f = FERMAT
    x = f.rand(K, RNG)
    out = {}
    net = RoundNetwork(K, 1)
    net.run(dft_a2a(f, {k: x[k] for k in range(K)}, list(range(K)), 1, P, out))
    y = np.stack([out[k] for k in range(K)])
    D = permuted_dft_matrix(f, K, P)
    assert np.array_equal(y, f.matmul(x[None, :], D)[0])
    c1, c2 = cost_dft(K, P, 1)
    assert (net.C1, net.C2) == (c1, c2)


def test_dft_radix3_other_field():
    """Radix-3 DFT needs 3^H | q-1: use q=487 (486 = 2*3^5)."""
    f = Field(487)
    K, P = 81, 3
    x = f.rand(K, RNG)
    out = {}
    net = RoundNetwork(K, 2)
    net.run(dft_a2a(f, {k: x[k] for k in range(K)}, list(range(K)), 2, P, out))
    y = np.stack([out[k] for k in range(K)])
    assert np.array_equal(y, f.matmul(x[None, :], permuted_dft_matrix(f, K, P))[0])
    # Cor. 1: P = p+1 -> strictly optimal C1 = C2 = H = 4
    assert net.C1 == net.C2 == 4


def test_dft_inverse_roundtrip():
    f = FERMAT
    K, P = 64, 2
    x = f.rand(K, RNG)
    out, back = {}, {}
    RoundNetwork(K, 1).run(dft_a2a(f, {k: x[k] for k in range(K)}, list(range(K)), 1, P, out))
    RoundNetwork(K, 1).run(dft_a2a(f, out, list(range(K)), 1, P, back, inverse=True))
    assert np.array_equal(np.stack([back[k] for k in range(K)]), x)


# ---------------- draw-and-loose (Sec. V-B) --------------------------------

@pytest.mark.parametrize("K,P", [(8, 2), (12, 2), (24, 2), (48, 2), (80, 4), (96, 2)])
def test_draw_loose_vs_vandermonde(K, P):
    f = FERMAT
    sp = StructuredPoints.build(f, K, P=P)
    V = vandermonde(f, sp.points())
    x = f.rand(K, RNG)
    out = {}
    net = RoundNetwork(K, 1)
    net.run(draw_loose(f, sp, {k: x[k] for k in range(K)}, list(range(K)), 1, out))
    y = np.stack([out[k] for k in range(K)])
    assert np.array_equal(y, f.matmul(x[None, :], V)[0])
    assert (net.C1, net.C2) == cost_draw_loose(sp, 1)


def test_draw_loose_inverse_roundtrip():
    f = FERMAT
    sp = StructuredPoints.build(f, 48, P=2)
    x = f.rand((48, 3), RNG)
    mid, back = {}, {}
    RoundNetwork(48, 1).run(draw_loose(f, sp, {k: x[k] for k in range(48)}, list(range(48)), 1, mid))
    RoundNetwork(48, 1).run(draw_loose(f, sp, mid, list(range(48)), 1, back, inverse=True))
    assert np.array_equal(np.stack([back[k] for k in range(48)]), x)


def test_draw_loose_beats_universal_c2_at_scale():
    """The point of Sec. V: C2 gain over universal grows with K (Remark 8)."""
    f = FERMAT
    for K in (256, 1024, 4096):
        sp = StructuredPoints.build(f, K, P=2)
        _, c2_vand = cost_draw_loose(sp, 1)
        _, c2_univ = cost_universal(K, 1)
        assert c2_vand < c2_univ
    # at K=4096: universal ~ 2*sqrt(K) = 126; DFT-specific = log2 K = 12
    assert cost_draw_loose(StructuredPoints.build(f, 4096, P=2), 1)[1] <= 12
    assert cost_universal(4096, 1)[1] >= 120
