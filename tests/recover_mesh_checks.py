"""Decode backend-parity checks on 8 forced host devices (subprocess
companion of test_recover.py — jax locks the device count at first init).

For every code kind, `Decoder.plan(spec, erased=E, backend=b).run(v)` must
return bitwise-identical repaired symbols for b in {"simulator", "local",
"mesh"}, and exactly invert the encode.  Also runs the degraded checkpoint
read end-to-end on the 8-device topology: save with N=8 data shards,
delete R shard files from disk, restore bitwise.

Prints 'RECOVER_MESH_CHECKS_OK' on success; any assertion failure is fatal.
"""
import os
import tempfile
from pathlib import Path

from _fake_devices import force_host_devices

force_host_devices(8)

import numpy as np

from repro.api import CodeSpec, Encoder
from repro.core.field import FERMAT
from repro.recover import Decoder, decode_cost

f = FERMAT
rng = np.random.default_rng(12)

cases = [
    ("universal", 8, 4, [(3,), (0, 9), (0, 1, 2, 3), (8, 9, 10, 11)]),
    ("rs", 8, 4, [(2, 11), (4, 5, 6, 7), (0, 3, 8, 10)]),
    ("rs", 8, 8, [(0, 2, 4, 6, 8, 10, 12, 14), tuple(range(8))]),
    ("lagrange", 8, 4, [(1, 10, 11)]),
    ("dft", 8, 8, [(0,), (5, 9, 13)]),
]
for kind, K, R, patterns in cases:
    spec = CodeSpec(kind=kind, K=K, R=R, W=16,
                    seed=9 if kind == "universal" else None)
    x = f.rand((K, 16), rng)
    cw = np.concatenate([x % f.q, Encoder.plan(spec, backend="local").run(x)])
    for erased in patterns:
        plans = {b: Decoder.plan(spec, erased=erased, backend=b)
                 for b in ("simulator", "local", "mesh")}
        v = cw[list(plans["mesh"].kept)]
        ys = {b: p.run(v) for b, p in plans.items()}
        for b, y in ys.items():
            assert np.array_equal(y, cw[list(erased)]), (kind, erased, b)
        c = decode_cost(K, len(erased), spec.p)
        net = plans["simulator"].sim_net
        assert (net.C1, net.C2) == (c.C1, c.C2 * 16), (kind, erased)
        print(f"{kind} K={K} R={R} erased={erased}: "
              "simulator == local == mesh, C1/C2 exact")

# repeated plan() reuses the plan AND its compiled mesh executables
from repro.recover.backends import _mesh_callables

spec = CodeSpec(kind="rs", K=8, R=4, W=16)
p1 = Decoder.plan(spec, erased=(0, 9), backend="mesh")
fns = _mesh_callables(p1)
p2 = Decoder.plan(spec, erased=(9, 0), backend="mesh")
assert p2 is p1 and _mesh_callables(p2) is fns, "mesh decode plan not cached"
print("mesh decode plan cache OK")

# degraded checkpoint restore on the 8-device topology
import jax

from repro.ckpt import CodedCheckpointer

assert len(jax.devices()) == 8, jax.devices()
state = {"w": np.arange(4096, dtype=np.float32).reshape(64, 64),
         "s": np.float32(3.25)}
with tempfile.TemporaryDirectory() as td:
    ck = CodedCheckpointer(td, n_shards=8, n_parity=2)
    ck.save(5, state)
    d = Path(td) / "step_000005"
    for name in ("shard_002.npy", "shard_004.npy"):
        os.remove(d / name)
    rest = ck.restore(5, state)
    ok = jax.tree.map(lambda a, b: bool(np.array_equal(np.asarray(a),
                                                       np.asarray(b))),
                      state, rest)
    assert all(jax.tree.leaves(ok)), "degraded restore drifted"
print("degraded checkpoint restore (2 shard files deleted) OK")

print("RECOVER_MESH_CHECKS_OK")
