"""Decode & repair subsystem: erasure injection, all-to-all decode with
exact closed-form network costs, Decoder/DecodePlan backend parity, the
GF solve kernel, and degraded checkpoint reads (the mesh backend is
exercised in `recover_mesh_checks.py` on 8 forced host devices)."""
import os
import subprocess
import sys
import tempfile
from pathlib import Path

import numpy as np
import pytest

from conftest_hypothesis import given, settings, st

from repro.api import CodeSpec, Encoder
from repro.core.field import FERMAT
from repro.core.simulator import FailedProcessorError, Msg, RoundNetwork
from repro.recover import Decoder, UndecodableError, decode_cost
from repro.recover.engine import decode_batches

REPO = Path(__file__).resolve().parent.parent
RNG = np.random.default_rng(23)


def _spec(kind, K, R, **kw):
    if kind == "universal":
        kw.setdefault("seed", 5)
    return CodeSpec(kind=kind, K=K, R=R, **kw)


def _codeword(spec, x):
    y = Encoder.plan(spec, backend="simulator").run(x)
    return np.concatenate([x % spec.q, y])


# ---------------------------------------------------------------------------
# simulator layer: erasure injection + opt-in round log
# ---------------------------------------------------------------------------

def test_fail_blocks_sends_and_receives():
    net = RoundNetwork(4, 1)
    net.fail([2])
    with pytest.raises(FailedProcessorError):
        net._account([Msg(2, 0, 1)])  # failed sender
    with pytest.raises(FailedProcessorError):
        net._account([Msg(0, 2, 1)])  # failed receiver
    net._account([Msg(0, 1, 1)])      # survivors talk freely
    assert net.C1 == 1


def test_fail_rejects_out_of_range():
    net = RoundNetwork(4)
    with pytest.raises(ValueError, match=r"outside \[0, 4\)"):
        net.fail([4])
    with pytest.raises(ValueError):
        net.fail_at(2, [4])
    with pytest.raises(ValueError):
        net.fail_at(-1, [1])


def test_encode_schedule_raises_on_failed_sink():
    """The *encode* framework routes through sink processors — once one is
    failed, running the schedule must raise, not silently miscount."""
    from repro.core.framework import decentralized_encode

    spec = _spec("rs", 8, 4)
    A = Encoder.plan(spec, backend="simulator").A
    net = RoundNetwork(12, 1)
    net.fail([9])  # sink T_1
    with pytest.raises(FailedProcessorError):
        decentralized_encode(FERMAT, A, FERMAT.rand((8, 1), RNG), net=net)


def test_round_log_is_opt_in():
    spec = _spec("rs", 16, 4)
    x = FERMAT.rand((16, 2), RNG)
    plan = Encoder.plan(spec, backend="simulator")
    plan.run(x)
    assert plan.sim_net.C1 > 0 and plan.sim_net.round_log == []

    net = RoundNetwork(8, 1, keep_log=True)
    from repro.core.prepare_shoot import prepare_shoot

    out = {}
    vals = {k: FERMAT.rand((2,), RNG) for k in range(8)}
    net.run(prepare_shoot(FERMAT, FERMAT.rand((8, 8), RNG), vals,
                          list(range(8)), 1, out))
    assert len(net.round_log) == net.C1 > 0
    assert net.C2 == sum(m for _, m in net.round_log)


# ---------------------------------------------------------------------------
# all-to-all decode: exactness + closed-form C1/C2
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,K,R", [
    ("universal", 16, 4), ("universal", 4, 16), ("rs", 16, 4),
    ("rs", 8, 8), ("lagrange", 16, 4), ("dft", 8, 8),
])
def test_decode_inverts_encode_sim_and_local(kind, K, R):
    spec = _spec(kind, K, R)
    W = 3
    x = FERMAT.rand((K, W), RNG)
    cw = _codeword(spec, x)
    rng = np.random.default_rng(K * 31 + R)
    patterns = [tuple(sorted(rng.choice(K + R, size=n, replace=False).tolist()))
                for n in range(R + 1)]
    for erased in patterns:
        ds = Decoder.plan(spec, erased=erased, backend="simulator")
        dl = Decoder.plan(spec, erased=erased, backend="local")
        v = cw[list(ds.kept)]
        rep = ds.run(v)
        assert np.array_equal(rep, cw[list(erased)]), (kind, erased)
        assert np.array_equal(dl.run(v), rep), (kind, erased)
        assert np.array_equal(ds.data(v), cw[:K]), (kind, erased)
        if erased:
            # measured network counts == closed form, exactly
            c = decode_cost(K, len(erased), spec.p)
            assert ds.sim_net.C1 == c.C1, (kind, erased)
            assert ds.sim_net.C2 == c.C2 * W, (kind, erased)
            assert ds.cost().C1 == c.C1  # spec.W == 1 here


@pytest.mark.parametrize("p", [1, 2, 3])
def test_decode_cost_closed_form_many_shapes(p):
    """decode_cost is *exact* for every (K, |E|) shape, not an upper bound."""
    rng = np.random.default_rng(p)
    for K in (2, 3, 5, 8, 12, 16):
        for E in (1, 2, K - 1, K, min(2 * K, 20)):
            W = 2
            D = FERMAT.rand((K, E), rng)
            v = FERMAT.rand((K, W), rng)
            from repro.recover import decentralized_decode

            net = RoundNetwork(K + 1, p)
            y, net = decentralized_decode(FERMAT, D, v, list(range(K)), p, net)
            assert np.array_equal(y, FERMAT.matmul(D.T, v))
            c = decode_cost(K, E, p)
            assert (net.C1, net.C2) == (c.C1, c.C2 * W), (K, E, p)


def test_decode_more_erasures_than_survivor_slots_batches():
    """K < R specs can lose more shards than there are survivors; the
    schedule processes repair targets in batches of K columns."""
    spec = _spec("universal", 4, 16)
    x = FERMAT.rand((4, 2), RNG)
    cw = _codeword(spec, x)
    erased = tuple(range(1, 11))  # 10 erasures > K = 4
    assert decode_batches(4, 10) == [(4, 4), (4, 4), (2, 2)]
    plan = Decoder.plan(spec, erased=erased, backend="simulator")
    v = cw[list(plan.kept)]
    assert np.array_equal(plan.run(v), cw[list(erased)])
    assert plan.sim_net.C1 == decode_cost(4, 10, 1).C1


def test_decode_simulator_fails_erased_processors():
    """The decode network has the erased processors failed — the schedule
    provably never touches them (it would raise otherwise)."""
    spec = _spec("rs", 16, 4)
    x = FERMAT.rand((16, 1), RNG)
    cw = _codeword(spec, x)
    erased = (0, 5, 17, 19)
    plan = Decoder.plan(spec, erased=erased, backend="simulator")
    plan.run(cw[list(plan.kept)])
    assert plan.sim_net.failed == set(erased)
    with pytest.raises(FailedProcessorError):
        plan.sim_net._account([Msg(0, 1, 1)])


def test_decoder_validation_and_cache():
    spec = _spec("rs", 16, 4)
    with pytest.raises(ValueError):
        Decoder.plan(spec, erased=(0, 1, 2, 3, 4))  # > R
    with pytest.raises(ValueError):
        Decoder.plan(spec, erased=(20,))            # out of range
    with pytest.raises(ValueError):
        Decoder.plan(spec, erased=(0,), backend="warp-drive")
    with pytest.raises(ValueError):                 # kernels are Fermat-only
        Decoder.plan(CodeSpec(kind="rs", K=8, R=4, q=7681), erased=(0,),
                     backend="local")
    p1 = Decoder.plan(spec, erased=(17, 0))
    p2 = Decoder.plan(spec, erased=(0, 17))         # order-normalized key
    assert p2 is p1
    p3 = Decoder.plan(spec, erased=(0, 17), backend="local")
    assert p3.tables is p1.tables                   # backends share tables


def test_decode_zero_erasures_is_noop():
    spec = _spec("rs", 8, 4)
    plan = Decoder.plan(spec, erased=())
    v = FERMAT.rand((8, 3), RNG)
    assert plan.run(v).shape == (0, 3)
    assert np.array_equal(plan.data(v), v % FERMAT.q)  # kept == data shards


def test_dft_undecodable_pattern_raises():
    """[I | A_dft] is not MDS: a full-R erasure whose survivors are rank
    deficient must raise UndecodableError (found by scanning patterns)."""
    import itertools

    spec = CodeSpec(kind="dft", K=8, R=8)
    hit = None
    for erased in itertools.combinations(range(16), 8):
        try:
            Decoder.plan(spec, erased=erased)
        except UndecodableError:
            hit = erased
            break
    assert hit is not None, "expected at least one undecodable DFT pattern"


def test_decoder_skips_dependent_survivor_columns():
    """With < R erasures there are spare survivors; the greedy kept-set
    selection must skip dependent columns instead of failing."""
    import itertools

    spec = CodeSpec(kind="dft", K=8, R=8)
    x = FERMAT.rand((8, 2), RNG)
    cw = _codeword(spec, x)
    checked = 0
    for erased in itertools.combinations(range(16), 6):
        plan = Decoder.plan(spec, erased=erased)  # must always succeed...
        if plan.kept != tuple(sorted(set(range(16)) - set(erased)))[:8]:
            # ...and this pattern actually exercised the skip logic
            v = cw[list(plan.kept)]
            assert np.array_equal(plan.run(v), cw[list(erased)])
            checked += 1
            if checked >= 3:
                break
    assert checked, "no dependent-column pattern found at |E| = 6"


def test_explicit_matrix_decode():
    K, R = 6, 3
    A = FERMAT.rand((K, R), RNG)
    spec = CodeSpec(kind="universal", K=K, R=R)
    x = FERMAT.rand((K, 2), RNG)
    cw = np.concatenate([x % FERMAT.q,
                         Encoder.plan(spec, backend="simulator", A=A).run(x)])
    plan = Decoder.plan(spec, erased=(2, 7), A=A)
    v = cw[list(plan.kept)]
    assert np.array_equal(plan.run(v), cw[[2, 7]])


def test_describe_mentions_pattern():
    plan = Decoder.plan(_spec("rs", 16, 4), erased=(1, 18))
    text = plan.describe()
    assert "erased" in text and "[1, 18]" in text and "C1=" in text


# ---------------------------------------------------------------------------
# hypothesis property: random erasure patterns, all four kinds
# ---------------------------------------------------------------------------

@given(kind=st.sampled_from(["universal", "rs", "lagrange", "dft"]),
       data=st.data())
@settings(max_examples=30, deadline=None)
def test_decode_roundtrip_property(kind, data):
    """encode ∘ decode identity for random |E| <= R patterns, every kind."""
    K, R = {"universal": (8, 4), "rs": (8, 4),
            "lagrange": (8, 4), "dft": (8, 8)}[kind]
    spec = _spec(kind, K, R)
    N = K + R
    n = data.draw(st.integers(0, R), label="n_erased")
    erased = tuple(sorted(data.draw(
        st.lists(st.integers(0, N - 1), min_size=n, max_size=n, unique=True),
        label="erased")))
    seed = data.draw(st.integers(0, 2**31), label="seed")
    x = FERMAT.rand((K, 2), np.random.default_rng(seed))
    cw = _codeword(spec, x)
    try:
        plan = Decoder.plan(spec, erased=erased, backend="simulator")
    except UndecodableError:
        assert kind == "dft", "only the non-MDS DFT kind may be undecodable"
        return
    v = cw[list(plan.kept)]
    assert np.array_equal(plan.run(v), cw[list(erased)])
    assert np.array_equal(plan.data(v), cw[:K])
    if erased:
        c = decode_cost(K, len(erased), spec.p)
        assert (plan.sim_net.C1, plan.sim_net.C2) == (c.C1, c.C2 * 2)


@given(K=st.integers(1, 12), R=st.integers(1, 12), seed=st.integers(0, 2**31))
@settings(max_examples=30, deadline=None)
def test_reconstruct_property(K, R, seed):
    """core.parity.reconstruct (kernel solve path) recovers the data from
    any K-of-N sample of the codeword, for random structured codes."""
    from repro.core.cauchy import StructuredGRS
    from repro.core.parity import reconstruct

    if max(K, R) % min(K, R):
        return  # StructuredGRS assumes K | R or R | K (Remark 4)
    rng = np.random.default_rng(seed)
    sgrs = StructuredGRS.build(FERMAT, K, R)
    x = FERMAT.rand((K, 3), rng)
    A = sgrs.grs.A_direct()
    full = np.concatenate([x, FERMAT.matmul(A.T, x)])
    kept = np.sort(rng.choice(K + R, size=K, replace=False))
    assert np.array_equal(reconstruct(FERMAT, sgrs, kept, full[kept]), x)


# ---------------------------------------------------------------------------
# kernel layer: GF solve
# ---------------------------------------------------------------------------

def test_gf_gauss_inverse_matches_numpy_oracle():
    from repro.core.matrices import gauss_inverse
    from repro.kernels.gf_solve import gf_gauss_inverse, gf_solve

    rng = np.random.default_rng(2)
    for n in (1, 3, 16, 40):
        a = FERMAT.rand((n, n), rng)
        ref = gauss_inverse(FERMAT, a)
        assert np.array_equal(np.asarray(gf_gauss_inverse(a), np.int64), ref)
        b = FERMAT.rand((n, 5), rng)
        assert np.array_equal(np.asarray(gf_solve(a, b), np.int64),
                              FERMAT.matmul(ref, b))


def test_gf_gauss_inverse_singular_raises():
    from repro.kernels.gf_solve import gf_gauss_inverse

    a = np.array([[1, 2, 3], [2, 4, 6], [0, 0, 5]], np.int64)  # row2 = 2*row1
    with pytest.raises(ValueError, match="singular"):
        gf_gauss_inverse(a)


def test_decode_blocks_is_encode_dual():
    from repro.kernels.ops import decode_blocks, encode_blocks

    import jax.numpy as jnp

    rng = np.random.default_rng(4)
    v = jnp.asarray(FERMAT.rand((16, 9), rng), jnp.uint32)
    D = jnp.asarray(FERMAT.rand((16, 5), rng), jnp.uint32)
    assert np.array_equal(np.asarray(decode_blocks(v, D)),
                          np.asarray(encode_blocks(v, D)))
    assert np.array_equal(np.asarray(decode_blocks(v, D), np.int64),
                          FERMAT.matmul(np.asarray(D, np.int64).T,
                                        np.asarray(v, np.int64)))


# ---------------------------------------------------------------------------
# application layer: degraded checkpoint reads
# ---------------------------------------------------------------------------

def _tree_equal(a, b):
    import jax

    flat_a, flat_b = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(flat_a, flat_b))


def test_checkpoint_degraded_read_missing_files():
    from repro.ckpt import CodedCheckpointer

    state = {"w": np.arange(2048, dtype=np.float32).reshape(32, 64),
             "b": np.linspace(-2, 2, 517, dtype=np.float32)}
    with tempfile.TemporaryDirectory() as td:
        ck = CodedCheckpointer(td, n_shards=8, n_parity=4)
        ck.save(1, state)
        d = Path(td) / "step_000001"
        # R files vanish from disk: 3 data shards + 1 parity shard
        for f in ("shard_000.npy", "shard_003.npy", "shard_006.npy",
                  "parity_001.npy"):
            os.remove(d / f)
        assert _tree_equal(state, ck.restore(1, state))
        # one more simulated failure pushes past R
        with pytest.raises(AssertionError):
            ck.restore(1, state, failed_shards={1})


def test_checkpoint_degraded_plus_simulated_failures():
    from repro.ckpt import CodedCheckpointer

    state = {"w": np.arange(100, dtype=np.int32)}
    with tempfile.TemporaryDirectory() as td:
        ck = CodedCheckpointer(td, n_shards=8, n_parity=4)
        ck.save(2, state)
        os.remove(Path(td) / "step_000002" / "shard_005.npy")
        assert _tree_equal(state, ck.restore(2, state, failed_shards={0, 7}))


@pytest.mark.slow
def test_recover_backend_parity_subprocess_8_devices():
    """simulator == local == mesh decode bitwise + degraded ckpt restore,
    on 8 forced host devices."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(REPO / "tests" / "recover_mesh_checks.py")],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "RECOVER_MESH_CHECKS_OK" in proc.stdout
