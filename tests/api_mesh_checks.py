"""Backend-parity checks for the unified API on 8 forced host devices
(subprocess companion of test_api.py — jax locks the device count at first
init, so the main pytest process cannot host these).

For universal, systematic-RS, and Lagrange specs (plus the DFT transform),
`Encoder.plan(spec, backend=b).run(x)` must return bitwise-identical sink
values for b in {"simulator", "local", "mesh"}, under every schedule the
planner can pick.  Also checks that a repeated plan() is a cache hit that
reuses the compiled mesh executable.

Prints 'API_MESH_CHECKS_OK' on success; any assertion failure is fatal.
"""
from _fake_devices import force_host_devices

force_host_devices(8)

import numpy as np

from repro.api import CodeSpec, Encoder
from repro.core.field import FERMAT

f = FERMAT
rng = np.random.default_rng(42)

cases = [
    ("universal", 8, 4, ["auto", "universal"]),
    ("universal", 8, 8, ["auto"]),
    ("rs", 8, 4, ["auto", "universal", "rs"]),
    ("rs", 8, 8, ["universal", "rs"]),
    ("rs", 8, 2, ["universal", "rs"]),
    ("lagrange", 8, 4, ["auto", "universal", "rs"]),
    ("dft", 8, 8, ["auto"]),
]
for kind, K, R, methods in cases:
    spec = CodeSpec(kind=kind, K=K, R=R, W=16,
                    seed=9 if kind == "universal" else None)
    x = f.rand((K, 16), rng)
    for method in methods:
        plans = {b: Encoder.plan(spec, backend=b, method=method)
                 for b in ("simulator", "local", "mesh")}
        ys = {b: p.run(x) for b, p in plans.items()}
        ref = f.matmul(plans["local"].A.T, x)
        for b, y in ys.items():
            assert np.array_equal(y, ref), (kind, K, R, method, b)
        print(f"{kind} K={K} R={R} method={plans['mesh'].method}: "
              "simulator == local == mesh")

# plan cache: repeated plan() reuses the plan AND its compiled mesh callable
spec = CodeSpec(kind="rs", K=8, R=4, W=16)
p1 = Encoder.plan(spec, backend="mesh")
fn1 = p1.mesh_callable()
p2 = Encoder.plan(spec, backend="mesh")
assert p2 is p1 and p2.mesh_callable() is fn1, "mesh plan not cached"

# explicit-matrix universal spec on the mesh grid
A = f.rand((8, 4), rng)
spec = CodeSpec(kind="universal", K=8, R=4)
x = f.rand((8, 16), rng)
ref = f.matmul(A.T, x)
for b in ("simulator", "local", "mesh"):
    assert np.array_equal(Encoder.plan(spec, backend=b, A=A).run(x), ref), b
print("explicit-A universal: simulator == local == mesh")

print("API_MESH_CHECKS_OK")
