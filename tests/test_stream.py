"""Streaming execution layer (api/stream.py): chunked `run_stream` and
coalesced `run_batched` are bitwise-identical to whole-W `run` on both
planners, the planner auto-selects the NTT fast path on the local backend
exactly when the spec's point structure allows it, per-chunk simulator
C1/C2 accounting is exact, and the end-to-end surfaces (streamed coded
checkpointer, batched coding queue) recover bitwise.

The mesh backend needs forced host devices, so its parity checks live in
`tests/stream_mesh_checks.py` (run as a CI step, like the api/recover
mesh checks).
"""
from __future__ import annotations

import numpy as np
import pytest

from conftest_hypothesis import given, settings, st
from repro.api import CodeSpec, Encoder
from repro.api.stream import StreamStats, default_chunk_w, iter_chunks
from repro.core.field import FERMAT
from repro.recover import Decoder

f = FERMAT
BACKENDS = ("simulator", "local")

SPECS = [
    CodeSpec(kind="rs", K=16, R=4),
    CodeSpec(kind="rs", K=8, R=8),
    CodeSpec(kind="lagrange", K=8, R=4),
    CodeSpec(kind="dft", K=8, R=8),
    CodeSpec(kind="universal", K=8, R=4, seed=3),
]


def _ids(specs):
    return [f"{s.kind}_K{s.K}_R{s.R}" for s in specs]


# ---------------- encode: run_stream / run_batched --------------------------

@pytest.mark.parametrize("spec", SPECS, ids=_ids(SPECS))
@pytest.mark.parametrize("backend", BACKENDS)
def test_encode_stream_bitwise(spec, backend):
    rng = np.random.default_rng(1)
    x = f.rand((spec.K, 69), rng)
    plan = Encoder.plan(spec, backend=backend)
    ref = plan.run(x)
    got = np.concatenate(list(plan.run_stream(x, chunk_w=16)), axis=1)
    assert np.array_equal(ref, got)
    # ragged explicit chunks are respected and still bitwise-equal
    chunks = [x[:, :5], x[:, 5:38], x[:, 38:]]
    got2 = np.concatenate(list(plan.run_stream(chunks)), axis=1)
    assert np.array_equal(ref, got2)


@pytest.mark.parametrize("backend", BACKENDS)
def test_encode_batched_mixed_widths(backend):
    spec = CodeSpec(kind="rs", K=8, R=4)
    rng = np.random.default_rng(2)
    x = f.rand((8, 50), rng)
    plan = Encoder.plan(spec, backend=backend)
    ref = plan.run(x)
    outs = plan.run_batched([x[:, :7], x[:, 7], x[:, 8:50]], chunk_w=16)
    assert np.array_equal(outs[0], ref[:, :7])
    assert np.array_equal(outs[1], ref[:, 7])  # 1-D request, 1-D reply
    assert np.array_equal(outs[2], ref[:, 8:50])
    assert plan.run_batched([]) == []


def test_stream_stats_exact_per_chunk():
    """Simulator chunks account C1/C2 exactly: each chunk is a full
    lockstep run, C2 scaling with the chunk width."""
    spec = CodeSpec(kind="rs", K=8, R=4)
    plan = Encoder.plan(spec, backend="simulator")
    x = f.rand((8, 40), np.random.default_rng(3))
    list(plan.run_stream(x, chunk_w=16))
    stats = plan.stream_stats
    assert stats.widths == [16, 16, 8]
    # per-chunk counters must equal a standalone run of that chunk
    for w0, w1, c1, c2 in zip([0, 16, 32], [16, 32, 40],
                              stats.C1, stats.C2):
        plan.run(x[:, w0:w1])
        assert (plan.sim_net.C1, plan.sim_net.C2) == (c1, c2)
    assert stats.chunks == 3 and stats.W == 40
    assert stats.totals() == (sum(stats.C1), sum(stats.C2))


def test_zero_width_batch_matches_run():
    spec = CodeSpec(kind="rs", K=8, R=4)
    empty = np.zeros((8, 0), np.int64)
    enc = Encoder.plan(spec, backend="local")
    assert enc.run_batched([empty])[0].shape == enc.run(empty).shape == (4, 0)
    dec = Decoder.plan(spec, erased=(0, 9), backend="local")
    assert dec.run_batched([empty])[0].shape == dec.run(empty).shape == (2, 0)


def test_iter_chunks_validation():
    with pytest.raises(ValueError):
        list(iter_chunks(np.zeros((4, 8)), 8, 16))
    assert default_chunk_w(8) % 128 == 0
    st_ = StreamStats()
    assert st_.chunks == 0 and st_.totals() == (0, 0)


# ---------------- NTT fast-path selection -----------------------------------

def test_local_fastpath_selection():
    assert Encoder.plan(CodeSpec(kind="rs", K=16, R=4),
                        backend="local").local_impl == "ntt"
    assert Encoder.plan(CodeSpec(kind="dft", K=8, R=8),
                        backend="local").local_impl == "ntt"
    assert Encoder.plan(CodeSpec(kind="lagrange", K=8, R=4),
                        backend="local").local_impl == "ntt"
    # odd small side: no radix-2 coset structure -> dense fallback
    assert Encoder.plan(CodeSpec(kind="rs", K=9, R=3),
                        backend="local").local_impl == "dense"
    assert Encoder.plan(CodeSpec(kind="universal", K=8, R=4, seed=1),
                        backend="local").local_impl == "dense"


@pytest.mark.parametrize("spec", [
    CodeSpec(kind="rs", K=16, R=4),     # K > R: block sum
    CodeSpec(kind="rs", K=4, R=16),     # K < R: beta-block concat
    CodeSpec(kind="rs", K=12, R=4),     # non-power-of-two K, pow2 blocks
    CodeSpec(kind="rs", K=8, R=8),
    CodeSpec(kind="lagrange", K=4, R=8),
    CodeSpec(kind="dft", K=16, R=16),
], ids=_ids([CodeSpec(kind="rs", K=16, R=4), CodeSpec(kind="rs", K=4, R=16),
             CodeSpec(kind="rs", K=12, R=4), CodeSpec(kind="rs", K=8, R=8),
             CodeSpec(kind="lagrange", K=4, R=8),
             CodeSpec(kind="dft", K=16, R=16)]))
def test_ntt_fastpath_bitwise_vs_matrix(spec):
    """The O(K log K) local path returns exactly x^T A."""
    rng = np.random.default_rng(4)
    plan = Encoder.plan(spec, backend="local")
    assert plan.local_impl == "ntt"
    x = f.rand((spec.K, 33), rng)
    assert np.array_equal(plan.run(x), f.matmul(plan.A.T, x))


def test_dense_fallback_bitwise_vs_matrix():
    spec = CodeSpec(kind="rs", K=9, R=3)
    plan = Encoder.plan(spec, backend="local")
    assert plan.local_impl == "dense"
    x = f.rand((9, 21), np.random.default_rng(5))
    assert np.array_equal(plan.run(x), f.matmul(plan.A.T, x))


# ---------------- decode: run_stream / run_batched --------------------------

@pytest.mark.parametrize("erased", [(0, 5, 9), (2,), (8, 9, 10, 11), ()],
                         ids=["mixed", "one", "all_parity", "none"])
@pytest.mark.parametrize("backend", BACKENDS)
def test_decode_stream_bitwise(erased, backend):
    spec = CodeSpec(kind="rs", K=8, R=4)
    rng = np.random.default_rng(6)
    x = f.rand((8, 45), rng)
    cw = np.concatenate([x % f.q, Encoder.plan(spec, backend="local").run(x)])
    plan = Decoder.plan(spec, erased=erased, backend=backend)
    v = cw[list(plan.kept)]
    ref = plan.run(v)
    got = np.concatenate(list(plan.run_stream(v, chunk_w=16)), axis=1)
    assert np.array_equal(ref, got)
    outs = plan.run_batched([v[:, :10], v[:, 10:]], chunk_w=16)
    assert np.array_equal(np.concatenate(outs, axis=1), ref)


# ---------------- property tests (hypothesis-gated) -------------------------

@settings(max_examples=15, deadline=None)
@given(data=st.data())
def test_ragged_chunks_and_erasures_property(data):
    """Any ragged chunking of any |E| <= R erasure pattern decodes (and
    encodes) bitwise-identically to the whole-W run."""
    spec = CodeSpec(kind="rs", K=8, R=4)
    W = data.draw(st.integers(min_value=1, max_value=40), label="W")
    # ragged split of [0, W)
    cuts = data.draw(st.lists(st.integers(min_value=1, max_value=W),
                              max_size=4, unique=True), label="cuts")
    bounds = sorted({0, W, *cuts})
    n_erased = data.draw(st.integers(min_value=0, max_value=4), label="|E|")
    erased = tuple(data.draw(
        st.permutations(list(range(12))), label="perm")[:n_erased])
    rng = np.random.default_rng(W * 37 + n_erased)
    x = f.rand((8, W), rng)

    enc = Encoder.plan(spec, backend="local")
    ref = enc.run(x)
    chunks = [x[:, a:b] for a, b in zip(bounds, bounds[1:])]
    assert np.array_equal(
        np.concatenate(list(enc.run_stream(chunks)), axis=1), ref)

    dec = Decoder.plan(spec, erased=erased, backend="local")
    v = np.concatenate([x % f.q, ref])[list(dec.kept)]
    dref = dec.run(v)
    dgot = np.concatenate(
        list(dec.run_stream([v[:, a:b] for a, b in zip(bounds, bounds[1:])])),
        axis=1)
    assert np.array_equal(dref, dgot)


# ---------------- end-to-end surfaces ---------------------------------------

def test_checkpoint_streamed_roundtrip_degraded(tmp_path):
    """Streamed save (parity memmaps) + streamed degraded restore recover
    the exact state, with chunk_w forcing many chunks."""
    from repro.ckpt import CodedCheckpointer

    state = {"w": np.arange(4096, dtype=np.float32).reshape(64, 64),
             "b": np.ones(130, np.float32)}
    ck = CodedCheckpointer(str(tmp_path), n_shards=8, n_parity=4, chunk_w=128)
    ck.save(0, state)
    d = tmp_path / "step_000000"
    (d / "shard_001.npy").unlink()
    (d / "shard_004.npy").unlink()
    (d / "parity_000.npy").unlink()
    rec = ck.restore(0, state)
    assert np.array_equal(rec["w"], state["w"])
    assert np.array_equal(rec["b"], state["b"])


def test_coding_queue_coalesces_bitwise():
    import threading

    from repro.launch.coding_queue import CodingQueue

    spec = CodeSpec(kind="rs", K=8, R=4)
    rng = np.random.default_rng(8)
    enc = Encoder.plan(spec, backend="local")
    erased = (0, 3)
    dec = Decoder.plan(spec, erased=erased, backend="local")

    q = CodingQueue(backend="local", chunk_w=128)
    payloads = [f.rand((8, int(w)), rng) for w in rng.integers(3, 40, 12)]
    futs = []

    def client(x):
        futs.append(("e", x, q.submit_encode(spec, x)))
        cw = np.concatenate([x % f.q, enc.run(x)])
        v = cw[list(dec.kept)]
        futs.append(("d", v, q.submit_decode(spec, erased, v)))

    threads = [threading.Thread(target=client, args=(x,)) for x in payloads]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for op, payload, fut in futs:
        ref = (enc if op == "e" else dec).run(payload)
        assert np.array_equal(fut.result(timeout=60), ref)
    q.close()
    assert q.stats.requests == 24
    assert q.stats.batches <= q.stats.requests  # some coalescing happened
    with pytest.raises(RuntimeError):
        q.submit_encode(spec, payloads[0])
