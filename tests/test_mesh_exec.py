"""Multi-device shard_map execution tests (subprocess: needs 8 devices)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


@pytest.mark.slow
def test_mesh_checks_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(REPO / "tests" / "mesh_checks.py")],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MESH_CHECKS_OK" in proc.stdout
