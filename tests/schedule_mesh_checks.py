"""Generic schedule-IR mesh lowering checks on 16 forced host devices
(subprocess companion of test_schedule.py — jax locks the device count at
first init).

A `commute=True` plan's rewritten `RoundIR` no longer matches the
hand-built mesh table paths, so `api.backends.build_mesh_callable` lowers
it generically (`core.shardmap_exec.build_ir_mesh_program` /
`mesh_ir_encode`): per-round ppermute legs + combine layers.  Asserts the
generic path is bitwise-identical to the simulator oracle on flat AND
TieredAxis meshes, for rs/lagrange/universal schedules at p=1 and p=2.

Prints 'SCHEDULE_MESH_CHECKS_OK' on success; any failure is fatal.
"""
from _fake_devices import force_host_devices

force_host_devices(16)

import numpy as np  # noqa: E402

from repro.api.planner import Encoder  # noqa: E402
from repro.api.spec import CodeSpec  # noqa: E402
from repro.topo import Topology, place  # noqa: E402

RNG = np.random.default_rng(7)


def check(spec, topo, method="auto", W=3, expect_fired=True):
    pl = place(spec, topo, "affinity")
    sim = Encoder.plan(spec, backend="simulator", method=method, topology=pl,
                       commute=True)
    mesh = Encoder.plan(spec, backend="mesh", method=method, topology=pl,
                        commute=True)
    assert mesh.schedule_ir().digest() == sim.schedule_ir().digest()
    x = RNG.integers(0, spec.field.q, (spec.K, W), dtype=np.int64)
    y_sim, y_mesh = sim.run(x), mesh.run(x)
    assert np.array_equal(y_sim, y_mesh), (spec, topo, method)
    fired = any(r.tag.startswith("commute")
                for r in mesh.schedule_ir().rounds)
    if expect_fired:   # some placements are already inter-optimal: the
        assert fired, (spec, topo)  # rewrite then correctly stays a no-op
    label = "tiered" if spec.K % topo.hosts == 0 and topo.hosts > 1 \
        else "flat"
    print(f"  ir-mesh[{spec.kind} K={spec.K} R={spec.R} p={spec.p} "
          f"{method} {label} commuted={fired}]: mesh == simulator")


def main():
    t54 = Topology(5, 4)   # 5 !| 16 -> flat mesh axis
    t45 = Topology(4, 5)   # 4  | 16 -> TieredAxis (4 x 4) mesh
    check(CodeSpec("rs", 16, 4), t54)
    check(CodeSpec("rs", 16, 4, p=2), t54)
    check(CodeSpec("lagrange", 16, 4), t54)
    check(CodeSpec("rs", 16, 4), t54, method="universal")
    check(CodeSpec("rs", 16, 4), t45, expect_fired=False)
    check(CodeSpec("rs", 16, 4), t45, method="universal", W=1,
          expect_fired=False)

    # canonical (commute=False) TieredAxis plan still takes the table fast
    # path; cross-check the two lowerings against each other once
    spec = CodeSpec("rs", 16, 4)
    pl = place(spec, t45, "affinity")
    x = RNG.integers(0, spec.field.q, (spec.K, 3), dtype=np.int64)
    y_tab = Encoder.plan(spec, backend="mesh", topology=pl).run(x)
    y_ir = Encoder.plan(spec, backend="mesh", topology=pl,
                        commute=True).run(x)
    assert np.array_equal(y_tab, y_ir)
    print("  ir-mesh[table path vs generic path]: identical outputs")
    print("SCHEDULE_MESH_CHECKS_OK")


if __name__ == "__main__":
    main()
