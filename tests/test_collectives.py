"""Broadcast/reduce primitives (Defs. 2-3, App. A) and structured points."""
import numpy as np
import pytest
from conftest_hypothesis import given, settings, st

from repro.core import FERMAT, RoundNetwork
from repro.core.collectives import broadcast, cost_broadcast, reduce
from repro.core.matrices import StructuredPoints, digit_reverse, digits


@pytest.mark.parametrize("N,p", [(2, 1), (5, 1), (16, 1), (9, 2), (27, 2), (7, 3)])
def test_broadcast_reaches_all_with_optimal_rounds(N, p):
    f = FERMAT
    val = f.arr(np.arange(4) + 7)
    out = {}
    net = RoundNetwork(N, p)
    net.run(broadcast(f, val, list(range(N)), p, out))
    assert all(np.array_equal(out[i], val) for i in range(N))
    c1, c2 = cost_broadcast(N, p, W=4)
    assert net.C1 == c1  # (p+1)-nomial optimum
    assert net.C2 == c2


@pytest.mark.parametrize("N,p", [(2, 1), (8, 1), (11, 1), (9, 2), (10, 3)])
def test_reduce_sums_to_root(N, p):
    f = FERMAT
    rng = np.random.default_rng(N)
    vals = {i: f.rand(3, rng) for i in range(N)}
    out = {}
    net = RoundNetwork(N, p)
    net.run(reduce(f, vals, list(range(N)), p, out))
    expected = np.zeros(3, np.int64)
    for v in vals.values():
        expected = f.add(expected, v)
    assert np.array_equal(out[0], expected)
    assert net.C1 == cost_broadcast(N, p)[0]  # dual of broadcast


def test_reduce_on_arbitrary_proc_ids():
    """Framework uses reduce over non-contiguous global processor ids."""
    f = FERMAT
    procs = [12, 3, 44, 7]
    vals = {g: f.arr([g]) for g in procs}
    out = {}
    RoundNetwork(64, 1).run(reduce(f, vals, procs, 1, out))
    assert out[12] == (12 + 3 + 44 + 7) % f.q


@given(k=st.integers(0, 3**5 - 1))
@settings(max_examples=50, deadline=None)
def test_digit_reverse_involution(k):
    assert digit_reverse(digit_reverse(k, 3, 5), 3, 5) == k
    ds = digits(k, 3, 5)
    assert sum(d * 3**i for i, d in enumerate(ds)) == k


@pytest.mark.parametrize("K,P", [(16, 2), (24, 2), (64, 4), (48, 2)])
def test_structured_points_distinct_and_reconstructible(K, P):
    sp = StructuredPoints.build(FERMAT, K, P=P)
    pts = sp.points()
    assert len(set(pts.tolist())) == K  # footnote 3: all distinct
    assert sp.M * sp.Z == K
    # zeta is a primitive Z-th root
    if sp.Z > 1:
        assert pow(sp.zeta, sp.Z, FERMAT.q) == 1
        assert pow(sp.zeta, sp.Z // 2, FERMAT.q) != 1


def test_structured_points_max_h_cap():
    sp = StructuredPoints.build(FERMAT, 64, P=2, max_h=2)
    assert sp.Z == 4 and sp.M == 16
