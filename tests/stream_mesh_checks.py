"""Mesh-backend streaming parity on 8 forced host devices (subprocess
companion of test_stream.py — jax locks the device count at first init,
so the main pytest process cannot host these).

`plan.run_stream` / `plan.run_batched` on backend="mesh" must be
bitwise-identical to the simulator's whole-W `run` for encode (rs + dft)
and decode (several erasure patterns), reusing the plan's compiled
shard_map executables across chunks.

Prints 'STREAM_MESH_CHECKS_OK' on success; any assertion failure is fatal.
"""
from _fake_devices import force_host_devices

force_host_devices(8)

import numpy as np

from repro.api import CodeSpec, Encoder
from repro.core.field import FERMAT
from repro.recover import Decoder

f = FERMAT
rng = np.random.default_rng(21)

for kind, K, R in [("rs", 8, 4), ("dft", 8, 8)]:
    spec = CodeSpec(kind=kind, K=K, R=R)
    x = f.rand((K, 150), rng)
    ref = Encoder.plan(spec, backend="simulator").run(x)
    mesh = Encoder.plan(spec, backend="mesh")
    got = np.concatenate(list(mesh.run_stream(x, chunk_w=64)), axis=1)
    assert np.array_equal(ref, got), (kind, "run_stream")
    outs = mesh.run_batched([x[:, :13], x[:, 13], x[:, 14:]])
    assert np.array_equal(outs[0], ref[:, :13]), (kind, "batched0")
    assert np.array_equal(outs[1], ref[:, 13]), (kind, "batched1")
    assert np.array_equal(outs[2], ref[:, 14:]), (kind, "batched2")
    print(f"mesh encode stream {kind} K={K} R={R}: bitwise == simulator")

spec = CodeSpec(kind="rs", K=8, R=4)
x = f.rand((8, 150), rng)
cw = np.concatenate([x % f.q, Encoder.plan(spec, backend="simulator").run(x)])
for erased in [(0, 9), (1, 2, 3), (4, 8, 10, 11)]:
    d_sim = Decoder.plan(spec, erased=erased, backend="simulator")
    v = cw[list(d_sim.kept)]
    ref = d_sim.run(v)
    d = Decoder.plan(spec, erased=erased, backend="mesh")
    got = np.concatenate(list(d.run_stream(v, chunk_w=64)), axis=1)
    assert np.array_equal(ref, got), (erased, "run_stream")
    outs = d.run_batched([v[:, :50], v[:, 50:]])
    assert np.array_equal(np.concatenate(outs, axis=1), ref), (erased, "batched")
    print(f"mesh decode stream E={erased}: bitwise == simulator")

print("STREAM_MESH_CHECKS_OK")
