"""Roofline infrastructure: HLO census parser, cost model, dry-run helpers."""
import jax
import jax.numpy as jnp

from repro.core.cost_model import (
    framework, gather_encode_scatter, lower_bound_c1, lower_bound_c2,
    multireduce_jeong, universal,
)
from repro.launch.hlo_cost import analyze


def test_hlo_census_scales_while_loops():
    """cost_analysis counts while bodies once; our census multiplies by the
    recovered trip count (the whole point of hlo_cost.py)."""
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y

    n, d, L = 64, 128, 7
    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((n, d), jnp.float32),
        jax.ShapeDtypeStruct((L, d, d), jnp.float32)).compile()
    census = analyze(c.as_text())
    expected = L * 2 * n * d * d
    assert abs(census["flops"] - expected) / expected < 0.05
    xla = c.cost_analysis()
    xla = xla[0] if isinstance(xla, (list, tuple)) else xla
    assert float(xla.get("flops", 0)) < expected / 2  # XLA undercounts


def test_hlo_census_nested_scan():
    def f(x, w):
        def outer(c, wi):
            def inner(ci, _):
                return jnp.tanh(ci @ wi), None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, x, w)
        return y

    n, d, L = 32, 64, 4
    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((n, d), jnp.float32),
        jax.ShapeDtypeStruct((L, d, d), jnp.float32)).compile()
    census = analyze(c.as_text())
    expected = L * 3 * 2 * n * d * d
    assert abs(census["flops"] - expected) / expected < 0.1


def test_hlo_census_counts_collectives(tmp_path):
    import os
    import subprocess
    import sys
    from pathlib import Path

    script = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hlo_cost import analyze
kw = ({'axis_types': (jax.sharding.AxisType.Auto,)}
      if hasattr(jax.sharding, 'AxisType') else {})
mesh = jax.make_mesh((4,), ('d',), **kw)
def g(x, w):
    return x @ w
xs = NamedSharding(mesh, P(None, 'd'))
ws = NamedSharding(mesh, P('d', None))
c = jax.jit(g, in_shardings=(xs, ws), out_shardings=NamedSharding(mesh, P())).lower(
    jax.ShapeDtypeStruct((64, 256), jnp.float32),
    jax.ShapeDtypeStruct((256, 64), jnp.float32)).compile()
r = analyze(c.as_text())
# f32 AR of (64,64): 16384 bytes * 2 * 3/4 = 24576
assert abs(r['collective_bytes'] - 24576) < 1, r['collective_bytes']
print('COLLECTIVE_CENSUS_OK')
"""
    p = tmp_path / "check.py"
    p.write_text(script)
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parent.parent / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, str(p)], capture_output=True,
                          text=True, env=env, timeout=300)
    assert "COLLECTIVE_CENSUS_OK" in proc.stdout, proc.stderr[-2000:]


def test_cost_model_bounds_and_baselines():
    for K in (16, 64, 256):
        u = universal(K, 1)
        assert u.C1 == lower_bound_c1(K, 1)
        assert u.C2 >= lower_bound_c2(K, 1) - 1
    mr = multireduce_jeong(256, 16, 1)
    ours = framework(256, 16, 1, universal(16, 1))
    assert mr.C2 - ours.C2 == round(max(0, 16 - 2 * 4 - 1))
    gs = gather_encode_scatter(256, 16, 1)
    assert gs.C2 > ours.C2  # centralized strawman loses


def test_model_flops_and_active_params():
    from repro.configs import get_config, get_shape
    from repro.launch.dryrun import active_params, model_flops

    # kimi: ~1T total, ~32B active (the config's own name says a32b)
    total, active = active_params(get_config("kimi_k2_1t_a32b"))
    assert 0.9e12 < total < 1.3e12, total
    assert 25e9 < active < 40e9, active
    # qwen3-14b ~ 14B
    total, _ = active_params(get_config("qwen3_14b"))
    assert 12e9 < total < 16e9, total
    # mamba2 ~ 780M
    total, _ = active_params(get_config("mamba2_780m"))
    assert 0.6e9 < total < 1.0e9, total
    # train flops = 3x prefill flops for same token count
    c = get_config("qwen3_14b")
    t = model_flops(c, get_shape("train_4k"))
    p = model_flops(c, get_shape("prefill_32k"))
    tokens_t = 4096 * 256
    tokens_p = 32768 * 32
    assert abs(t / tokens_t / (p / tokens_p) - 3.0) < 1e-6


def test_sharding_guard():
    from jax.sharding import PartitionSpec as P

    from repro.dist.sharding import guard

    sizes = {"data": 16, "model": 16, "pod": 2}
    # divisible: kept
    assert guard(P("model", None), (32, 7), sizes) == P("model", None)
    # non-divisible: dropped
    assert guard(P("model"), (30,), sizes) == P(None)
    # tuple axes
    assert guard(P(("pod", "data")), (64,), sizes) == P(("pod", "data"))
    assert guard(P(("pod", "data")), (33,), sizes) == P(None)
