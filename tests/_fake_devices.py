"""Shared setup for the subprocess mesh-check scripts: force N fake host
devices BEFORE jax initializes.

jax locks the platform device count at first initialization, so every
`tests/*_mesh_checks.py` script must set XLA_FLAGS as its very first act
— before anything imports jax.  Call `force_host_devices()` at the top of
the script, ahead of any repro/jax import:

    from _fake_devices import force_host_devices

    force_host_devices(8)

Raises if jax is already initialized (the flag would be silently
ineffective — exactly the bug this helper exists to prevent).
"""
import os
import sys


def force_host_devices(n: int = 8) -> None:
    if "jax" in sys.modules:
        raise RuntimeError(
            "force_host_devices() must run before jax is imported — move "
            "the call above every repro/jax import")
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n} "
        + os.environ.get("XLA_FLAGS", "")
    )
