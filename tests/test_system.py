"""CodedSystem session API + the Backend protocol/registry.

Covers the registry lifecycle (register a dummy backend, plan and execute
through it end-to-end, capability errors for unsupported (spec, backend)
pairs), the fail -> degraded-read -> heal -> encode round-trip on the
in-process backends for all four code kinds (the mesh leg runs in
`system_mesh_checks.py` on 8 forced host devices), the thread-safety of
the per-run stats, and the coordinated cache clear."""
import os
import subprocess
import sys
import threading
from pathlib import Path

import numpy as np
import pytest

from repro.api import (
    Backend,
    BackendCapabilityError,
    CodedSystem,
    CodeSpec,
    Encoder,
    LinkModel,
    available_backends,
    cache_clear,
    cache_info,
    register_backend,
    unregister_backend,
)
from repro.core.field import FERMAT
from repro.recover import Decoder

REPO = Path(__file__).resolve().parent.parent
RNG = np.random.default_rng(23)

# (kind, K, R, erasure pattern) — patterns mix data and parity positions;
# the dft pattern is one of the decodable ones (the transform is not MDS)
CASES = [
    ("universal", 8, 4, (0, 9)),
    ("rs", 8, 4, (2, 4, 11)),
    ("lagrange", 8, 4, (1, 10)),
    ("dft", 8, 8, (5, 9, 13)),
]


def _spec(kind, K, R, **kw):
    if kind == "universal":
        kw.setdefault("seed", 5)
    return CodeSpec(kind=kind, K=K, R=R, **kw)


# ---------------------------------------------------------------------------
# fail -> degraded read -> heal -> encode round-trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,K,R,erased", CASES)
def test_round_trip_bitwise_across_backends(kind, K, R, erased):
    spec = _spec(kind, K, R)
    x = FERMAT.rand((K, 5), RNG)
    outs = {}
    for backend in ("simulator", "local"):
        system = CodedSystem(spec, backend=backend)
        cw = system.codeword(x)
        system.fail(erased)
        assert system.failed == tuple(sorted(erased))
        lost = system.decode(cw)                  # recompute erased symbols
        data = system.read(cw)                    # full degraded read
        assert np.array_equal(lost, cw[list(sorted(erased))]), backend
        assert np.array_equal(data, x % FERMAT.q), backend
        system.heal()
        assert system.failed == () and system.kept == tuple(range(K))
        assert np.array_equal(system.encode(x), cw[K:]), backend
        outs[backend] = (cw, lost, data)
    a, b = outs["simulator"], outs["local"]
    for ya, yb in zip(a, b):
        assert np.array_equal(ya, yb)


def test_read_accepts_codeword_or_survivor_rows():
    spec = _spec("rs", 8, 4)
    system = CodedSystem(spec, backend="simulator").fail((0, 1))
    x = FERMAT.rand((8, 3), RNG)
    cw = system.codeword(x)
    v = cw[list(system.kept)]
    assert np.array_equal(system.read(cw), system.read(v))
    assert np.array_equal(system.decode(cw), system.decode(v))
    with pytest.raises(ValueError):
        system.read(cw[:5])  # neither N nor K rows


def test_fail_heal_state_machine():
    system = CodedSystem(_spec("rs", 8, 4), backend="simulator")
    system.fail(2).fail((3, 9))
    assert system.failed == (2, 3, 9)
    with pytest.raises(ValueError):
        system.fail((4, 5))  # 5 failures > R=4
    with pytest.raises(ValueError):
        system.fail(12)      # outside [0, N)
    with pytest.raises(ValueError):
        system.heal(12)      # heal validates the same range as fail
    system.heal(3)
    assert system.failed == (2, 9)
    system.heal()
    assert system.failed == ()
    # incremental failures replan the decode side automatically
    system.fail(0)
    assert system.decode_plan.erased == (0,)
    system.fail(1)
    assert system.decode_plan.erased == (0, 1)


def test_healthy_read_and_empty_decode():
    system = CodedSystem(_spec("rs", 8, 4), backend="simulator")
    x = FERMAT.rand((8, 2), RNG)
    cw = system.codeword(x)
    assert np.array_equal(system.read(cw), x % FERMAT.q)
    assert system.decode(cw).shape == (0, 2)


def test_streams_and_batched_through_system():
    spec = _spec("rs", 8, 4, W=64)
    system = CodedSystem(spec, backend="local", chunk_w=128)
    x = FERMAT.rand((8, 300), RNG)
    cw = system.codeword(x)
    got = np.concatenate(list(system.encode_stream(x)), axis=1)
    assert np.array_equal(got, cw[8:])
    outs = system.encode_batched([x[:, :10], x[:, 10:]])
    assert np.array_equal(np.concatenate(outs, axis=1), cw[8:])
    system.fail((2, 11))
    rep = np.concatenate(list(system.decode_stream(cw)), axis=1)
    assert np.array_equal(rep, system.decode(cw))
    # chunked decode stream accepts (N, w) codeword chunks too
    rep2 = np.concatenate(
        list(system.decode_stream(cw[:, i : i + 77] for i in range(0, 300, 77))),
        axis=1)
    assert np.array_equal(rep2, rep)


def test_submit_futures_roundtrip():
    spec = _spec("rs", 8, 4)
    with CodedSystem(spec, backend="local") as system:
        x = FERMAT.rand((8, 17), RNG)
        cw = system.codeword(x)
        system.fail((0, 9))
        fe = system.submit("encode", x)
        fd = system.submit("decode", cw)
        assert np.array_equal(fe.result(timeout=60), cw[8:])
        assert np.array_equal(fd.result(timeout=60), system.decode(cw))
        with pytest.raises(ValueError):
            system.submit("transmogrify", x)
        stats = system.stats()
        assert stats["queue"].requests == 2
    # context exit drained the queue; a later submit opens a fresh one
    fut = system.submit("encode", x)
    assert np.array_equal(fut.result(timeout=60), cw[8:])
    system.close()


def test_submit_preserves_explicit_matrix():
    """The queue must plan with the session's explicit generator block —
    and same-spec requests carrying different matrices must not coalesce
    into one plan (the A digest is part of the group key)."""
    spec = CodeSpec(kind="universal", K=8, R=4)
    A1, A2 = FERMAT.rand((8, 4), RNG), FERMAT.rand((8, 4), RNG)
    x = FERMAT.rand((8, 9), RNG)
    s2 = CodedSystem(spec, backend="local", A=A2)
    with CodedSystem(spec, backend="local", A=A1) as s1:
        f1 = s1.submit("encode", x)
        assert np.array_equal(f1.result(timeout=60), s1.encode(x))
        cw = s1.codeword(x)
        s1.fail((0, 9))
        fd = s1.submit("decode", cw)
        assert np.array_equal(fd.result(timeout=60), s1.decode(cw))
    # ONE queue, two matrices over the same spec: per-A group keys keep
    # them on their own plans
    from repro.launch.coding_queue import CodingQueue

    q = CodingQueue(backend="local")
    fa, fb = q.submit_encode(spec, x, A=A1), q.submit_encode(spec, x, A=A2)
    ra, rb = fa.result(timeout=60), fb.result(timeout=60)
    q.close()
    assert np.array_equal(ra, s1.heal().encode(x))
    assert np.array_equal(rb, s2.encode(x))
    assert not np.array_equal(ra, rb)
    s2.close()


def test_lagrange_system_submit_uses_session_matrix():
    """Arbitrary interpolation points only exist on the session's A —
    queued submission must not replan from the bare spec (which would
    build the structured code or fail its K | R assertion)."""
    from repro.coding import LagrangeComputer

    lcc = LagrangeComputer.build(FERMAT, K=5, N=16)
    x = FERMAT.rand((5, 4), RNG)
    system = lcc.system()
    try:
        fut = system.submit("encode", x)
        assert np.array_equal(fut.result(timeout=60), lcc.encode(x))
    finally:
        system.close()


def test_stats_and_describe():
    system = CodedSystem(_spec("rs", 8, 4), backend="simulator",
                         link=LinkModel())
    x = FERMAT.rand((8, 2), RNG)
    cw = system.codeword(x)
    st = system.stats()
    assert st["failed"] == () and "decode" not in st
    assert st["encode"]["last"].C1 > 0          # measured by the simulator
    assert st["encode"]["model_us"] > 0
    assert {"encode", "decode"} <= set(st["cache"])
    system.fail((1, 8))
    system.read(cw)
    st = system.stats()
    assert st["decode"]["erased"] == (1, 8)
    text = system.describe()
    assert "CodedSystem[rs]" in text and "failed  : [1, 8]" in text
    assert "DecodePlan" in text and "EncodePlan" in text


# ---------------------------------------------------------------------------
# Backend registry lifecycle
# ---------------------------------------------------------------------------

class _HostMatmulBackend(Backend):
    """Dummy third-party executor: exact host matmuls, any modulus."""

    def encode(self, plan, x):
        return plan.field.matmul(plan.A.T, x)

    def decode(self, plan, v):
        return plan.field.matmul(plan.tables.D.T, v)


def test_registered_dummy_backend_end_to_end():
    register_backend("dummy-host", _HostMatmulBackend)
    try:
        assert "dummy-host" in available_backends()
        spec = _spec("rs", 8, 4)
        x = FERMAT.rand((8, 6), RNG)
        system = CodedSystem(spec, backend="dummy-host")
        ref = CodedSystem(spec, backend="simulator")
        cw = system.codeword(x)
        assert np.array_equal(cw, ref.codeword(x))
        system.fail((2, 3))
        ref.fail((2, 3))
        assert np.array_equal(system.decode(cw), ref.decode(cw))
        assert np.array_equal(system.read(cw), x % FERMAT.q)
        # streaming falls back to bitwise per-chunk execution
        got = np.concatenate(list(system.encode_stream(x, chunk_w=2)), axis=1)
        assert np.array_equal(got, cw[8:])
        # the planner layer sees it too
        assert Encoder.plan(spec, backend="dummy-host").backend == "dummy-host"
    finally:
        unregister_backend("dummy-host")
    assert "dummy-host" not in available_backends()
    with pytest.raises(ValueError, match="unknown backend"):
        Encoder.plan(_spec("rs", 8, 4), backend="dummy-host")


def test_register_refuses_silent_shadowing():
    with pytest.raises(ValueError, match="already registered"):
        register_backend("simulator", _HostMatmulBackend)
    register_backend("dummy-twice", _HostMatmulBackend)
    try:
        with pytest.raises(ValueError, match="already registered"):
            register_backend("dummy-twice", _HostMatmulBackend)
        register_backend("dummy-twice", _HostMatmulBackend, overwrite=True)
    finally:
        unregister_backend("dummy-twice")


def test_capability_errors_at_plan_time():
    # non-Fermat modulus on the uint32 kernel backends
    spec7681 = CodeSpec(kind="rs", K=8, R=4, q=7681)
    for backend in ("local", "mesh"):
        with pytest.raises(BackendCapabilityError, match="Fermat"):
            Encoder.plan(spec7681, backend=backend)
        with pytest.raises(BackendCapabilityError):
            Decoder.plan(spec7681, erased=(0,), backend=backend)
        with pytest.raises(BackendCapabilityError):
            CodedSystem(spec7681, backend=backend)
    # mesh encode needs the R | K framework grid...
    with pytest.raises(BackendCapabilityError, match=r"R \| K"):
        Encoder.plan(CodeSpec(kind="universal", K=8, R=3, seed=1),
                     backend="mesh")
    # ...and one device per source (declared requirement, checked at plan
    # time instead of erroring deep inside shard_map)
    import jax

    if len(jax.devices()) < 4096:
        with pytest.raises(BackendCapabilityError, match="devices"):
            Encoder.plan(CodeSpec(kind="rs", K=4096, R=512), backend="mesh")
    # a backend that implements neither op refuses execution clearly
    register_backend("dummy-inert", Backend)
    try:
        plan = Encoder.plan(_spec("rs", 8, 4), backend="dummy-inert")
        with pytest.raises(BackendCapabilityError, match="encode"):
            plan.run(FERMAT.rand((8, 2), RNG))
    finally:
        unregister_backend("dummy-inert")


# ---------------------------------------------------------------------------
# thread-safe per-run stats (the old plan.sim_net race)
# ---------------------------------------------------------------------------

def test_last_stats_thread_local_on_shared_plan():
    spec = _spec("rs", 8, 4)
    plan = Encoder.plan(spec, backend="simulator")
    widths = {"a": 1, "b": 7}
    expected = {}
    for key, w in widths.items():
        plan.run(FERMAT.rand((8, w), RNG))
        expected[key] = (plan.last_stats.C1, plan.last_stats.C2)
    assert expected["a"][1] != expected["b"][1]  # C2 scales with width

    errors = []
    barrier = threading.Barrier(2)

    def worker(key):
        w = widths[key]
        try:
            for _ in range(10):
                barrier.wait(timeout=30)
                plan.run(FERMAT.rand((8, w), RNG))
                got = (plan.last_stats.C1, plan.last_stats.C2)
                if got != expected[key]:
                    errors.append((key, got, expected[key]))
                assert plan.sim_net.C2 == expected[key][1]
        except Exception as exc:  # noqa: BLE001 — surface in main thread
            errors.append((key, repr(exc)))

    threads = [threading.Thread(target=worker, args=(k,)) for k in widths]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not errors, errors[:4]


def test_run_stats_carry_op_and_backend():
    system = CodedSystem(_spec("rs", 8, 4), backend="simulator")
    x = FERMAT.rand((8, 2), RNG)
    cw = system.codeword(x)
    assert system.encode_plan.last_stats.op == "encode"
    system.fail((0,))
    system.decode(cw)
    assert system.decode_plan.last_stats.op == "decode"
    assert system.decode_plan.last_stats.backend == "simulator"
    # kernel backends measure nothing (and must not inherit stale stats)
    local = CodedSystem(_spec("rs", 8, 4), backend="local")
    local.encode(x)
    assert local.encode_plan.last_stats is None


# ---------------------------------------------------------------------------
# coordinated cache clear
# ---------------------------------------------------------------------------

def test_cache_clear_clears_both_stacks():
    cache_clear()
    system = CodedSystem(_spec("rs", 8, 4), backend="simulator")
    x = FERMAT.rand((8, 2), RNG)
    cw = system.codeword(x)
    system.fail((0, 1))
    system.read(cw)
    info = cache_info()
    assert info["encode"]["plans"] >= 1 and info["decode"]["plans"] >= 1
    # Encoder.cache_clear is the same coordinated entry point: no decode
    # plan may survive holding references into dropped host tables
    Encoder.cache_clear()
    info = cache_info()
    assert info["encode"]["plans"] == 0 and info["encode"]["tables"] == 0
    assert info["decode"]["plans"] == 0 and info["decode"]["tables"] == 0
    # Decoder-only clear remains decode-scoped (safe direction)
    system2 = CodedSystem(_spec("rs", 8, 4), backend="simulator")
    system2.fail((0,))
    system2.read(system2.codeword(x))
    Decoder.cache_clear()
    info = cache_info()
    assert info["decode"]["plans"] == 0
    assert info["encode"]["plans"] >= 1


# ---------------------------------------------------------------------------
# mesh leg (8 forced host devices, subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_system_round_trip_mesh_subprocess():
    """encode -> fail -> read -> heal bitwise across all three built-in
    backends, mesh included, on 8 forced host devices."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(REPO / "tests" / "system_mesh_checks.py")],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SYSTEM_MESH_CHECKS_OK" in proc.stdout
