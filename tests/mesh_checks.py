"""Multi-device mesh checks, run in a subprocess with 8 host devices
(jax locks the device count at first init, so the main pytest process —
which must see 1 device for the smoke tests — cannot host these).

Prints 'MESH_CHECKS_OK' on success; any assertion failure is fatal.
"""
from _fake_devices import force_host_devices

force_host_devices(8)

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.field import FERMAT
from repro.core.matrices import permuted_dft_matrix
from repro.core.parity import build_parity_tables, mesh_parity_encode, reconstruct
from repro.core.shardmap_exec import (
    build_dft_tables,
    build_universal_tables,
    mesh_dft,
    mesh_universal_a2a,
    shard_map,
)

f = FERMAT
rng = np.random.default_rng(123)
N, W = 8, 16
mesh = Mesh(np.array(jax.devices()), ("d",))
x = f.rand((N, W), rng).astype(np.uint32)


def run_sharded(body, arrs: dict):
    keys = list(arrs)

    @partial(shard_map, mesh=mesh,
             in_specs=(P("d"),) + tuple(P("d") for _ in keys),
             out_specs=P("d"))
    def step(xb, *tb):
        rows = {k: v[0] for k, v in zip(keys, tb)}
        return body(xb[0], rows)[None]

    return np.asarray(step(jnp.asarray(x), *[jnp.asarray(arrs[k]) for k in keys]))


# ---- universal A2A, full axis and groups, p in {1, 2} ----------------------
for p in (1, 2):
    C = f.rand((N, N), rng)
    t = build_universal_tables(f, [C], N, p=p)
    y = run_sharded(
        lambda v, rows: mesh_universal_a2a(v, rows["coef"], rows["corr"], t, "d"),
        {"coef": t.coef, "corr": t.corr},
    )
    assert np.array_equal(y, f.matmul(C.T, x.astype(np.int64))), f"universal p={p}"

C0, C1 = f.rand((4, 4), rng), f.rand((4, 4), rng)
tg = build_universal_tables(f, [C0, C1], N, p=1, group_stride=1)
y = run_sharded(
    lambda v, rows: mesh_universal_a2a(v, rows["coef"], rows["corr"], tg, "d"),
    {"coef": tg.coef, "corr": tg.corr},
)
exp = np.concatenate([f.matmul(C0.T, x[:4].astype(np.int64)),
                      f.matmul(C1.T, x[4:].astype(np.int64))])
assert np.array_equal(y, exp), "grouped universal"

# ---- DFT (Cor. 1 optimal path) + inverse -----------------------------------
td = build_dft_tables(f, N, 8)
y = run_sharded(lambda v, rows: mesh_dft(v, rows["ca"], rows["cb"], td, "d"),
                {"ca": td.ca.T, "cb": td.cb.T})
D = permuted_dft_matrix(f, 8, 2)
assert np.array_equal(y, f.matmul(D.T, x.astype(np.int64))), "dft fwd"
tdi = build_dft_tables(f, N, 8, inverse=True)
xi = x
x_glob = jnp.asarray(y.astype(np.uint32))
keys = ["ca", "cb"]


@partial(shard_map, mesh=mesh, in_specs=(P("d"), P("d"), P("d")), out_specs=P("d"))
def inv_step(xb, ca, cb):
    return mesh_dft(xb[0], ca[0], cb[0], tdi, "d", inverse=True)[None]


back = np.asarray(inv_step(x_glob, jnp.asarray(tdi.ca.T), jnp.asarray(tdi.cb.T)))
assert np.array_equal(back, x.astype(np.int64)), "dft inverse"

# ---- parity encode (both methods) + any-K-of-N restore ---------------------
for R in (2, 4, 8):
    for method in ("universal", "rs"):
        t = build_parity_tables(f, N, R, p=1, method=method)
        arrs = t.device_arrays()
        y = run_sharded(lambda v, rows: mesh_parity_encode(v, rows, t, "d"), arrs)
        A = t.sgrs.grs.A_direct()
        exp = f.matmul(A.T, x.astype(np.int64))
        assert np.array_equal(y[:R], exp), f"parity N={N} R={R} {method}"

t = build_parity_tables(f, N, 4, method="rs")
A = t.sgrs.grs.A_direct()
parity = f.matmul(A.T, x.astype(np.int64))
full = np.concatenate([x.astype(np.int64), parity])
for trial in range(5):
    kept = np.sort(rng.choice(N + 4, N, replace=False))
    rec = reconstruct(f, t.sgrs, kept, full[kept])
    assert np.array_equal(rec, x.astype(np.int64)), f"reconstruct {kept}"

# ---- collective-bytes sanity: specific beats universal in lowered HLO ------
def collective_bytes(fn, *args):
    lowered = jax.jit(fn).lower(*args)
    txt = lowered.compile().as_text()
    import re

    total = 0
    for line in txt.splitlines():
        if "collective-permute" in line and "u32[" in line:
            m = re.findall(r"u32\[([\d,]*)\]", line)
            if m and "=" in line:
                dims = m[0]
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                total += 4 * n
    return total


tu = build_parity_tables(f, N, 4, p=1, method="universal")
tr = build_parity_tables(f, N, 4, p=1, method="rs")


def make_fn(t):
    arrs = t.device_arrays()
    keys = list(arrs)

    @partial(shard_map, mesh=mesh,
             in_specs=(P("d"),) + tuple(P("d") for _ in keys),
             out_specs=P("d"))
    def step(xb, *tb):
        rows = {k: v[0] for k, v in zip(keys, tb)}
        return mesh_parity_encode(xb[0], rows, t, "d")[None]

    def fn(xg):
        return step(xg, *[jnp.asarray(arrs[k]) for k in keys])

    return fn


bu = collective_bytes(make_fn(tu), jnp.asarray(x))
br = collective_bytes(make_fn(tr), jnp.asarray(x))
print(f"collective bytes universal={bu} rs={br}")
assert bu > 0 and br > 0

print("MESH_CHECKS_OK")
