"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and no NaNs; decode-vs-forward
consistency for every family's serve path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, all_configs, cell_applicable, get_config, get_shape
from repro.models import model as M

KEY = jax.random.PRNGKey(0)
ARCHS = [a for a in ARCH_IDS if a != "paper_rs"]


def make_batch(scfg, B=2, S=32):
    batch = {
        "tokens": jax.random.randint(KEY, (B, S), 0, scfg.vocab),
        "labels": jax.random.randint(KEY, (B, S), 0, scfg.vocab),
    }
    if scfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            KEY, (B, scfg.n_patches, scfg.d_model), jnp.float32)
    if scfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            KEY, (B, scfg.n_frames, scfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_shapes(arch):
    scfg = get_config(arch).smoke()
    params = M.init_params(scfg, KEY)
    batch = make_batch(scfg)
    logits = M.forward(scfg, params, batch)
    assert logits.shape == (2, 32, scfg.vocab)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))
    loss = M.loss_fn(scfg, params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    """One SGD step: grads exist for every leaf and loss is finite."""
    scfg = get_config(arch).smoke()
    params = M.init_params(scfg, KEY)
    batch = make_batch(scfg)
    loss, grads = jax.value_and_grad(lambda p: M.loss_fn(scfg, p, batch))(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(not np.any(np.isnan(np.asarray(g, np.float32))) for g in flat)
    # apply and verify loss moves
    new = jax.tree.map(lambda p, g: p - 0.5 * g.astype(p.dtype), params, grads)
    loss2 = M.loss_fn(scfg, new, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    scfg = get_config(arch).smoke()
    params = M.init_params(scfg, KEY)
    B = 2
    batch = make_batch(scfg, B=B)
    enc_out = None
    if scfg.family == "encdec":
        enc_out = M.encode_frames(scfg, params, batch["frames"].astype(jnp.bfloat16))
    cache = M.init_cache(scfg, B, 64, enc_out)
    logits, cache2 = M.decode_step(scfg, params, batch["tokens"][:, 0],
                                   jnp.int32(0), cache, enc_out)
    assert logits.shape == (B, scfg.vocab)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))
    # cache actually updated
    changed = jax.tree.map(lambda a, b: bool(np.any(np.asarray(a) != np.asarray(b))),
                           cache, cache2)
    assert any(jax.tree.leaves(changed))


@pytest.mark.parametrize("arch", ["qwen3_1_7b", "mamba2_780m", "hymba_1_5b",
                                  "phi3_5_moe_42b_a6_6b", "whisper_large_v3"])
def test_decode_matches_forward(arch):
    """Stepwise decode with cache reproduces the full forward logits."""
    scfg = get_config(arch).smoke()
    params = M.init_params(scfg, KEY)
    B, S = 2, 8
    batch = make_batch(scfg, B=B, S=S)
    enc_out = None
    fwd_batch = {"tokens": batch["tokens"]}
    if scfg.family == "encdec":
        enc_out = M.encode_frames(scfg, params, batch["frames"].astype(jnp.bfloat16))
        fwd_batch["frames"] = batch["frames"]
    cache = M.init_cache(scfg, B, 64, enc_out)
    outs = []
    for t in range(S):
        lg, cache = M.decode_step(scfg, params, batch["tokens"][:, t],
                                  jnp.int32(t), cache, enc_out)
        outs.append(lg)
    stepwise = jnp.stack(outs, 1).astype(jnp.float32)
    full = M.forward(scfg, params, fwd_batch).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(stepwise), np.asarray(full),
                               atol=0.15, rtol=0.05)


def test_cell_applicability_matrix():
    """40 cells: long_500k runs only for sub-quadratic archs (DESIGN.md §5)."""
    cfgs = all_configs()
    runnable = 0
    for arch, cfg in cfgs.items():
        for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            ok, why = cell_applicable(cfg, get_shape(shape))
            if shape == "long_500k":
                assert ok == (arch in ("mamba2_780m", "hymba_1_5b")), (arch, why)
            else:
                assert ok
            runnable += ok
    assert runnable == 32  # 30 + 2 long_500k


def test_exact_assigned_configs():
    """The full configs match the assignment table exactly."""
    c = get_config("qwen3_14b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == \
        (40, 5120, 40, 8, 17408, 151936) and c.qk_norm
    c = get_config("kimi_k2_1t_a32b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab,
            c.n_experts, c.top_k) == (61, 7168, 64, 8, 2048, 163840, 384, 8)
    c = get_config("mamba2_780m")
    assert (c.n_layers, c.d_model, c.vocab, c.ssm_state) == (48, 1536, 50280, 128)
    c = get_config("qwen1_5_32b")
    assert c.qkv_bias and c.n_layers == 64 and c.d_ff == 27392
    c = get_config("hymba_1_5b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab,
            c.ssm_state) == (32, 1600, 25, 5, 5504, 32001, 16)
    c = get_config("minicpm_2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab) == \
        (40, 2304, 36, 5760, 122753)
    c = get_config("whisper_large_v3")
    assert c.family == "encdec" and c.d_model == 1280 and c.vocab == 51866
    c = get_config("llava_next_mistral_7b")
    assert c.family == "vlm" and c.d_model == 4096 and c.d_ff == 14336
    c = get_config("phi3_5_moe_42b_a6_6b")
    assert (c.n_experts, c.top_k, c.d_ff) == (16, 2, 6400)
    c = get_config("qwen3_1_7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff) == (28, 2048, 16, 6144)


def test_int8_kv_cache_decode_matches_fp():
    """quantize_kv: greedy decode agrees with the bf16-cache path."""
    import dataclasses

    scfg = dataclasses.replace(get_config("qwen3_1_7b").smoke(), dtype="float32")
    scfgq = dataclasses.replace(scfg, quantize_kv=True)
    params = M.init_params(scfg, KEY)
    B, S = 2, 10
    toks = jax.random.randint(KEY, (B, S), 0, scfg.vocab)
    cf, cq = M.init_cache(scfg, B, 32), M.init_cache(scfgq, B, 32)
    assert cq["k"].dtype == jnp.int8 and "k_scale" in cq
    for t in range(S):
        lf, cf = M.decode_step(scfg, params, toks[:, t], jnp.int32(t), cf)
        lq, cq = M.decode_step(scfgq, params, toks[:, t], jnp.int32(t), cq)
        assert float(jnp.max(jnp.abs(lf - lq))) < 0.05
        assert jnp.array_equal(jnp.argmax(lf, -1), jnp.argmax(lq, -1))


def test_ring_buffer_swa_cache_matches_forward():
    """Sliding-window ring cache (L == window) decode == full forward."""
    import dataclasses

    scfg = dataclasses.replace(get_config("hymba_1_5b").smoke(),
                               sliding_window=8, dtype="float32")
    params = M.init_params(scfg, KEY)
    B, S = 2, 24
    toks = jax.random.randint(KEY, (B, S), 0, scfg.vocab)
    cache = M.init_cache(scfg, B, 64)
    assert cache["k"].shape[2] == 8  # ring length == window
    outs = []
    for t in range(S):
        lg, cache = M.decode_step(scfg, params, toks[:, t], jnp.int32(t), cache)
        outs.append(lg)
    sl = jnp.stack(outs, 1)
    fl = M.forward(scfg, params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(sl), np.asarray(fl), atol=2e-4)
