"""Field arithmetic: numpy oracle path, jnp Fermat uint32 path, packing."""
import numpy as np
import pytest

from conftest_hypothesis import given, settings, st

from repro.core.field import (
    FERMAT,
    FERMAT_Q,
    Field,
    bytes_to_symbols,
    fermat_add,
    fermat_matvec_cols,
    fermat_mul,
    fermat_reduce,
    fermat_sub,
    find_generator,
    is_prime,
    symbols_to_bytes,
)


def test_is_prime():
    assert is_prime(2) and is_prime(65537) and is_prime(12289)
    assert not is_prime(1) and not is_prime(65536) and not is_prime(12288)


def test_generator_order():
    for q in (5, 257, 12289, 65537):
        g = find_generator(q)
        seen = set()
        x = 1
        for _ in range(q - 1):
            x = x * g % q
            seen.add(x)
        assert len(seen) == q - 1


def test_field_basic_ops():
    f = FERMAT
    a = np.array([0, 1, 65535, 65536, 12345])
    b = np.array([65536, 65536, 65536, 65536, 54321])
    assert np.all(f.add(a, b) == (a.astype(object) + b) % f.q)
    assert np.all(f.mul(a, b) == (a.astype(object) * b) % f.q)
    inv = f.inv(np.array([1, 2, 65536]))
    assert np.all(f.mul(np.array([1, 2, 65536]), inv) == 1)


def test_pow_negative_and_zero():
    f = Field(12289)
    assert f.pow(np.int64(5), 0) == 1
    x = np.int64(1234)
    assert f.mul(f.pow(x, 5), f.pow(x, -5)) == 1


def test_matmul_exact_vs_object():
    rng = np.random.default_rng(0)
    f = FERMAT
    a = f.rand((17, 33), rng)
    b = f.rand((33, 9), rng)
    exact = (a.astype(object) @ b.astype(object)) % f.q
    assert np.array_equal(f.matmul(a, b), exact.astype(np.int64))


def test_poly_eval_horner():
    f = FERMAT
    coeffs = np.array([3, 0, 2, 7])  # 3 + 2x^2 + 7x^3
    x = np.array([0, 1, 5])
    expected = (3 + 2 * x.astype(object) ** 2 + 7 * x.astype(object) ** 3) % f.q
    assert np.array_equal(f.poly_eval(coeffs, x), expected.astype(np.int64))


def test_root_of_unity():
    f = FERMAT
    for order in (2, 4, 256, 65536):
        w = f.root_of_unity(order)
        assert pow(w, order, f.q) == 1
        assert pow(w, order // 2, f.q) != 1
    with pytest.raises(ValueError):
        f.root_of_unity(3)  # 3 does not divide 2^16


# ---------------- jnp uint32 Fermat path -----------------------------------

def test_fermat_reduce_full_range_samples():
    import jax.numpy as jnp

    rng = np.random.default_rng(1)
    xs = np.concatenate(
        [rng.integers(0, 1 << 32, 20000, dtype=np.uint64).astype(np.uint32),
         np.array([0, 1, 65536, 65537, 0xFFFFFFFF, 0xFFFF0000], np.uint32)]
    )
    got = np.asarray(fermat_reduce(jnp.asarray(xs)))
    assert np.array_equal(got, xs.astype(np.uint64) % FERMAT_Q)


@given(st.integers(0, FERMAT_Q - 1), st.integers(0, FERMAT_Q - 1))
@settings(max_examples=300, deadline=None)
def test_fermat_mul_matches_bigint(a, b):
    import jax.numpy as jnp

    got = int(fermat_mul(jnp.uint32(a), jnp.uint32(b)))
    assert got == a * b % FERMAT_Q


def test_fermat_mul_overflow_corner():
    import jax.numpy as jnp

    # 65536 == -1 (mod q): the only case where a*b overflows uint32
    assert int(fermat_mul(jnp.uint32(65536), jnp.uint32(65536))) == 1
    assert int(fermat_mul(jnp.uint32(65536), jnp.uint32(12345))) == (65536 * 12345) % FERMAT_Q
    assert int(fermat_mul(jnp.uint32(65536), jnp.uint32(0))) == 0


def test_fermat_add_sub_matvec():
    import jax.numpy as jnp

    rng = np.random.default_rng(2)
    a = rng.integers(0, FERMAT_Q, (5, 64)).astype(np.uint32)
    b = rng.integers(0, FERMAT_Q, (5, 64)).astype(np.uint32)
    assert np.array_equal(np.asarray(fermat_add(jnp.asarray(a), jnp.asarray(b))),
                          (a.astype(np.uint64) + b) % FERMAT_Q)
    assert np.array_equal(np.asarray(fermat_sub(jnp.asarray(a), jnp.asarray(b))),
                          (a.astype(np.int64) - b) % FERMAT_Q)
    c = rng.integers(0, FERMAT_Q, (64, 16)).astype(np.uint32)
    got = np.asarray(fermat_matvec_cols(jnp.asarray(a), jnp.asarray(c)))
    exp = (a.astype(object) @ c.astype(object)) % FERMAT_Q
    assert np.array_equal(got, exp.astype(np.uint32))


@given(st.binary(min_size=0, max_size=257))
@settings(max_examples=100, deadline=None)
def test_byte_symbol_roundtrip(raw):
    raw = np.frombuffer(raw, np.uint8)
    sym = bytes_to_symbols(raw)
    assert np.all(sym < 1 << 16)
    back = symbols_to_bytes(sym, raw.size)
    assert np.array_equal(back, raw)
