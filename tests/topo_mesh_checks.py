"""Hierarchical-mesh parity checks on 8 forced host devices (subprocess
companion of test_topo.py — jax locks the device count at first init).

The tentpole claim for the mesh backend: running a plan on the
(hosts x devices_per_host) hierarchical grid — collectives decomposed
into per-tier ppermute legs by `core.shardmap_exec.TieredAxis` — is
bitwise-identical to the flat single-axis mesh, for all four spec kinds
and for every grid shape whose host count divides K.  Also asserts the
decomposition actually fires tiered legs (dev-axis/host-axis ppermutes,
not just the joint fallback), and that hierarchical plans are cached
separately from flat ones.

Prints 'TOPO_MESH_CHECKS_OK' on success; any assertion failure is fatal.
"""
from _fake_devices import force_host_devices

force_host_devices(8)

import numpy as np  # noqa: E402

from repro.api import CodeSpec, Encoder, Topology  # noqa: E402
from repro.core import shardmap_exec as se  # noqa: E402

RNG = np.random.default_rng(23)


def check_bitwise_parity():
    specs = [
        CodeSpec("universal", 8, 4, W=32, seed=3),
        CodeSpec("rs", 8, 4, W=32),
        CodeSpec("lagrange", 8, 4, W=32),
        CodeSpec("dft", 8, 8, W=32),
    ]
    for spec in specs:
        x = spec.field.rand((spec.K, spec.W), RNG)
        flat_plan = Encoder.plan(spec, backend="mesh")
        flat = flat_plan.run(x)
        sim = Encoder.plan(spec, backend="simulator").run(x)
        assert np.array_equal(flat, sim), spec.kind
        for hosts, dph in ((2, 4), (4, 2)):
            plan = Encoder.plan(spec, backend="mesh",
                                topology=Topology(hosts, dph))
            assert plan is not flat_plan, "topology must key the plan cache"
            y = plan.run(x)
            assert np.array_equal(flat, y), (spec.kind, hosts, dph)
            again = Encoder.plan(spec, backend="mesh",
                                 topology=Topology(hosts, dph))
            assert again is plan, "equal topologies must hit the plan cache"
        print(f"  parity[{spec.kind}]: flat == (2x4) == (4x2) == simulator")


def check_tiered_legs_fire():
    """The (2 x 4) grid must lower rs rounds onto dev- AND host-axis legs
    (phase-1 groups of 4 are host-local, the stride-4 reduce crosses
    hosts) — not route everything through the joint fallback."""
    counts = {"dev": 0, "host": 0, "joint": 0}
    orig = se._tiered_ppermute

    def spy(x, axis, perm):
        dph = axis.dph
        if all(s // dph == d // dph for s, d in perm):
            counts["dev"] += 1
        elif all(s % dph == d % dph for s, d in perm):
            counts["host"] += 1
        else:
            counts["joint"] += 1
        return orig(x, axis, perm)

    se._tiered_ppermute = spy
    try:
        spec = CodeSpec("rs", 8, 4, W=8)
        x = spec.field.rand((8, 8), RNG)
        Encoder.plan(spec, backend="mesh",
                     topology=Topology(2, 4)).run(x)
    finally:
        se._tiered_ppermute = orig
    assert counts["dev"] > 0 and counts["host"] > 0, counts
    assert counts["joint"] == 0, counts
    print(f"  tiered legs fire: {counts}")


if __name__ == "__main__":
    check_bitwise_parity()
    check_tiered_legs_fire()
    print("TOPO_MESH_CHECKS_OK")
