"""Schedule-IR tests: golden digests, the validate() static checker
(positive sweep + mutation rejection), coeff_matrix ground truth, the
tier_commute rewrite pass, and IR-vs-closed-form accounting.

The golden digests pin the exact canonical round programs: any edit to a
builder that changes even one send/combine changes the digest, so these
fail loudly on accidental schedule drift.  The mesh lowering of commuted
programs runs in the `schedule_mesh_checks.py` subprocess (jax locks the
device count at first init).
"""
import os
import subprocess
import sys
from dataclasses import replace
from pathlib import Path

import numpy as np
import pytest
from conftest_hypothesis import HAVE_HYPOTHESIS, given, settings, st

from repro.api.planner import Encoder
from repro.api.spec import CodeSpec
from repro.core.schedule import (Round, ScheduleValidationError, Send,
                                 build_encode_ir, execute)
from repro.core.simulator import RoundNetwork
from repro.obs import drift
from repro.recover.planner import Decoder
from repro.topo import Topology, place

REPO = Path(__file__).resolve().parent.parent
RNG = np.random.default_rng(11)


# ---------------------------------------------------------------------------
# golden digests — the canonical programs, pinned
# ---------------------------------------------------------------------------

GOLDEN = [
    (CodeSpec("universal", 9, 3, p=2, seed=9), "a645678176d4450d"),
    (CodeSpec("rs", 16, 4), "e723afc227cffff8"),
    (CodeSpec("dft", 8, 8), "8aa9988febd2caf0"),
    (CodeSpec("universal", 4, 2, seed=5), "46a783700fbdcd0c"),
    (CodeSpec("dft", 4, 4), "8d4e2a7f2debde99"),
]


@pytest.mark.parametrize("spec,want", GOLDEN,
                         ids=[f"{s.kind}-{s.K}-{s.R}-p{s.p}"
                              for s, _ in GOLDEN])
def test_golden_digest(spec, want):
    ir = build_encode_ir(spec).validate()
    assert ir.digest() == want
    # rebuilt from scratch -> byte-identical program
    assert build_encode_ir(spec).digest() == want


def test_digest_distinguishes_programs():
    digs = {build_encode_ir(s).digest() for s, _ in GOLDEN}
    assert len(digs) == len(GOLDEN)


# ---------------------------------------------------------------------------
# coeff_matrix: the IR computes exactly x^T A (encode) / v^T D (decode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", [
    CodeSpec("rs", 6, 3), CodeSpec("lagrange", 8, 4),
    CodeSpec("universal", 5, 7, seed=2), CodeSpec("dft", 8, 8),
], ids=lambda s: f"{s.kind}-{s.K}-{s.R}")
def test_encode_coeff_matrix_is_A_T(spec):
    plan = Encoder.plan(spec, backend="simulator")
    ir = plan.schedule_ir()
    assert np.array_equal(ir.coeff_matrix(plan.field), plan.A.T % spec.q)


def test_decode_coeff_matrix_is_D_T():
    spec = CodeSpec("rs", 8, 4)
    plan = Decoder.plan(spec, erased=[1, 5, 9])
    ir = plan.schedule_ir()
    assert np.array_equal(ir.coeff_matrix(plan.field),
                          plan.tables.D.T % spec.q)


def test_empty_erasure_ir_has_no_rounds():
    plan = Decoder.plan(CodeSpec("rs", 6, 3), erased=[])
    ir = plan.schedule_ir()
    assert ir.rounds == () and ir.cost() == (0, 0)
    y = plan.run(np.arange(12, dtype=np.int64).reshape(6, 2))
    assert y.shape == (0, 2)


# ---------------------------------------------------------------------------
# validate(): positive sweep + mutation rejection
# ---------------------------------------------------------------------------

ALL_KINDS = [CodeSpec("universal", 6, 3, seed=1), CodeSpec("rs", 8, 4),
             CodeSpec("lagrange", 9, 3), CodeSpec("dft", 8, 8)]


@pytest.mark.parametrize("spec", ALL_KINDS, ids=lambda s: s.kind)
def test_validate_passes_both_planners(spec):
    Encoder.plan(spec, backend="simulator").schedule_ir().validate()
    erased = [spec.K + 1] if spec.kind != "dft" else [2]
    Decoder.plan(spec, erased=erased).schedule_ir().validate()


def _first_send_round(ir):
    return next(i for i, r in enumerate(ir.rounds) if r.sends)


def _mutate_round(ir, i, rnd):
    rounds = list(ir.rounds)
    rounds[i] = rnd
    return replace(ir, rounds=tuple(rounds))


def test_validate_rejects_port_violation():
    ir = build_encode_ir(CodeSpec("rs", 8, 4)).validate()
    i = _first_send_round(ir)
    r = ir.rounds[i]
    # duplicating a send doubles both its sender's and receiver's port use
    bad = _mutate_round(ir, i, replace(r, sends=r.sends + (r.sends[0],)))
    with pytest.raises(ScheduleValidationError, match="port violation"):
        bad.validate()


def test_validate_rejects_phantom_packet():
    ir = build_encode_ir(CodeSpec("rs", 8, 4)).validate()
    i = _first_send_round(ir)
    r = ir.rounds[i]
    s = r.sends[0]
    ghost = Send(s.src, s.dst, (ir.n_packets + 7,))
    bad = _mutate_round(ir, i, Round((ghost,) + r.sends[1:], r.combines,
                                     r.tag))
    with pytest.raises(ScheduleValidationError, match="before creation"):
        bad.validate()


def test_validate_rejects_misplaced_sender():
    ir = build_encode_ir(CodeSpec("rs", 8, 4)).validate()
    i = _first_send_round(ir)
    r = ir.rounds[i]
    s = r.sends[0]
    # a processor that never held the packet tries to send it
    thief = next(g for g in range(ir.n_procs)
                 if g not in (s.src, s.dst)
                 and all(g not in (o.src, o.dst) for o in r.sends))
    bad = _mutate_round(ir, i, replace(
        r, sends=(Send(thief, s.dst, s.packets),) + r.sends[1:]))
    with pytest.raises(ScheduleValidationError,
                       match="not at sender|port violation"):
        bad.validate()


def test_validate_rejects_failed_processor_touch():
    spec = CodeSpec("rs", 8, 4)
    plan = Decoder.plan(spec, erased=[3])
    ir = plan.schedule_ir()
    ir.validate(failed={3})                    # the real erasure: fine
    kept0 = plan.kept[0]
    with pytest.raises(ScheduleValidationError, match="failed processor"):
        ir.validate(failed={3, kept0})         # a survivor the IR uses


def _random_spec(rng):
    kind = ["universal", "rs", "lagrange", "dft"][int(rng.integers(4))]
    if kind == "dft":
        K = 2 ** int(rng.integers(1, 5))
        return CodeSpec("dft", K, K)
    if kind == "universal":
        K = int(rng.integers(2, 10))
        R = int(rng.integers(1, 7))
        return CodeSpec(kind, K, R, p=int(rng.integers(1, 3)),
                        seed=int(rng.integers(100)))
    # structured rs/lagrange require min | max of (K, R) (Remark 4)
    small = int(rng.integers(1, 4))
    K = small * int(rng.integers(1, 5))
    R = small
    if rng.integers(2):
        K, R = R, K
    return CodeSpec(kind, K, R, p=int(rng.integers(1, 3)))


def _check_random_spec_placement(spec, hosts, dph):
    ir = build_encode_ir(spec).validate()
    n = spec.K if spec.kind == "dft" else spec.K + spec.R
    if hosts * dph >= n:
        pl = place(spec, Topology(hosts, dph), "affinity")
        a = ir.attribute(pl)
        c1, c2 = ir.cost()
        assert a["intra"][0] + a["inter"][0] == c1
        assert a["intra"][1] + a["inter"][1] == c2
        ir.tier_commute(pl).validate()


if HAVE_HYPOTHESIS:
    @given(data=st.data())
    @settings(max_examples=25, deadline=None)
    def test_validate_random_specs(data):
        rng = np.random.default_rng(
            data.draw(st.integers(min_value=0, max_value=2 ** 31)))
        _check_random_spec_placement(_random_spec(rng),
                                     int(rng.integers(1, 5)),
                                     int(rng.integers(1, 7)))
else:  # no hypothesis: a fixed-seed random sweep instead of a skip
    def test_validate_random_specs():
        rng = np.random.default_rng(29)
        for _ in range(25):
            _check_random_spec_placement(_random_spec(rng),
                                         int(rng.integers(1, 5)),
                                         int(rng.integers(1, 7)))


# ---------------------------------------------------------------------------
# execute(): the generic interpreter against the plan paths
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ALL_KINDS, ids=lambda s: s.kind)
def test_execute_matches_local_matmul(spec):
    plan = Encoder.plan(spec, backend="simulator")
    f = plan.field
    x = f.rand((spec.K, 3), RNG)
    ir = plan.schedule_ir()
    net = RoundNetwork(ir.n_procs, spec.p)
    y = execute(ir, f, x, net)
    assert np.array_equal(y, f.matmul(x.T, plan.A).T)
    assert (net.C1, net.C2) == tuple(v * 3 if i else v
                                     for i, v in enumerate(ir.cost()))


# ---------------------------------------------------------------------------
# tier_commute: strict inter-round shrink, value-identical outputs
# ---------------------------------------------------------------------------

def _rs164_placement():
    return place(CodeSpec("rs", 16, 4), Topology(5, 4), "affinity")


def test_tier_commute_shrinks_inter_rounds():
    spec = CodeSpec("rs", 16, 4)
    pl = _rs164_placement()
    ir = build_encode_ir(spec).validate()
    cm = ir.tier_commute(pl)
    base, opt = ir.attribute(pl), cm.attribute(pl)
    assert base["inter"][0] == 3          # the acceptance-criterion config
    assert opt["inter"][0] == 1
    assert opt["inter"][0] < base["inter"][0]
    assert cm.digest() != ir.digest()
    assert "[commuted]" in cm.summary()
    # outputs are value-identical
    f = spec.field
    x = f.rand((spec.K, 2), RNG)
    y0 = execute(ir, f, x, RoundNetwork(ir.n_procs, spec.p))
    y1 = execute(cm, f, x, RoundNetwork(cm.n_procs, spec.p))
    assert np.array_equal(y0, y1)


def test_tier_commute_noop_without_jobs():
    spec = CodeSpec("dft", 8, 8)
    pl = place(spec, Topology(2, 4), "affinity")
    ir = build_encode_ir(spec).validate()
    assert ir.tier_commute(pl) is ir


def test_commuted_plan_measured_equals_attribute():
    """Simulator run of a commute=True plan: measured per-tier counts ==
    attribute() x width, and the drift ledger stays clean."""
    drift.LEDGER.reset()
    spec = CodeSpec("rs", 16, 4)
    pl = _rs164_placement()
    base = Encoder.plan(spec, topology=pl)
    plan = Encoder.plan(spec, topology=pl, commute=True)
    assert plan is not base, "commute must key the plan cache"
    f = plan.field
    x = f.rand((spec.K, 3), RNG)
    y = plan.run(x)
    assert np.array_equal(y, base.run(x))
    a = plan.schedule_ir().attribute(pl)
    tiers = plan.sim_net.by_tier()
    for t in ("intra", "inter"):
        assert tiers[t] == (a[t][0], a[t][1] * 3)
    assert drift.LEDGER.drifted() == []
    drift.LEDGER.reset()


def test_commute_requires_placement():
    with pytest.raises(ValueError, match="placement"):
        Encoder.plan(CodeSpec("rs", 16, 4), commute=True)


# ---------------------------------------------------------------------------
# describe(): the schedule line rides along on every backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["simulator", "local"])
def test_describe_has_schedule_line(backend):
    spec = CodeSpec("rs", 8, 4)
    plan = Encoder.plan(spec, backend=backend)
    ir = plan.schedule_ir()
    d = plan.describe()
    assert f"schedule: {ir.summary(plan.placement)}" in d
    assert ir.digest() in d
    dplan = Decoder.plan(spec, erased=[2, 7], backend=backend)
    assert dplan.schedule_ir().digest() in dplan.describe()
    assert "schedule:" in Decoder.plan(spec, erased=[],
                                       backend=backend).describe()


def test_coded_system_commute():
    from repro.api import CodedSystem

    drift.LEDGER.reset()
    spec = CodeSpec("rs", 16, 4)
    x = RNG.integers(0, spec.field.q, (16, 2), dtype=np.int64)
    base = CodedSystem(spec, topology=Topology(5, 4))
    sys_ = CodedSystem(spec, topology=Topology(5, 4), commute=True)
    assert np.array_equal(sys_.encode(x), base.encode(x))
    assert "[commuted]" in sys_.describe()
    assert drift.LEDGER.drifted() == []
    drift.LEDGER.reset()
    with pytest.raises(ValueError, match="placed topology"):
        CodedSystem(spec, commute=True)


def test_commuted_describe_tiers_match_ir():
    pl = _rs164_placement()
    plan = Encoder.plan(CodeSpec("rs", 16, 4), topology=pl, commute=True)
    d = plan.describe()
    assert "[commuted]" in d
    a = plan.schedule_ir().attribute(pl)
    assert f"tiers intra {a['intra'][0]} | inter {a['inter'][0]}" in d


# ---------------------------------------------------------------------------
# mesh lowering of commuted programs (subprocess: needs 16 devices)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_schedule_mesh_checks_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(REPO / "tests" / "schedule_mesh_checks.py")],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SCHEDULE_MESH_CHECKS_OK" in proc.stdout
