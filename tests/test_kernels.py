"""Pallas gf_matmul kernel: shape sweep + adversarial values vs oracles."""
import jax.numpy as jnp
import numpy as np
import pytest
from conftest_hypothesis import given, settings, st

from repro.core.field import FERMAT, FERMAT_Q
from repro.kernels.gf_matmul import gf_matmul
from repro.kernels.ops import encode_blocks
from repro.kernels.ref import gf_matmul_ref

RNG = np.random.default_rng(11)


def _oracle(a, b):
    return FERMAT.matmul(a.astype(np.int64), b.astype(np.int64)).astype(np.uint32)


@pytest.mark.parametrize(
    "M,K,N",
    [(1, 1, 1), (128, 128, 128), (7, 300, 65), (130, 257, 96),
     (200, 130, 250), (128, 1, 128), (1, 1024, 1)],
)
def test_gf_matmul_shape_sweep(M, K, N):
    a = RNG.integers(0, FERMAT_Q, (M, K)).astype(np.uint32)
    b = RNG.integers(0, FERMAT_Q, (K, N)).astype(np.uint32)
    exp = _oracle(a, b)
    assert np.array_equal(np.asarray(gf_matmul(jnp.asarray(a), jnp.asarray(b))), exp)
    assert np.array_equal(np.asarray(gf_matmul_ref(jnp.asarray(a), jnp.asarray(b))), exp)


@pytest.mark.parametrize("dtype", [np.uint32, np.int32, np.uint16])
def test_gf_matmul_dtypes(dtype):
    hi = min(FERMAT_Q - 1, np.iinfo(dtype).max)
    a = RNG.integers(0, hi, (64, 96)).astype(dtype)
    b = RNG.integers(0, hi, (96, 32)).astype(dtype)
    got = np.asarray(gf_matmul(jnp.asarray(a), jnp.asarray(b)))
    assert np.array_equal(got, _oracle(a.astype(np.uint32), b.astype(np.uint32)))


@pytest.mark.parametrize("blocks", [(32, 32, 32), (128, 128, 16), (64, 128, 128)])
def test_gf_matmul_block_shapes(blocks):
    bm, bn, bk = blocks
    a = RNG.integers(0, FERMAT_Q, (200, 170)).astype(np.uint32)
    b = RNG.integers(0, FERMAT_Q, (170, 90)).astype(np.uint32)
    got = np.asarray(gf_matmul(jnp.asarray(a), jnp.asarray(b), bm=bm, bn=bn, bk=bk))
    assert np.array_equal(got, _oracle(a, b))


def test_gf_matmul_adversarial_65536():
    """65536 == -1 (mod q) is the only uint32-overflow corner."""
    for shape in [(64, 64), (130, 64)]:
        a = np.full(shape, 65536, np.uint32)
        b = np.full((shape[1], 32), 65536, np.uint32)
        assert np.array_equal(np.asarray(gf_matmul(jnp.asarray(a), jnp.asarray(b))),
                              _oracle(a, b))


def test_gf_matmul_worst_case_accumulation():
    """All-max values at a large bk: overflow-proof check (bk_inner slices of
    8 bound the per-sum addend count; 4096 exercises many slices)."""
    a = np.full((8, 4096), FERMAT_Q - 1, np.uint32)
    b = np.full((4096, 8), FERMAT_Q - 1, np.uint32)
    got = np.asarray(gf_matmul(jnp.asarray(a), jnp.asarray(b), bk=4096))
    assert np.array_equal(got, _oracle(a, b))


@given(
    m=st.integers(1, 40), k=st.integers(1, 60), n=st.integers(1, 40),
    seed=st.integers(0, 2**31),
)
@settings(max_examples=10, deadline=None)
def test_gf_matmul_property(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, FERMAT_Q, (m, k)).astype(np.uint32)
    b = rng.integers(0, FERMAT_Q, (k, n)).astype(np.uint32)
    assert np.array_equal(
        np.asarray(gf_matmul(jnp.asarray(a), jnp.asarray(b), bm=32, bn=32, bk=32)),
        _oracle(a, b),
    )


def test_encode_blocks_dispatch():
    x = RNG.integers(0, FERMAT_Q, (160, 200)).astype(np.uint32)
    coeffs = RNG.integers(0, FERMAT_Q, (160, 130)).astype(np.uint32)
    got = np.asarray(encode_blocks(jnp.asarray(x), jnp.asarray(coeffs)))
    assert np.array_equal(got, _oracle(coeffs.T, x))
    small = np.asarray(encode_blocks(jnp.asarray(x[:4]), jnp.asarray(coeffs[:4, :3])))
    assert np.array_equal(small, _oracle(coeffs[:4, :3].T, x[:4]))


# ---------------- NTT kernel (the paper's DFT layer on-chip) -----------------

@pytest.mark.parametrize("K", [4, 16, 64, 256, 1024])
def test_ntt_kernel_vs_permuted_dft(K):
    from repro.kernels.ntt import ntt, ntt_ref

    x = RNG.integers(0, FERMAT_Q, (K, 6)).astype(np.uint32)
    got = np.asarray(ntt(jnp.asarray(x)))
    assert np.array_equal(got, ntt_ref(jnp.asarray(x)))


@pytest.mark.parametrize("K", [16, 128])
def test_ntt_roundtrip_and_padding(K):
    from repro.kernels.ntt import ntt

    x = RNG.integers(0, FERMAT_Q, (K, 131)).astype(np.uint32)  # W % bw != 0
    y = ntt(jnp.asarray(x))
    back = np.asarray(ntt(y, inverse=True))
    assert np.array_equal(back, x)


def test_ntt_adversarial_values():
    from repro.kernels.ntt import ntt, ntt_ref

    x = np.full((64, 4), FERMAT_Q - 1, np.uint32)
    assert np.array_equal(np.asarray(ntt(jnp.asarray(x))), ntt_ref(jnp.asarray(x)))
