"""Coded computation: gradient coding, LCC decode routing, coded matmul,
the straggler-tolerant train step, and the unified coding-layer API."""
import inspect

import jax
import numpy as np
import pytest

from repro.api import Encoder
from repro.coding import (CodedMatmul, GradientCoder, LagrangeComputer,
                          coded_gradient, default_backend)
from repro.configs import get_config
from repro.core.field import FERMAT, Field
from repro.data import SyntheticLM
from repro.recover.planner import Decoder
from repro.train import (StragglerInjector, init_state,
                         make_straggler_train_step, make_train_setup,
                         make_train_step)

RNG = np.random.default_rng(0)
KEY = jax.random.PRNGKey(0)


# ---------------- group assignment / decode_weights -------------------------

@pytest.mark.parametrize("n,s", [(6, 1), (6, 2), (8, 3), (4, 0)])
def test_group_assignment_invariants(n, s):
    gc = GradientCoder(n, s)
    B = gc.encode_matrix()
    # every part covered by exactly its group's s+1 workers
    assert np.array_equal(B.sum(axis=0), np.full(n, s + 1))
    for w in range(n):
        parts = gc.parts_for_worker(w)
        assert len(parts) == s + 1
        assert all(p // (s + 1) == w // (s + 1) for p in parts)
    # any alive mask with <= s stragglers decodes: a @ B == ones
    for trial in range(10):
        dead = RNG.choice(n, size=RNG.integers(0, s + 1), replace=False)
        alive = np.array([w not in dead for w in range(n)])
        a = gc.decode_weights(alive)
        assert np.array_equal(a @ B, np.ones(n))
        assert np.all(a[~alive] == 0)


def test_decode_weights_group_wipeout_is_loud():
    gc = GradientCoder(6, s=1)
    alive = np.ones(6, bool)
    alive[[2, 3]] = False  # both members of group 1
    with pytest.raises(RuntimeError, match="group 1 fully straggled"):
        gc.decode_weights(alive)


def test_combine_exact_and_deprecated_shim():
    gc = GradientCoder(6, s=1)
    parts = [{"g": np.float32(RNG.standard_normal(4))} for _ in range(6)]
    reports = [{"g": sum(parts[i]["g"] for i in gc.parts_for_worker(w))}
               for w in range(6)]
    full = gc.combine(reports, np.ones(6, bool))
    for dead in [{0}, {1, 4}, {5}]:
        alive = np.array([w not in dead for w in range(6)])
        out = gc.combine(reports, alive)
        # bitwise, not allclose: survivors enter the sum unscaled
        assert np.array_equal(np.asarray(out["g"]), np.asarray(full["g"]))
    with pytest.deprecated_call():
        out = coded_gradient(gc, reports, np.ones(6, bool))
    assert np.array_equal(np.asarray(out["g"]), np.asarray(full["g"]))


# ---------------- unified API surface ---------------------------------------

def test_unified_signature_contract():
    # both coders: keyword-only system(*, backend=..., ...) with the
    # shared default_backend(q) resolution
    for cls, meth in [(GradientCoder, "system"), (GradientCoder, "encode_plan"),
                      (LagrangeComputer, "system"),
                      (LagrangeComputer, "encode_plan")]:
        sig = inspect.signature(getattr(cls, meth))
        for p in list(sig.parameters.values())[1:]:
            assert p.kind is inspect.Parameter.KEYWORD_ONLY, (cls, meth, p)
        assert sig.parameters["backend"].default is None, (cls, meth)
    gc = GradientCoder(4, s=1)
    with pytest.raises(TypeError):
        gc.system("local")  # positional backend is gone
    assert gc.system().backend == "local"  # default_backend(65537)
    assert default_backend(65537) == "local"
    assert default_backend(97) == "simulator"
    lcc = LagrangeComputer.build(Field(97), K=3, N=6)
    assert lcc.system().backend == "simulator"


def test_encode_plan_session_is_cached_no_leak():
    gc = GradientCoder(8, s=1)
    before = Encoder.cache_info()
    s1 = gc.system()
    p1 = gc.encode_plan()
    for _ in range(20):
        assert gc.system() is s1           # one session, not one per call
        assert gc.encode_plan() is p1
    after = Encoder.cache_info()
    # 20 repeat calls added at most the one initial plan entry
    assert after["plans"] - before["plans"] <= 1


# ---------------- LCC decode via the shared decode-plan path ----------------

@pytest.mark.parametrize("deg", [1, 2, 3])
def test_lcc_decode_random_subsets_and_host_parity(deg):
    f = FERMAT
    lcc = LagrangeComputer.build(f, K=4, N=12)
    x = f.rand((4, 3), np.random.default_rng(deg))

    def poly(v):
        out = v
        for _ in range(deg - 1):
            out = f.mul(out, v)
        return f.add(out, 7)

    results = poly(lcc.encode(x))
    T = lcc.recovery_threshold(deg)
    truth = poly(x)
    for trial in range(5):
        n_live = int(RNG.integers(T, lcc.N + 1))
        ids = RNG.permutation(lcc.N)[:n_live]  # unsorted, random subset
        dec = lcc.decode(deg, ids, results[ids])
        assert np.array_equal(dec, truth)
        host = lcc._decode_host(deg, ids, results[ids])
        assert np.array_equal(host, dec)  # plan path == host fallback


def test_lcc_decode_hits_shared_plan_cache():
    f = FERMAT
    lcc = LagrangeComputer.build(f, K=4, N=12)
    x = f.rand((4, 2), np.random.default_rng(1))
    results = f.mul(lcc.encode(x), 5)
    ids = np.arange(12)[2:]  # drop workers 0, 1
    lcc.decode(1, ids, results[ids])
    before = Decoder.cache_info()
    lcc.decode(1, ids, results[ids])
    after = Decoder.cache_info()
    assert after["plan_hits"] > before["plan_hits"]
    assert after["plans"] == before["plans"]


def test_lcc_decode_insufficient_workers():
    lcc = LagrangeComputer.build(FERMAT, K=4, N=12)
    T = lcc.recovery_threshold(2)
    with pytest.raises(AssertionError):
        lcc.decode(2, np.arange(T - 1), np.zeros((T - 1, 2), np.int64))


# ---------------- coded inference (CodedMatmul) ------------------------------

def test_coded_matmul_all_dropout_counts_bitwise():
    K, R, b, d, out = 4, 2, 2, 8, 3
    X = FERMAT.rand((K * b, d), RNG)
    W = FERMAT.rand((d, out), RNG)
    truth = FERMAT.matmul(X, W)
    with CodedMatmul(K, R) as cm:
        for nd in range(R + 1):
            dead = RNG.choice(K + R, size=nd, replace=False)
            assert np.array_equal(cm(X, W, dead=dead), truth)
        with pytest.raises(ValueError, match="exceed R"):
            cm(X, W, dead=range(R + 1))
        assert not cm.system.failed  # decode heals back to healthy


def test_coded_matmul_backend_parity():
    K, R = 4, 2
    X = FERMAT.rand((K * 2, 6), RNG)
    W = FERMAT.rand((6, 4), RNG)
    with CodedMatmul(K, R) as loc, \
            CodedMatmul(K, R, backend="simulator") as sim:
        got_l = loc(X, W, dead=[1, 5])
        got_s = sim(X, W, dead=[1, 5])
    assert np.array_equal(got_l, got_s)


# ---------------- straggler-tolerant train step ------------------------------

@pytest.fixture(scope="module")
def tiny_train():
    cfg = get_config("qwen3_1_7b").smoke()
    opt, _ = make_train_setup(cfg, total_steps=20, peak_lr=5e-3)
    state = init_state(cfg, KEY, opt)
    batch = SyntheticLM(cfg.vocab, 16, 8).device_batch(0)
    return cfg, opt, state, batch


def _trees_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_coded_step_bitwise_recovery(tiny_train):
    cfg, opt, state, batch = tiny_train
    coder = GradientCoder(4, s=1)
    step = make_straggler_train_step(cfg, opt, coder)
    ref_state, ref_m = step(state, batch)  # all alive
    for dead in [{0}, {1}, {3}, {0, 2}]:
        if len(dead) > coder.s:
            continue
        alive = np.array([w not in dead for w in range(4)])
        got_state, got_m = step(state, batch, alive)
        assert _trees_equal(got_state.params, ref_state.params)
        assert got_m["stragglers"] == len(dead)
    # two stragglers in distinct groups with s=2 coding
    coder2 = GradientCoder(6, s=2)
    step2 = make_straggler_train_step(cfg, opt, coder2)
    batch6 = SyntheticLM(cfg.vocab, 16, 12).device_batch(0)
    ref6, _ = step2(state, batch6)
    alive = np.ones(6, bool)
    alive[[0, 4]] = False
    got6, _ = step2(state, batch6, alive)
    assert _trees_equal(got6.params, ref6.params)


def test_coded_step_close_to_uncoded_step(tiny_train):
    cfg, opt, state, batch = tiny_train
    coder = GradientCoder(4, s=1)
    coded = make_straggler_train_step(cfg, opt, coder)
    plain = jax.jit(make_train_step(cfg, opt))
    s1, m1 = coded(state, batch)
    s2, m2 = plain(state, batch)
    # different reduction association (per-part vs whole-batch), so
    # allclose, not bitwise
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_coded_step_guards(tiny_train):
    cfg, opt, state, batch = tiny_train
    coder = GradientCoder(4, s=1)
    step = make_straggler_train_step(cfg, opt, coder)
    alive = np.ones(4, bool)
    alive[[0, 1]] = False  # wipes group 0
    with pytest.raises(RuntimeError, match="fully straggled"):
        step(state, batch, alive)
    bad_batch = SyntheticLM(cfg.vocab, 16, 6).device_batch(0)  # 6 % 4 != 0
    with pytest.raises(ValueError, match="not divisible"):
        step(state, bad_batch)
    with pytest.raises(ValueError, match="alive must be"):
        step(state, batch, np.ones(5, bool))


def test_coded_step_metrics_and_trace(tiny_train):
    from repro.obs import metrics, trace

    cfg, opt, state, batch = tiny_train
    coder = GradientCoder(4, s=1)
    step = make_straggler_train_step(cfg, opt, coder)
    tracer = trace.Tracer()
    trace.install(tracer)
    try:
        before = metrics.REGISTRY.snapshot()
        alive = np.ones(4, bool)
        alive[2] = False
        step(state, batch, alive)
        after = metrics.REGISTRY.snapshot()
        spans = tracer.events(cat="train.step")
    finally:
        trace.uninstall(tracer)
    assert spans and spans[-1]["args"]["stragglers"] == [2]

    def total(snap, name):
        return sum(snap.get(name, {}).get("values", {}).values())

    assert total(after, "coded_train_steps_total") == \
        total(before, "coded_train_steps_total") + 1
    assert total(after, "coded_train_stragglers_total") == \
        total(before, "coded_train_stragglers_total") + 1
    hist = after.get("coded_train_step_us", {}).get("values", {})
    assert any(v["count"] >= 1 for v in hist.values())


# ---------------- StragglerInjector ------------------------------------------

@pytest.mark.parametrize("mode", ["random", "bursty", "fixed"])
def test_straggler_injector_masks_decodable(mode):
    coder = GradientCoder(6, s=2)
    inj = StragglerInjector.build(mode, coder, steps=40, rate=0.8, seed=3)
    n_straggled_steps = 0
    for t in range(40):
        mask = inj.mask(t)
        coder.decode_weights(mask)  # never raises: patterns keep <= s
        assert (~mask).sum() <= coder.s
        n_straggled_steps += int(not mask.all())
    assert n_straggled_steps > 0  # rate=0.8 over 40 steps must fire
    # the plan is registered through FaultInjector (the chaos tooling)
    assert inj.plan and all(0 <= w < 6 for _, w in inj.plan)
    assert inj.injector.net.pending_kills  # lives on a real RoundNetwork


def test_straggler_injector_fixed_and_bounds():
    coder = GradientCoder(6, s=1)
    inj = StragglerInjector.fixed(coder, steps=5, workers=[4])
    for t in range(5):
        assert list(np.flatnonzero(~inj.mask(t))) == [4]
    with pytest.raises(ValueError, match="exceed tolerance"):
        StragglerInjector.fixed(coder, steps=5, workers=[0, 1])
    with pytest.raises(ValueError, match="unknown straggler mode"):
        StragglerInjector.build("flaky", coder, steps=5)
