"""The perf-regression gate (`benchmarks/run.py --check`): baseline
matching, tolerances, bounds, and — crucially — that renamed or dropped
benchmarks cannot silently stop being gated (baseline entry with no
measured row fails; measured row with no baseline entry warns)."""
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))

from benchmarks.run import _check_baseline, _params_from  # noqa: E402


def _entry(us, **kw):
    return {"us_per_call": us, "params": kw.pop("params", {}), **kw}


def test_clean_pass_and_relative_tolerance():
    base = {"stream/a_K16": _entry(100.0)}
    acc = {"stream/a_K16": _entry(110.0)}
    problems, warnings = _check_baseline(acc, base, 0.25, None)
    assert problems == [] and warnings == []

    acc = {"stream/a_K16": _entry(200.0)}  # 2x: above 1 + 0.25
    problems, _ = _check_baseline(acc, base, 0.25, None)
    assert len(problems) == 1 and "regressed" in problems[0]

    # per-entry tolerance overrides the CLI default
    base = {"stream/a_K16": _entry(100.0, tolerance=1.5)}
    problems, _ = _check_baseline(acc, base, 0.25, None)
    assert problems == []


def test_baseline_entry_without_measured_row_fails():
    """A renamed/dropped benchmark must fail the gate, not vanish from it."""
    base = {"stream/old_name_K16": _entry(100.0)}
    problems, _ = _check_baseline({}, base, 0.25, None)
    assert len(problems) == 1
    assert "in baseline but not measured" in problems[0]


def test_measured_row_without_baseline_entry_warns():
    """The rename's other half: the NEW row name is running ungated."""
    base = {"stream/old_K16": _entry(100.0)}
    acc = {"stream/old_K16": _entry(100.0),
           "stream/new_K16": _entry(5.0),
           "table1/unrelated": _entry(1.0)}  # un-gated section: no warning
    problems, warnings = _check_baseline(acc, base, 0.25, None)
    assert problems == []
    assert len(warnings) == 1 and "stream/new_K16" in warnings[0]
    assert "NOT gated" in warnings[0]


def test_meta_keys_are_not_gated_rows():
    """``_``-prefixed keys (the ``_meta`` git-sha/timestamp stamp in the
    JSON artifact) are metadata: a baseline carrying one must neither fail
    the gate as "not measured" nor gate any measured row."""
    base = {"stream/a_K16": _entry(100.0),
            "_meta": {"git_sha": "abc123", "timestamp": "2026-01-01"}}
    acc = {"stream/a_K16": _entry(100.0)}
    problems, warnings = _check_baseline(acc, base, 0.25, None)
    assert problems == [] and warnings == []


def test_sections_filter_skips_unran_baseline_entries():
    base = {"stream/a_K16": _entry(100.0), "recover/b_K16": _entry(50.0)}
    acc = {"stream/a_K16": _entry(100.0)}
    problems, _ = _check_baseline(acc, base, 0.25, {"stream"})
    assert problems == []  # recover wasn't run: its absence is fine
    problems, _ = _check_baseline(acc, base, 0.25, {"stream", "recover"})
    assert len(problems) == 1 and problems[0].startswith("recover/b_K16")


def test_shape_param_drift_fails():
    base = {"stream/a_K16": _entry(100.0, params={"K": 16})}
    acc = {"stream/a_K16": _entry(100.0, params={"K": 32})}
    problems, _ = _check_baseline(acc, base, 0.25, None)
    assert len(problems) == 1 and "shape params drifted" in problems[0]


def test_absolute_bounds_and_better_higher():
    base = {"stream/ntt_speedup_K128": {"min": 1.5},
            "stream/tput": _entry(100.0, better="higher")}
    acc = {"stream/ntt_speedup_K128": _entry(1.2),
           "stream/tput": _entry(60.0)}  # 40% below with tol 0.25
    problems, _ = _check_baseline(acc, base, 0.25, None)
    assert len(problems) == 2
    assert any("below required min" in p for p in problems)
    assert any("regressed below" in p for p in problems)


def test_params_parsed_from_row_names():
    assert _params_from("stream/enc_K16_R4_W4096", "backend=local;x=1") == {
        "K": 16, "R": 4, "W": 4096, "backend": "local"}
