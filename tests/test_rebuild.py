"""Live failure injection + decentralized rebuild.

Covers the round-granular fault model (`fail_at` / `FaultInjector` /
`PartialRunError` with exact aborted-prefix accounting and the
`repair_with_faults` restart driver), `CodedSystem.rebuild` /
`rebuild_stream` (bitwise across backends for all four kinds, healing
semantics, checkpoint `scrub()`), the queue's rebuild op and superset
failover under erasure churn, and the failure-path bugfixes: simulator
validation as real exceptions, `stats()`/`describe()` on undecodable dft
patterns, and `CodingQueue.close()` failing (not stranding) timed-out
futures."""
import threading

import numpy as np
import pytest

from repro.api import (
    Backend,
    CodedSystem,
    CodeSpec,
    register_backend,
    unregister_backend,
)
from repro.core.field import FERMAT
from repro.core.simulator import (
    FailedProcessorError,
    FaultInjector,
    Msg,
    PartialRunError,
    PortViolationError,
    RoundNetwork,
)
from repro.launch.coding_queue import CodingQueue
from repro.recover import Decoder, decode_cost, repair_with_faults

RNG = np.random.default_rng(41)

# decodable patterns per kind (mixing data and parity positions)
CASES = [
    ("universal", 8, 4, (0, 9)),
    ("rs", 8, 4, (2, 4, 11)),
    ("lagrange", 8, 4, (1, 10)),
    ("dft", 8, 8, (5, 9, 13)),
]
# |E|=6 <= R=8 but information-losing for the non-MDS dft codeword
DFT_UNDECODABLE = (0, 2, 4, 6, 8, 9)


def _spec(kind, K, R, **kw):
    if kind == "universal":
        kw.setdefault("seed", 5)
    return CodeSpec(kind=kind, K=K, R=R, **kw)


def _codeword(spec, x, backend="simulator"):
    s = CodedSystem(spec, backend=backend)
    return s.codeword(x)


# ---------------------------------------------------------------------------
# simulator validation: real exceptions, correct round label
# ---------------------------------------------------------------------------

def test_msg_validation_raises_value_error():
    with pytest.raises(ValueError, match="self-message"):
        Msg(3, 3, 1)
    with pytest.raises(ValueError, match=">= 1"):
        Msg(0, 1, 0)


def test_account_rejects_out_of_range_and_port_violations():
    net = RoundNetwork(4, p=1)
    with pytest.raises(ValueError, match="outside"):
        net._account([Msg(0, 7, 1)])
    with pytest.raises(PortViolationError, match=r"\(send\)"):
        net._account([Msg(0, 1, 1), Msg(0, 2, 1)])
    with pytest.raises(PortViolationError, match=r"\(recv\)"):
        net._account([Msg(1, 0, 1), Msg(2, 0, 1)])
    assert net.C1 == 0  # nothing was accounted


def test_failed_processor_error_labels_the_current_round():
    """Regression: the message used to say `round {C1}` — the *previous*
    round, since C1 increments only after the check."""
    net = RoundNetwork(4, p=1)
    net.fail([2])
    with pytest.raises(FailedProcessorError, match="round 1:") as ei:
        net._account([Msg(0, 2, 1)])
    assert ei.value.proc == 2
    net._account([Msg(0, 1, 1)])  # round 1 completes
    with pytest.raises(FailedProcessorError, match="round 2:"):
        net._account([Msg(2, 0, 1)])


def test_received_accounting():
    net = RoundNetwork(4, p=2)
    net._account([Msg(0, 1, 5), Msg(2, 1, 3), Msg(3, 0, 2)])
    assert net.received == {1: 8, 0: 2}


# ---------------------------------------------------------------------------
# fail_at / PartialRunError: round-granular kills
# ---------------------------------------------------------------------------

def _decode_sim(spec, cw, erased, net):
    plan = Decoder.plan(spec, erased=erased, backend="simulator")
    from repro.recover import decentralized_decode

    net.fail(erased)
    return decentralized_decode(FERMAT, plan.tables.D,
                                FERMAT.arr(cw[list(plan.kept)]),
                                list(plan.kept), spec.p, net)


def test_mid_schedule_kill_raises_partial_run_error():
    spec = _spec("rs", 8, 4)
    cw = _codeword(spec, FERMAT.rand((8, 3), RNG))
    net = RoundNetwork(spec.N, spec.p)
    net.fail_at(1, (3,))
    with pytest.raises(PartialRunError) as ei:
        _decode_sim(spec, cw, (0, 9), net)
    e = ei.value
    # the aborted round is NOT accounted: exactly the 1-round prefix
    assert e.round == 1 and e.C1 == 1 == net.C1
    assert e.C2 == net.C2 > 0
    assert e.proc == 3 and e.killed == frozenset({3})
    assert set(e.failed) == {0, 3, 9}
    # received-so-far state of the completed prefix, per processor
    assert e.received == net.received and sum(e.received.values()) > 0
    # PartialRunError still is a FailedProcessorError (old catch sites)
    assert isinstance(e, FailedProcessorError)


def test_kill_beyond_schedule_never_fires():
    spec = _spec("rs", 8, 4)
    cw = _codeword(spec, FERMAT.rand((8, 2), RNG))
    net = RoundNetwork(spec.N, spec.p)
    net.fail_at(10_000, (3,))
    y, _ = _decode_sim(spec, cw, (0,), net)
    assert np.array_equal(y, cw[[0]])
    assert 3 not in net.failed  # pending, never applied


def test_static_failures_still_raise_plain_error():
    """Touching a *statically* failed processor stays the hard contract
    error — PartialRunError is reserved for live-injected kills."""
    net = RoundNetwork(4, 1)
    net.fail([2])

    def bad():
        yield [Msg(0, 2, 1)]

    with pytest.raises(FailedProcessorError) as ei:
        net.run(bad())
    assert not isinstance(ei.value, PartialRunError)


def test_fault_injector_plan_and_random_kills():
    net = RoundNetwork(8, 1)
    inj = FaultInjector(net)
    inj.kill_at(2, (1,)).kill_at(5, (3, 4))
    assert set(inj.plan) == {(2, 1), (5, 3), (5, 4)}
    rng = np.random.default_rng(3)
    kills = inj.random_kills(rng, candidates=range(8), n_kills=2,
                             max_round=6)
    assert len(kills) == 2 and all(0 <= r <= 6 for r, _ in kills)
    assert len({p for _, p in kills}) == 2  # distinct victims


# ---------------------------------------------------------------------------
# repair_with_faults: restart against the enlarged erasure set
# ---------------------------------------------------------------------------

def test_repair_no_faults_matches_closed_form():
    spec = _spec("rs", 8, 4)
    W = 3
    cw = _codeword(spec, FERMAT.rand((8, W), RNG))
    rep = repair_with_faults(spec, cw, erased=(0, 9))
    assert np.array_equal(rep.codeword, cw)
    assert rep.restarts == 0 and len(rep.attempts) == 1
    c = decode_cost(8, 2, spec.p)
    a = rep.attempts[0]
    assert a.completed and (a.C1, a.C2) == (c.C1, c.C2 * W)
    assert (rep.net.C1, rep.net.C2) == (c.C1, c.C2 * W)


@pytest.mark.parametrize("kind,K,R,erased", CASES)
def test_repair_with_mid_schedule_kill_all_kinds(kind, K, R, erased):
    """A kill aborting the schedule mid-run recovers to the correct full
    codeword, with the network accounting the aborted prefix plus the
    retry EXACTLY (last attempt == closed form)."""
    spec = _spec(kind, K, R)
    W = 4
    cw = _codeword(spec, FERMAT.rand((K, W), RNG))
    base = Decoder.plan(spec, erased=erased, backend="simulator")
    victim = base.kept[1]  # an active survivor: guaranteed mid-run traffic
    net = RoundNetwork(spec.N, spec.p)
    FaultInjector(net).kill_at(1, (victim,))
    rep = repair_with_faults(spec, cw, erased=erased, net=net)
    assert np.array_equal(rep.codeword, cw), (kind, erased)
    assert victim in rep.erased and set(erased) <= set(rep.erased)
    # exact accounting: totals are the sum of per-attempt deltas, and the
    # final (completed) attempt costs exactly the closed form
    assert net.C1 == sum(a.C1 for a in rep.attempts)
    assert net.C2 == sum(a.C2 for a in rep.attempts)
    last = rep.attempts[-1]
    c = decode_cost(K, len(last.erased), spec.p)
    assert last.completed and (last.C1, last.C2) == (c.C1, c.C2 * W)
    aborted = [a for a in rep.attempts if not a.completed]
    assert aborted and victim in aborted[0].killed
    assert aborted[0].C1 < decode_cost(K, len(erased), spec.p).C1


def test_repair_kill_at_round_zero_planned_around():
    """A kill due before the first round enlarges the pattern up front —
    no abort, one attempt."""
    spec = _spec("rs", 8, 4)
    cw = _codeword(spec, FERMAT.rand((8, 2), RNG))
    net = RoundNetwork(spec.N, spec.p)
    net.fail_at(0, (4,))
    rep = repair_with_faults(spec, cw, erased=(0,), net=net)
    assert np.array_equal(rep.codeword, cw)
    assert rep.restarts == 0 and rep.erased == (0, 4)


def test_repair_idle_survivor_kill_gets_followup_pass():
    """A kill landing on a processor the schedule no longer touches does
    not abort — but its symbol is still lost, so a follow-up pass must
    recompute it before the repair returns."""
    spec = _spec("rs", 8, 4)
    cw = _codeword(spec, FERMAT.rand((8, 3), RNG))
    # the (0, 9) decode runs 3 rounds; proc 3 idles in the final round
    net = RoundNetwork(spec.N, spec.p)
    net.fail_at(2, (3,))
    rep = repair_with_faults(spec, cw, erased=(0, 9), net=net)
    assert np.array_equal(rep.codeword, cw)
    assert 3 in rep.erased
    assert all(a.completed for a in rep.attempts) and len(rep.attempts) == 2


def test_repair_beyond_R_refused():
    spec = _spec("rs", 8, 4)
    cw = _codeword(spec, FERMAT.rand((8, 2), RNG))
    net = RoundNetwork(spec.N, spec.p)
    FaultInjector(net).kill_at(1, (4, 5))
    with pytest.raises(ValueError, match="exceed"):
        repair_with_faults(spec, cw, erased=(0, 1, 2), net=net)


def test_repair_validates_leading_dim():
    spec = _spec("rs", 8, 4)
    with pytest.raises(ValueError, match="N=12"):
        repair_with_faults(spec, FERMAT.rand((8, 2), RNG), erased=(0,))


# ---------------------------------------------------------------------------
# CodedSystem.rebuild / rebuild_stream
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,K,R,erased", CASES)
def test_rebuild_bitwise_across_backends(kind, K, R, erased):
    spec = _spec(kind, K, R)
    x = FERMAT.rand((K, 5), RNG)
    outs = {}
    for backend in ("simulator", "local"):
        system = CodedSystem(spec, backend=backend)
        cw = system.codeword(x)
        # full (N, W) codeword input
        system.fail(erased)
        healed = system.rebuild(cw)
        assert np.array_equal(healed, cw), (kind, backend)
        assert system.failed == ()  # rebuild heals
        # (K, W) kept-ordered survivors input: the unkept survivor rows
        # are recomputed too (complement-pattern decode)
        system.fail(erased)
        healed2 = system.rebuild(cw[list(system.kept)])
        assert np.array_equal(healed2, cw), (kind, backend, "K-input")
        assert system.failed == ()
        outs[backend] = healed
    assert np.array_equal(outs["simulator"], outs["local"])


def test_rebuild_shapes_and_healthy():
    spec = _spec("rs", 8, 4)
    system = CodedSystem(spec, backend="simulator")
    x = FERMAT.rand((8,), RNG)
    cw = system.codeword(x)
    system.fail((0, 9))
    assert np.array_equal(system.rebuild(cw), cw)  # 1-D round-trips
    # healthy rebuild: passthrough for N rows, parity recompute for K rows
    assert np.array_equal(system.rebuild(cw), cw)
    assert np.array_equal(system.rebuild(x), cw)
    with pytest.raises(ValueError, match="leading dim"):
        system.rebuild(cw[:5])


def test_rebuild_stream_bitwise_and_heals_on_exhaustion():
    spec = _spec("rs", 8, 4)
    system = CodedSystem(spec, backend="local")
    x = FERMAT.rand((8, 300), RNG)
    cw = system.codeword(x)
    system.fail((2, 4, 11))
    got = np.concatenate(list(system.rebuild_stream(cw, chunk_w=128)),
                         axis=1)
    assert np.array_equal(got, cw)
    assert system.failed == ()
    # ragged (N, w) chunks and (K, w) survivor chunks both work
    system.fail((2, 4, 11))
    kept = list(system.kept)
    got2 = np.concatenate(list(system.rebuild_stream(
        (cw[:, i : i + 77] for i in range(0, 300, 77)), chunk_w=128)),
        axis=1)
    assert np.array_equal(got2, cw)
    system.fail((2, 4, 11))
    got3 = np.concatenate(list(system.rebuild_stream(
        (cw[kept, i : i + 64] for i in range(0, 300, 64)))), axis=1)
    assert np.array_equal(got3, cw)
    assert system.failed == ()
    # an unconsumed stream heals nothing
    system.fail((2,))
    stream = system.rebuild_stream(cw)
    assert system.failed == (2,)
    list(stream)
    assert system.failed == ()
    system.close()


def test_rebuild_stream_pins_pattern_and_heals_only_it():
    """Erasure churn mid-stream: chunks in flight keep the pattern pinned
    at creation, and exhaustion heals ONLY that pattern — a concurrent
    fail() landing mid-rebuild stays failed."""
    spec = _spec("rs", 8, 4)
    system = CodedSystem(spec, backend="simulator")
    x = FERMAT.rand((8, 60), RNG)
    cw = system.codeword(x)
    system.fail((0, 9))
    stream = system.rebuild_stream(cw, chunk_w=16)
    first = next(stream)
    system.fail(3)  # lands mid-rebuild
    rest = list(stream)
    healed = np.concatenate([first] + rest, axis=1)
    assert np.array_equal(healed, cw)  # pinned pattern: 3 never consulted
    assert system.failed == (3,)       # ...and stays failed after healing


def test_decode_stream_pinned_under_churn():
    spec = _spec("rs", 8, 4)
    system = CodedSystem(spec, backend="simulator")
    x = FERMAT.rand((8, 40), RNG)
    cw = system.codeword(x)
    system.fail((1, 8))
    stream = system.decode_stream(cw, chunk_w=8)
    first = next(stream)
    system.fail(5)
    system.heal(1)  # shrink AND grow while chunks are in flight
    rep = np.concatenate([first] + list(stream), axis=1)
    assert np.array_equal(rep, cw[[1, 8]])  # the pattern pinned at creation


# ---------------------------------------------------------------------------
# queued rebuild + superset failover
# ---------------------------------------------------------------------------

def test_submit_rebuild_roundtrip():
    spec = _spec("rs", 8, 4)
    with CodedSystem(spec, backend="local") as system:
        x = FERMAT.rand((8, 17), RNG)
        cw = system.codeword(x)
        system.fail((0, 9))
        fut = system.submit("rebuild", cw)
        assert np.array_equal(fut.result(timeout=60), cw)
        # queued rebuild does NOT auto-heal (the worker must not mutate
        # session state behind the caller's back)
        assert system.failed == (0, 9)
        with pytest.raises(ValueError, match="full N=12"):
            system.submit("rebuild", cw[list(system.kept)])
        with pytest.raises(ValueError, match="op must be"):
            system.submit("transmogrify", cw)


def test_queue_failover_avoids_dead_rows():
    """The pinned pattern is invalidated by a strict-superset live
    pattern: the queue must replan and never consume the newly-dead rows
    (here poisoned to prove they are untouched).  A decode future still
    resolves to its pinned rows; a rebuild future recomputes ALL superset
    positions."""
    spec = _spec("rs", 8, 4)
    x = FERMAT.rand((8, 6), RNG)
    cw = _codeword(spec, x, backend="local")
    E1, E2 = (0, 9), (0, 2, 9)
    poisoned = cw.copy()
    poisoned[2] = (poisoned[2] + 12345) % spec.q  # proc 2 died post-submit
    q = CodingQueue(backend="local")
    try:
        fd = q.submit_decode(spec, E1, poisoned, pattern_ref=lambda: E2)
        assert np.array_equal(fd.result(timeout=60), cw[list(E1)])
        fr = q.submit_rebuild(spec, E1, poisoned, pattern_ref=lambda: E2)
        assert np.array_equal(fr.result(timeout=60), cw)
        assert q.stats.failovers == 2
        # a K-row payload cannot be re-sliced: fails loudly, no stale rows
        plan1 = Decoder.plan(spec, erased=E1, backend="local")
        fk = q.submit_decode(spec, E1, cw[list(plan1.kept)],
                             pattern_ref=lambda: E2)
        with pytest.raises(RuntimeError, match="invalidated"):
            fk.result(timeout=60)
        # a SHRUNK pattern (heal) is not a failover: pinned plan stands
        fs = q.submit_decode(spec, E1, cw, pattern_ref=lambda: (0,))
        assert np.array_equal(fs.result(timeout=60), cw[list(E1)])
        assert q.stats.failovers == 3  # only the three supersets above
    finally:
        q.close()


class _GatedBackend(Backend):
    """Host-matmul executor whose encode blocks on an event once `armed`
    — makes the submit -> fail -> drain interleaving deterministic in
    tests (the queue worker stalls on an encode group while later
    requests pile up behind it)."""

    def __init__(self, armed: bool = True):
        self.armed = armed
        self.entered = threading.Event()
        self.release = threading.Event()

    def encode(self, plan, x):
        if self.armed:
            self.entered.set()
            assert self.release.wait(timeout=120)
        return plan.field.matmul(plan.A.T, x)

    def decode(self, plan, v):
        return plan.field.matmul(plan.tables.D.T, v)


def test_session_failover_end_to_end():
    """fail() AFTER submit but BEFORE the worker drains: the session's
    pattern_ref hands the queue the superset, deterministically forced by
    blocking the worker on an earlier encode group."""
    be = _GatedBackend(armed=False)
    register_backend("gated", be)
    try:
        spec = _spec("rs", 8, 4)
        system = CodedSystem(spec, backend="gated")
        x = FERMAT.rand((8, 5), RNG)
        cw = system.codeword(x)
        system.fail((0,))
        be.armed = True  # gate only the queue worker's encode group
        f_block = system.submit("encode", x)    # occupies the worker
        assert be.entered.wait(timeout=60)
        poisoned = cw.copy()
        poisoned[1] = (poisoned[1] + 7) % spec.q
        f_dec = system.submit("decode", poisoned)   # pinned to (0,)
        f_reb = system.submit("rebuild", poisoned)
        system.fail(1)                          # invalidates both
        be.release.set()
        assert np.array_equal(f_block.result(timeout=60), cw[8:])
        assert np.array_equal(f_dec.result(timeout=60), cw[[0]])
        assert np.array_equal(f_reb.result(timeout=60), cw)
        st = system.stats()
        assert st["queue"].failovers == 2
        system.close()
    finally:
        unregister_backend("gated")


def test_churn_threads_rebuild_and_decode_futures_resolve():
    """Concurrent fail/heal churn (disjoint position pools, total <= R)
    racing queued submissions: every rebuild future must still resolve to
    the exact full codeword, every decode future to correct rows."""
    spec = _spec("rs", 8, 4)
    system = CodedSystem(spec, backend="local")
    x = FERMAT.rand((8, 31), RNG)
    cw = system.codeword(x)
    stop = threading.Event()
    errors: list = []

    def churn(pool):
        rng = np.random.default_rng(pool[0])
        try:
            while not stop.is_set():
                system.fail(int(rng.choice(pool)))
                system.heal(int(rng.choice(pool)))
        except Exception as exc:  # noqa: BLE001 — surface in main thread
            errors.append(repr(exc))

    threads = [threading.Thread(target=churn, args=(pool,))
               for pool in ([2, 3], [9, 10])]
    for t in threads:
        t.start()
    try:
        futs = [system.submit("rebuild", cw) for _ in range(12)]
        for fut in futs:
            assert np.array_equal(fut.result(timeout=120), cw)
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=60)
    assert not errors, errors[:3]
    system.close()


# ---------------------------------------------------------------------------
# stats()/describe() on undecodable patterns (bugfix)
# ---------------------------------------------------------------------------

def test_stats_and_describe_survive_undecodable_dft_pattern():
    from repro.recover import UndecodableError

    spec = _spec("dft", 8, 8)
    system = CodedSystem(spec, backend="simulator")
    x = FERMAT.rand((8, 2), RNG)
    cw = system.codeword(x)
    system.fail(DFT_UNDECODABLE)
    with pytest.raises(UndecodableError):
        system.decode_plan  # the pattern really is information-losing
    st = system.stats()  # ...but introspection must not crash
    assert st["decode"]["decodable"] is False
    assert st["decode"]["erased"] == DFT_UNDECODABLE
    text = system.describe()
    assert "UNDECODABLE" in text
    # reads still raise (correctly); heal restores everything
    with pytest.raises(UndecodableError):
        system.read(cw)
    system.heal()
    st = system.stats()
    assert "decode" not in st
    system.fail((5, 9))
    system.read(cw)
    assert system.stats()["decode"]["decodable"] is True


# ---------------------------------------------------------------------------
# CodingQueue.close() timeout (bugfix)
# ---------------------------------------------------------------------------

def test_queue_close_timeout_fails_pending_futures():
    be = _GatedBackend()
    register_backend("gated-close", be)
    try:
        spec = _spec("rs", 8, 4)
        q = CodingQueue(backend="gated-close")
        x = FERMAT.rand((8, 3), RNG)
        f1 = q.submit_encode(spec, x)
        assert be.entered.wait(timeout=60)
        f2 = q.submit_encode(spec, x)  # queued behind the blocked group
        with pytest.raises(RuntimeError, match="did not drain"):
            q.close(timeout=0.2)
        # the stranded futures are FAILED, not silently dangling
        for f in (f1, f2):
            with pytest.raises(RuntimeError, match="did not drain"):
                f.result(timeout=1)
        # new submissions are refused after the (attempted) close
        with pytest.raises(RuntimeError, match="closed"):
            q.submit_encode(spec, x)
        be.release.set()
    finally:
        be.release.set()
        unregister_backend("gated-close")


def test_queue_close_clean_drain_still_resolves_everything():
    spec = _spec("rs", 8, 4)
    q = CodingQueue(backend="local")
    x = FERMAT.rand((8, 3), RNG)
    futs = [q.submit_encode(spec, x) for _ in range(5)]
    q.close()
    from repro.api import Encoder

    expect = Encoder.plan(spec, backend="local").run(x)
    for f in futs:
        assert np.array_equal(f.result(timeout=1), expect)


# ---------------------------------------------------------------------------
# checkpoint scrub: verify + rebuild in place off memmaps
# ---------------------------------------------------------------------------

def test_checkpoint_scrub_rebuilds_missing_and_corrupt(tmp_path):
    import json

    from repro.ckpt.checkpoint import CodedCheckpointer

    state = {"w": np.arange(4096, dtype=np.float32).reshape(64, 64),
             "b": np.ones(777, dtype=np.float32)}
    ck = CodedCheckpointer(str(tmp_path), n_shards=8, n_parity=4)
    ck.save(3, state)
    d = tmp_path / "step_000003"
    meta = json.loads((d / "meta.json").read_text())
    assert len(meta["sha256"]) == 12  # every shard + parity is covered
    assert ck.scrub(3)["rebuilt"] == []  # clean checkpoint: no-op
    # one missing shard, one silently-corrupt shard, one corrupt parity
    (d / "shard_002.npy").unlink()
    for name in ("shard_005.npy", "parity_001.npy"):
        arr = np.load(d / name)
        arr[7] = (arr[7] + 1) % 65537
        np.save(d / name, arr)
    rep = ck.scrub()  # default: latest step
    assert rep["missing"] == [2] and sorted(rep["corrupt"]) == [5, 9]
    assert rep["rebuilt"] == [2, 5, 9] and rep["verified"]
    # in-place rebuild is bitwise: files verify clean, restore round-trips
    assert ck.scrub(3)["rebuilt"] == []
    got = ck.restore(3, state)
    assert np.array_equal(got["w"], state["w"])
    assert np.array_equal(got["b"], state["b"])
    # beyond R damaged files the scrub refuses loudly
    for k in (0, 1, 3, 4, 6):
        (d / f"shard_00{k}.npy").unlink()
    with pytest.raises(RuntimeError, match="unrecoverable"):
        ck.scrub(3)
