"""Optional-hypothesis shim: property tests skip (instead of erroring at
collection) when hypothesis is not installed.

    from conftest_hypothesis import given, settings, st

With hypothesis present these are the real objects; without it, `@given`
turns the test into a pytest-skip and `st.*` return inert placeholders.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on environment
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):
        return lambda f: f

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
