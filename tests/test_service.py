"""The multi-tenant serving layer: CodedService session pooling,
admission control (quotas, backpressure, weighted-fair waiter grants),
cross-session coalescing — including the isolation guarantee that two
tenants with different generator matrices NEVER share a coalesced batch —
per-tenant/per-tag stats, and the CodingQueue submit/close race.

The blocking-backend fixture (`_GatedBackend`) holds the queue worker
inside `encode` until the test releases it, so tests can pile requests
into the queue deterministically and assert exactly how they coalesce.
"""
import threading
import time

import numpy as np
import pytest

from repro.api import (
    Backend,
    CodedSystem,
    CodeSpec,
    register_backend,
    unregister_backend,
)
from repro.core.field import FERMAT
from repro.launch.coding_queue import CodingQueue
from repro.launch.service import (
    CodedService,
    QueueFullError,
    TenantQuota,
)
from repro.launch.tenancy import AdmissionController, percentile

RNG = np.random.default_rng(41)


def _wait_until(pred, timeout=10.0, what="condition"):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return
        time.sleep(0.002)
    raise AssertionError(f"timed out waiting for {what}")


# ---------------------------------------------------------------------------
# end-to-end round trips through the service
# ---------------------------------------------------------------------------

def test_service_round_trip_encode_decode_rebuild():
    spec = CodeSpec(kind="rs", K=8, R=4, W=6)
    x = FERMAT.rand((8, 6), RNG)
    ref = CodedSystem(spec, backend="local")
    cw = ref.codeword(x)
    with CodedService(backend="local") as svc:
        parity = svc.submit("t0", spec, "encode", x).result(timeout=60)
        assert np.array_equal(parity, cw[8:])

        sess = svc.session("t0", spec)
        sess.fail((2, 9))
        lost = svc.submit("t0", spec, "decode", cw).result(timeout=60)
        assert np.array_equal(lost, cw[[2, 9]])
        healed = svc.submit("t0", spec, "rebuild", cw).result(timeout=60)
        assert np.array_equal(healed, cw)

        st = svc.stats()
        t = st["tenants"]["t0"]
        assert t["submitted"] == 3 and t["completed"] == 3
        assert t["failed"] == 0 and t["inflight_ops"] == 0
        assert st["service"]["requests"] == 3
    with pytest.raises(RuntimeError):
        svc.submit("t0", spec, "encode", x)
    with pytest.raises(RuntimeError):
        svc.session("t0", spec)


def test_session_pool_identity_and_lru_eviction():
    spec = CodeSpec(kind="rs", K=8, R=4)
    svc = CodedService(backend="local", max_sessions=2)
    try:
        s0 = svc.session("a", spec)
        assert svc.session("a", spec) is s0          # pooled, not rebuilt
        assert svc.session("b", spec) is not s0      # per-tenant sessions
        # a session with live erasure state must survive eviction: its
        # failure pattern is system truth, not a cache entry
        s0.fail(1)
        svc.session("c", spec)
        assert svc.sessions == 2                     # b evicted, a kept
        assert svc.session("a", spec) is s0
        assert svc.session("a", spec).failed == (1,)
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# a backend whose encode blocks until released — deterministic queue piling
# ---------------------------------------------------------------------------

class _GatedBackend(Backend):
    """Host matmul that holds the queue worker until `gate` is set;
    `entered` proves the worker is INSIDE an execution (its batch is
    sealed), so later submissions deterministically pile into the NEXT
    drain rather than racing into the current one."""

    gate = threading.Event()
    entered = threading.Event()

    def encode(self, plan, x):
        type(self).entered.set()
        type(self).gate.wait(timeout=60)
        return plan.field.matmul(plan.A.T, x)

    def decode(self, plan, v):
        type(self).entered.set()
        type(self).gate.wait(timeout=60)
        return plan.field.matmul(plan.tables.D.T, v)


@pytest.fixture()
def gated_backend():
    _GatedBackend.gate = threading.Event()
    _GatedBackend.entered = threading.Event()
    register_backend("gated-host", _GatedBackend)
    try:
        yield "gated-host"
    finally:
        _GatedBackend.gate.set()
        unregister_backend("gated-host")


def test_cross_session_coalescing_shares_one_batch(gated_backend):
    """Same (spec, backend, A-digest) from DIFFERENT tenants coalesces
    into one batch; every future still gets its own rows."""
    spec = CodeSpec(kind="rs", K=8, R=4, W=4)
    xs = [FERMAT.rand((8, 4), RNG) for _ in range(4)]
    plan_ref = CodedSystem(spec, backend="local")
    with CodedService(backend=gated_backend) as svc:
        # occupy the worker so the next submissions pile up and coalesce
        warm = svc.submit("t0", spec, "encode", xs[0])
        assert _GatedBackend.entered.wait(timeout=60)
        futs = [svc.submit(f"t{i % 2}", spec, "encode", x, tag="shared")
                for i, x in enumerate(xs)]
        _wait_until(lambda: svc.queue_depth == 5, what="5 queued ops")
        _GatedBackend.gate.set()
        for x, fut in zip(xs, futs):
            assert np.array_equal(fut.result(timeout=60),
                                  plan_ref.codeword(x)[8:])
        warm.result(timeout=60)
        st = svc.stats()
        # 1 warmup batch + 1 coalesced batch of 4 (cross-tenant)
        assert st["service"]["requests"] == 5
        assert st["service"]["batches"] == 2
        assert st["tags"]["shared"]["coalescing_ratio"] == pytest.approx(4.0)


def test_tenant_matrices_never_share_a_batch(gated_backend):
    """Two tenants, same spec, DIFFERENT explicit A matrices: their
    requests must never coalesce into one execution — each future is
    bitwise its own matrix's parity and each group holds one tenant."""
    K, R, W = 8, 4, 4
    spec = CodeSpec(kind="universal", K=K, R=R, W=W)
    rng = np.random.default_rng(97)
    A1, A2 = FERMAT.rand((K, R), rng), FERMAT.rand((K, R), rng)
    assert not np.array_equal(A1, A2)
    x = FERMAT.rand((K, W), rng)
    with CodedService(backend=gated_backend) as svc:
        warm = svc.submit("ta", spec, "encode", x, A=A1)
        assert _GatedBackend.entered.wait(timeout=60)
        futs_a = [svc.submit("ta", spec, "encode", x, A=A1, tag="volA")
                  for _ in range(2)]
        futs_b = [svc.submit("tb", spec, "encode", x, A=A2, tag="volB")
                  for _ in range(2)]
        _wait_until(lambda: svc.queue_depth == 5, what="5 queued ops")
        _GatedBackend.gate.set()
        exp_a = FERMAT.matmul(A1.T, x)
        exp_b = FERMAT.matmul(A2.T, x)
        assert not np.array_equal(exp_a, exp_b)
        for fut in futs_a:
            assert np.array_equal(fut.result(timeout=60), exp_a)
        for fut in futs_b:
            assert np.array_equal(fut.result(timeout=60), exp_b)
        warm.result(timeout=60)
        st = svc.stats()
        # the 4 piled ops split into TWO digest-keyed batches, never one
        assert st["service"]["batches"] == 3  # warmup + volA + volB
        assert st["tags"]["volA"]["coalescing_ratio"] == pytest.approx(2.0)
        assert st["tags"]["volB"]["coalescing_ratio"] == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# admission control through the service
# ---------------------------------------------------------------------------

def test_admission_quota_rejects_loudly_and_recovers(gated_backend):
    spec = CodeSpec(kind="rs", K=8, R=4, W=4)
    x = FERMAT.rand((8, 4), RNG)
    with CodedService(backend=gated_backend,
                      default_quota=TenantQuota(max_inflight_ops=2)) as svc:
        f1 = svc.submit("t0", spec, "encode", x)
        f2 = svc.submit("t0", spec, "encode", x)
        with pytest.raises(QueueFullError):
            svc.submit("t0", spec, "encode", x, block=False)
        with pytest.raises(QueueFullError):
            svc.submit("t0", spec, "encode", x, timeout=0.05)
        # another tenant is NOT throttled by t0's quota
        f3 = svc.submit("t1", spec, "encode", x, block=False)
        _GatedBackend.gate.set()
        for f in (f1, f2, f3):
            f.result(timeout=60)
        # slots released on completion: t0 admits again
        _wait_until(lambda: svc.stats()["service"]["inflight_ops"] == 0,
                    what="slots released")
        svc.submit("t0", spec, "encode", x, block=False).result(timeout=60)
        assert svc.stats()["tenants"]["t0"]["rejected"] == 2


def test_admission_backpressure_blocks_then_admits(gated_backend):
    spec = CodeSpec(kind="rs", K=8, R=4, W=4)
    x = FERMAT.rand((8, 4), RNG)
    with CodedService(backend=gated_backend,
                      default_quota=TenantQuota(max_inflight_ops=1)) as svc:
        first = svc.submit("t0", spec, "encode", x)
        got = {}

        def blocked_submit():
            got["fut"] = svc.submit("t0", spec, "encode", x)  # blocks

        th = threading.Thread(target=blocked_submit)
        th.start()
        _wait_until(lambda: svc.stats()["service"]["waiting"] == 1,
                    what="submission waiting on admission")
        assert "fut" not in got
        _GatedBackend.gate.set()      # first op completes -> slot frees
        th.join(timeout=60)
        assert not th.is_alive()
        assert np.array_equal(got["fut"].result(timeout=60),
                              first.result(timeout=60))


# ---------------------------------------------------------------------------
# AdmissionController unit behavior (fairness, FIFO, bookkeeping)
# ---------------------------------------------------------------------------

def test_admission_weighted_fair_grant_order():
    """When slots free, the grant goes to the tenant with the smallest
    weight-normalized in-flight load — not to the earliest waiter."""
    ac = AdmissionController(max_ops=2)
    ac.acquire("hog")
    ac.acquire("hog")            # hog holds the whole service
    order = []
    cv = threading.Condition()

    def waiter(tenant):
        ac.acquire(tenant)
        with cv:
            order.append(tenant)
            cv.notify_all()

    t_hog = threading.Thread(target=waiter, args=("hog",))
    t_hog.start()                # hog queues FIRST (earlier seq)
    _wait_until(lambda: ac.waiting == 1, what="hog waiter queued")
    t_light = threading.Thread(target=waiter, args=("light",))
    t_light.start()
    _wait_until(lambda: ac.waiting == 2, what="both waiters queued")

    ac.release("hog")            # one slot frees: light must win it
    with cv:
        assert cv.wait_for(lambda: len(order) == 1, timeout=10)
        assert order == ["light"]
    ac.release("hog")            # now hog's waiter gets the next slot
    with cv:
        assert cv.wait_for(lambda: len(order) == 2, timeout=10)
        assert order == ["light", "hog"]
    t_hog.join(timeout=10)
    t_light.join(timeout=10)
    ops, _ = ac.inflight()
    assert ops == 2


def test_admission_weight_biases_grants():
    """A weight-2 tenant is allowed twice the in-flight load before its
    waiter loses priority: with 2 ops in flight each, heavy (2/2=1) beats
    light (2/1=2) for the freed slot — despite light queueing FIRST."""
    ac = AdmissionController(max_ops=5)
    ac.set_quota("heavy", TenantQuota(weight=2.0))
    for t in ("heavy", "light"):
        ac.acquire(t)
        ac.acquire(t)
    ac.acquire("z")              # fills the 5th slot; freed below
    order = []
    cv = threading.Condition()

    def waiter(tenant):
        ac.acquire(tenant)
        with cv:
            order.append(tenant)
            cv.notify_all()

    t_light = threading.Thread(target=waiter, args=("light",))
    t_light.start()              # light queues first
    _wait_until(lambda: ac.waiting == 1, what="light waiter queued")
    t_heavy = threading.Thread(target=waiter, args=("heavy",))
    t_heavy.start()
    _wait_until(lambda: ac.waiting == 2, what="both waiters queued")
    ac.release("z")              # heavy 2/2=1.0 beats light 2/1=2.0
    with cv:
        assert cv.wait_for(lambda: len(order) == 1, timeout=10)
        assert order == ["heavy"]
    ac.release("light")          # light's own slot frees its waiter
    t_light.join(timeout=10)
    t_heavy.join(timeout=10)


def test_admission_tenant_fifo_no_bypass():
    """An op never jumps ahead of its own tenant's queued waiters, even
    when a slot is technically free at submit time."""
    ac = AdmissionController(max_ops=1)
    ac.acquire("t")

    def waiter():
        ac.acquire("t")

    th = threading.Thread(target=waiter)
    th.start()
    _wait_until(lambda: ac.waiting == 1, what="waiter queued")
    with pytest.raises(QueueFullError):
        ac.acquire("t", block=False)
    ac.release("t")              # waiter takes the slot, not the bypasser
    th.join(timeout=10)
    assert ac.inflight("t") == (1, 0)
    ac.release("t")
    ac.acquire("t", block=False)  # no waiters left: fast path admits


def test_admission_oversized_payload_runs_alone():
    ac = AdmissionController(max_bytes=100)
    ac.acquire("t", nbytes=1000)          # empty ledger: admitted alone
    with pytest.raises(QueueFullError):
        ac.acquire("t", nbytes=1, block=False)
    ac.release("t", nbytes=1000)
    ac.acquire("t", nbytes=1, block=False)


# ---------------------------------------------------------------------------
# CodingQueue submit/close race (regression)
# ---------------------------------------------------------------------------

def test_queue_submit_after_close_raises():
    spec = CodeSpec(kind="rs", K=8, R=4, W=4)
    q = CodingQueue(backend="local")
    q.close()
    with pytest.raises(RuntimeError, match="closed"):
        q.submit_encode(spec, FERMAT.rand((8, 4), RNG))


def test_queue_submit_close_race_never_hangs():
    """Hammer the submit/close boundary: every submit either returns a
    future that RESOLVES or raises RuntimeError immediately — a submission
    accepted during close must not strand its future."""
    spec = CodeSpec(kind="rs", K=8, R=4, W=2)
    x = FERMAT.rand((8, 2), RNG)
    for _ in range(5):
        q = CodingQueue(backend="local")
        futs, raised = [], []
        start = threading.Barrier(4)

        def submitter():
            start.wait(timeout=10)
            for _ in range(20):
                try:
                    futs.append(q.submit_encode(spec, x))
                except RuntimeError:
                    raised.append(1)
                    return

        threads = [threading.Thread(target=submitter) for _ in range(3)]
        for t in threads:
            t.start()
        start.wait(timeout=10)
        q.close(timeout=60)
        for t in threads:
            t.join(timeout=60)
            assert not t.is_alive()
        for fut in futs:          # accepted => resolved, never stranded
            assert np.asarray(fut.result(timeout=60)).shape == (4, 2)


# ---------------------------------------------------------------------------
# stats plumbing
# ---------------------------------------------------------------------------

def test_percentile_nearest_rank():
    xs = list(range(1, 101))
    assert percentile(xs, 0.50) == 50
    assert percentile(xs, 0.99) == 99
    assert percentile(xs, 0.999) == 100
    assert percentile([7], 0.999) == 7
    assert np.isnan(percentile([], 0.5))


def test_describe_and_latency_reservoir():
    spec = CodeSpec(kind="rs", K=8, R=4, W=4)
    x = FERMAT.rand((8, 4), RNG)
    with CodedService(backend="local") as svc:
        for _ in range(3):
            svc.submit("acme", spec, "encode", x, tag="v0").result(timeout=60)
        text = svc.describe()
        assert "acme" in text and "v0" in text and "coalesce=" in text
        lats = svc.latencies_us("acme")
        assert len(lats) == 3 and all(v > 0 for v in lats)
        assert len(svc.latencies_us()) == 3
        snap = svc.stats()["tenants"]["acme"]
        assert snap["p50_us"] <= snap["p99_us"] <= snap["p999_us"]
