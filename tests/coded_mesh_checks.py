"""Coded-inference checks on the mesh backend, 8 forced host devices
(subprocess companion of test_coding.py — jax locks the device count at
first init).

The tentpole claim, end to end: a layer matmul Y = X @ W runs
Lagrange-coded through `CodedMatmul`'s `CodedSystem` session on the MESH
backend, and the decode (the existing `recover/` stack) recovers Y
bitwise-exactly around every dropout count 0..R — including the full-R
patterns — with parity against the local kernel and the simulator oracle.
A deg-2 `LagrangeComputer.decode` leg exercises the shared decode-plan
routing on the mesh as well.

Prints 'CODED_MESH_CHECKS_OK' on success; any assertion failure is fatal.
"""
from _fake_devices import force_host_devices

force_host_devices(8)

import numpy as np

from repro.coding import CodedMatmul, LagrangeComputer
from repro.core.field import FERMAT

rng = np.random.default_rng(7)
K, R, b, d, out = 8, 4, 2, 16, 6  # mesh: R | K, K <= 8 devices

X = FERMAT.rand((K * b, d), rng)
W = FERMAT.rand((d, out), rng)
truth = FERMAT.matmul(X, W)

systems = {backend: CodedMatmul(K, R, backend=backend)
           for backend in ("simulator", "local", "mesh")}
mesh = systems["mesh"]
shards = mesh.encode(X)
assert np.array_equal(shards[:K].reshape(K * b, d), X % FERMAT.q), \
    "systematic data shards"
results = mesh.worker_compute(shards, W)

for nd in range(R + 1):
    patterns = [rng.choice(K + R, size=nd, replace=False) for _ in range(3)]
    if nd == R:
        patterns.append(np.arange(R))          # all parity down
        patterns.append(np.arange(K - R, K))   # R data shards down
    for dead in patterns:
        got = {name: cm.decode(results, dead=dead)
               for name, cm in systems.items()}
        for name, Y in got.items():
            assert np.array_equal(Y, truth), (name, nd, sorted(dead))
        assert not mesh.system.failed
    print(f"dropouts={nd}: mesh decode bitwise-exact "
          f"(== local == simulator), {len(patterns)} patterns")
for cm in systems.values():
    cm.close()

# LCC polynomial decode (deg 2) through the shared decode-plan path on the
# mesh: the virtual spec has K_spec = T = 2*(K-1)+1 <= 8 devices for K=4
lcc = LagrangeComputer.build(FERMAT, K=4, N=12)
x = FERMAT.rand((4, 5), rng)
res = FERMAT.add(FERMAT.mul(lcc.encode(x), lcc.encode(x)), 3)
want = FERMAT.add(FERMAT.mul(x % FERMAT.q, x % FERMAT.q), 3)
T = lcc.recovery_threshold(2)

from repro.recover.planner import Decoder

spec, A = lcc._decode_spec(2)
ids = np.sort(rng.choice(12, size=T + 2, replace=False))
live = set(int(w) for w in ids)
erased = tuple(range(4)) + tuple(4 + n for n in range(12) if n not in live)
plan = Decoder.plan(spec, erased, backend="mesh", A=A)
v = np.stack([res[pos - 4] for pos in plan.kept])  # res rows are worker ids
dec = plan.run(v)[:4]
assert np.array_equal(dec, want), "mesh LCC decode"
assert np.array_equal(dec, lcc.decode(2, ids, res[ids])), "mesh == local LCC"
print(f"LCC deg-2 decode on mesh: T={T}, {12 - len(live)} dead workers, "
      "bitwise == local plan path")

print("CODED_MESH_CHECKS_OK")
