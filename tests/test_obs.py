"""Unified observability layer: round-level tracing (Chrome trace-event
export), the one metrics registry, and the cost-model drift ledger —
trace correctness under chaos, snapshot consistency under concurrency,
and the zero-drift acceptance criterion across all four code kinds."""
import json
import threading

import numpy as np
import pytest

from repro.api import CodeSpec, CodedSystem, Encoder
from repro.core.field import FERMAT
from repro.core.simulator import PartialRunError, RoundNetwork
from repro.obs import drift, metrics, trace
from repro.recover import Decoder

RNG = np.random.default_rng(41)


def _spec(kind, K, R, **kw):
    if kind == "universal":
        kw.setdefault("seed", 5)
    return CodeSpec(kind=kind, K=K, R=R, **kw)


def _codeword(spec, x):
    plan = Encoder.plan(spec, backend="simulator")
    return np.concatenate([x % spec.q, plan.run(x)], axis=0)


# ---------------------------------------------------------------------------
# tracer: export shape + chaos correctness
# ---------------------------------------------------------------------------

def test_tracer_export_is_valid_chrome_trace(tmp_path):
    t = trace.Tracer()
    with t.span("work", pid="p", tid="t", args={"k": 1}):
        t.instant("mark", pid="p", tid="t")
    path = tmp_path / "out.json"
    t.save(path)
    d = json.loads(path.read_text())
    assert d["displayTimeUnit"] == "ms"
    evs = d["traceEvents"]
    # metadata names the string tracks; pid/tid in events are interned ints
    names = {e["args"]["name"] for e in evs if e["ph"] == "M"}
    assert {"p", "t"} <= names
    phs = [e["ph"] for e in evs if e["ph"] != "M"]
    assert sorted(phs) == ["X", "i"]
    assert all(isinstance(e["pid"], int) and isinstance(e["tid"], int)
               for e in evs)


def test_trace_rounds_bitwise_match_network_counters():
    spec = _spec("rs", 16, 4)
    x = FERMAT.rand((16, 3), RNG)
    with trace.installed() as t:
        plan = Encoder.plan(spec, backend="simulator")
        plan.run(x)
        net = plan.sim_net
        rounds = t.events(cat="sim.round")
    assert len(rounds) == net.C1
    assert sum(e["args"]["m_t"] for e in rounds) == net.C2
    # per-processor tracks tell the same story: per round, the max over
    # procs of sent elems IS that round's m_t contribution upper bound
    per_proc = t.events(cat="sim.proc")
    assert {e["args"]["round"] for e in per_proc} == \
        {e["args"]["round"] for e in rounds}


def test_chaos_kill_instant_lands_in_the_right_round():
    spec = _spec("rs", 8, 4)
    cw = _codeword(spec, FERMAT.rand((8, 3), RNG))
    tracer = trace.Tracer()
    net = RoundNetwork(spec.N, spec.p, tracer=tracer)
    net.fail_at(1, (3,))
    plan = Decoder.plan(spec, erased=(0, 9), backend="simulator")
    from repro.recover import decentralized_decode

    net.fail((0, 9))
    with pytest.raises(PartialRunError):
        decentralized_decode(FERMAT, plan.tables.D,
                             FERMAT.arr(cw[list(plan.kept)]),
                             list(plan.kept), spec.p, net)
    kills = tracer.events(cat="sim.fail", name="kill")
    assert [e["args"] for e in kills] == [{"round": 1, "proc": 3}]
    aborts = tracer.events(cat="sim.fail", name="abort")
    assert len(aborts) == 1 and aborts[0]["args"]["proc"] == 3
    # static fails got their own instants, on per-processor tracks
    fails = tracer.events(cat="sim.fail", name="fail")
    assert {e["args"]["proc"] for e in fails} == {0, 9}
    # the completed prefix is fully traced: one round event per accounted
    # round, C2 preserved bitwise
    rounds = tracer.events(cat="sim.round")
    assert len(rounds) == net.C1 == 1
    assert sum(e["args"]["m_t"] for e in rounds) == net.C2


def test_round_log_events_keep_legacy_tuple_contract():
    net = RoundNetwork(8, 1, keep_log=True, tracer=False)
    from repro.core.prepare_shoot import prepare_shoot

    out = {}
    vals = {k: FERMAT.rand((2,), RNG) for k in range(8)}
    net.run(prepare_shoot(FERMAT, FERMAT.rand((8, 8), RNG), vals,
                          list(range(8)), 1, out))
    assert len(net.round_log) > 0
    # legacy consumers unpack (n_msgs, m_t) 2-tuples
    assert net.C2 == sum(m for _, m in net.round_log)
    ev = net.round_log[0]
    assert len(ev) == 2 and ev[0] == ev.n_msgs and ev[1] == ev.m_t
    # the structured upgrade rides along: per-proc send/recv breakdowns
    # that sum to the round's traffic
    assert sum(n for _, n in ev.sent) == sum(n for _, n in ev.recv)


def test_tracing_off_means_no_tracer_consulted():
    assert trace.get_tracer() is None
    net = RoundNetwork(4, 1)
    assert net.tracer is None  # resolved once, hot path is one None check


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_registry_counter_gauge_histogram_roundtrip():
    reg = metrics.MetricsRegistry()
    reg.counter("ops_total", "ops").inc(2, op="encode")
    reg.gauge("depth").set(7, q="a")
    h = reg.histogram("lat_us")
    for v in (1.0, 3.0, 2.0):
        h.observe(v, op="encode")
    snap = reg.snapshot()
    assert snap["ops_total"]["values"]["op=encode"] == 2
    assert snap["depth"]["values"]["q=a"] == 7
    hv = snap["lat_us"]["values"]["op=encode"]
    assert hv == {"count": 3, "sum": 6.0, "min": 1.0, "max": 3.0,
                  "mean": 2.0}
    text = reg.render_text()
    assert 'repro_ops_total{op="encode"} 2' in text
    assert "repro_lat_us_count" in text
    with pytest.raises(ValueError):
        reg.gauge("ops_total")  # name already registered as a counter


def test_registry_snapshot_consistent_under_concurrency():
    reg = metrics.MetricsRegistry()
    a = reg.counter("a_total")
    b = reg.counter("b_total")
    stop = threading.Event()

    def writer():
        while not stop.is_set():
            # invariant: a is ALWAYS incremented before b
            a.inc(1, t="x")
            b.inc(1, t="x")

    threads = [threading.Thread(target=writer) for _ in range(4)]
    for t in threads:
        t.start()
    try:
        for _ in range(300):
            snap = reg.snapshot()
            av = snap["a_total"]["values"].get("t=x", 0)
            bv = snap["b_total"]["values"].get("t=x", 0)
            # one lock guards all families: no snapshot may catch b ahead
            # of a (each writer orders a before b under that lock)
            assert av >= bv
    finally:
        stop.set()
        for t in threads:
            t.join()


def test_plan_run_publishes_into_the_registry():
    before = metrics.REGISTRY.snapshot().get(
        "coded_runs_total", {}).get("values", {}).get(
        "backend=simulator,kind=rs,op=encode", 0)
    spec = _spec("rs", 8, 4)
    Encoder.plan(spec, backend="simulator").run(FERMAT.rand((8, 2), RNG))
    after = metrics.REGISTRY.snapshot()["coded_runs_total"]["values"][
        "backend=simulator,kind=rs,op=encode"]
    assert after == before + 1


# ---------------------------------------------------------------------------
# drift ledger: measured C1/C2 vs the closed-form model
# ---------------------------------------------------------------------------

KINDS = [("universal", 16, 4, (2, 17)), ("rs", 16, 4, (1, 18)),
         ("lagrange", 16, 4, (0, 19)), ("dft", 8, 8, (5, 9, 13))]


def test_zero_drift_across_all_kinds_on_simulator():
    drift.LEDGER.reset()
    for kind, K, R, erased in KINDS:
        spec = _spec(kind, K, R)
        x = FERMAT.rand((K, 3), RNG)
        sys1 = CodedSystem(spec, backend="simulator")
        cw = sys1.codeword(x)
        sys1.fail(erased)
        assert np.array_equal(sys1.decode(cw), cw[list(erased)])
        sys1.close()
    entries = drift.LEDGER.entries()
    # every kind contributed an encode AND a decode cell, all exact
    assert {(e.spec.kind, e.op) for e in entries} == \
        {(k, op) for k, _, _, _ in KINDS for op in ("encode", "decode")}
    assert all(e.runs == e.exact for e in entries)
    assert drift.LEDGER.drifted() == []
    assert "ZERO drift" in drift.LEDGER.describe()


def test_streamed_runs_keep_zero_drift():
    drift.LEDGER.reset()
    spec = _spec("rs", 16, 4)
    plan = Encoder.plan(spec, backend="simulator")
    for _ in plan.run_stream(FERMAT.rand((16, 400), RNG), chunk_w=128):
        pass
    entries = drift.LEDGER.entries()
    assert entries and drift.LEDGER.drifted() == []
    assert sum(e.runs for e in entries) == 4  # ceil(400/128) chunks


def test_drift_fails_loudly_on_model_mismatch():
    drift.LEDGER.reset()
    spec = _spec("rs", 8, 4)
    plan = Encoder.plan(spec, backend="simulator")
    net = RoundNetwork(spec.N, spec.p, tracer=False)
    net.C1, net.C2 = 999, 999  # a cooked measurement cannot match
    drift.record_run(plan, net, "encode", 1)
    bad = drift.LEDGER.drifted()
    assert len(bad) == 1 and bad[0].last_mismatch is not None
    assert "DRIFTED" in drift.LEDGER.describe()
    drift.LEDGER.reset()


def test_system_stats_surface_metrics_and_drift():
    drift.LEDGER.reset()
    spec = _spec("rs", 8, 4)
    with CodedSystem(spec, backend="simulator") as sys1:
        sys1.codeword(FERMAT.rand((8, 2), RNG))
        st = sys1.stats()
    assert "coded_runs_total" in st["metrics"]
    assert st["drift"]["drifted"] == 0
    assert st["drift"]["runs"] == st["drift"]["exact"] > 0
    with CodedSystem(spec, backend="local") as sys2:
        assert "drift" not in sys2.stats()  # nothing measured to compare


# ---------------------------------------------------------------------------
# ServiceStats latency reservoir (deque(maxlen=...) + dropped accounting)
# ---------------------------------------------------------------------------

def test_service_stats_reservoir_bounds_and_counts_drops():
    from repro.launch.tenancy import ServiceStats

    st = ServiceStats("t", reservoir=16)
    for i in range(40):
        st.record_submitted(8)
        st.record_done(float(i), 8, True)
    snap = st.snapshot()
    assert snap["lat_samples"] == 16
    assert snap["lat_dropped"] == 24
    # the reservoir keeps the NEWEST samples (deque maxlen semantics)
    assert st.latencies_us() == [float(i) for i in range(24, 40)]


# ---------------------------------------------------------------------------
# PlanStats thread-local contract (pinned by the PlanStats docstring)
# ---------------------------------------------------------------------------

def test_plan_stats_cross_thread():
    spec = _spec("rs", 8, 4)
    plan = Encoder.plan(spec, backend="simulator")
    plan.run(FERMAT.rand((8, 2), RNG))
    assert plan.last_stats is not None

    seen = {}

    def reader():
        # a thread that never ran the plan reads None — never another
        # thread's stats
        seen["last"] = plan.last_stats
        seen["stream"] = plan.stream_stats

    th = threading.Thread(target=reader)
    th.start()
    th.join()
    assert seen == {"last": None, "stream": None}
    assert plan.last_stats is not None  # the owner's view is untouched


# ---------------------------------------------------------------------------
# CodedSystem trace= user surface
# ---------------------------------------------------------------------------

def test_coded_system_trace_path_saved_on_close(tmp_path):
    path = tmp_path / "sys.json"
    spec = _spec("rs", 8, 4)
    sys1 = CodedSystem(spec, backend="simulator", trace=str(path))
    cw = sys1.codeword(FERMAT.rand((8, 2), RNG))
    sys1.fail([1])
    sys1.decode(cw)
    assert trace.get_tracer() is sys1.tracer
    sys1.close()
    assert trace.get_tracer() is None  # uninstalled, not leaked
    d = json.loads(path.read_text())
    cats = {e.get("cat") for e in d["traceEvents"]}
    assert "sim.round" in cats and "sim.proc" in cats
