"""Sec. III framework + Sec. VI RS method + Appendix B, end-to-end."""
import numpy as np
import pytest

from conftest_hypothesis import given, settings, st

from repro.core import FERMAT, RoundNetwork, decentralized_encode, nonsystematic_encode
from repro.core.cauchy import StructuredGRS, cauchy_a2a, cost_cauchy
from repro.core.matrices import lagrange_matrix

RNG = np.random.default_rng(7)


@pytest.mark.parametrize(
    "K,R,W,p",
    [(25, 4, 3, 1), (16, 4, 1, 1), (4, 25, 2, 1), (4, 16, 1, 2),
     (7, 3, 1, 1), (3, 7, 1, 2), (12, 12, 2, 1), (1, 5, 1, 1), (5, 1, 1, 1)],
)
def test_framework_universal(K, R, W, p):
    f = FERMAT
    A = f.rand((K, R), RNG)
    x = f.rand((K, W), RNG)
    y, net = decentralized_encode(f, A, x, p=p)
    assert np.array_equal(y, f.matmul(A.T, x))
    assert net.C1 > 0 or K == R == 1


@given(K=st.integers(1, 30), R=st.integers(1, 30), p=st.integers(1, 3),
       seed=st.integers(0, 2**31))
@settings(max_examples=40, deadline=None)
def test_framework_property(K, R, p, seed):
    """Any (K, R, p) and any A: sinks get x^T A (Def. 1)."""
    f = FERMAT
    rng = np.random.default_rng(seed)
    A = f.rand((K, R), rng)
    x = f.rand((K, 1), rng)
    y, _ = decentralized_encode(f, A, x, p=p)
    assert np.array_equal(y, f.matmul(A.T, x))


@pytest.mark.parametrize("K,R", [(32, 8), (16, 16), (8, 32), (64, 16)])
def test_framework_rs_method(K, R):
    """Specific (Cauchy-like) method gives identical results to universal."""
    f = FERMAT
    sgrs = StructuredGRS.build(f, K, R)
    A = sgrs.grs.A_direct()
    x = f.rand((K, 2), RNG)
    y_rs, net_rs = decentralized_encode(f, A, x, p=1, method="rs", sgrs=sgrs)
    y_un, _ = decentralized_encode(f, A, x, p=1)
    assert np.array_equal(y_rs, f.matmul(A.T, x))
    assert np.array_equal(y_rs, y_un)


def test_rs_encode_decode_any_k_of_n():
    """MDS property end-to-end: any K of the N=K+R coded/systematic symbols
    reconstruct the data (this is what coded checkpointing relies on)."""
    f = FERMAT
    K, R, W = 8, 4, 6
    sgrs = StructuredGRS.build(f, K, R)
    A = sgrs.grs.A_direct()
    x = f.rand((K, W), RNG)
    parity, _ = decentralized_encode(f, A, x, p=1, method="rs", sgrs=sgrs)
    full = np.concatenate([x, parity])  # systematic codeword (N, W)
    G = np.concatenate([np.eye(K, dtype=np.int64), A], axis=1)  # K x N
    rng = np.random.default_rng(3)
    for _ in range(10):
        keep = np.sort(rng.choice(K + R, size=K, replace=False))
        sub = G[:, keep]
        from repro.core.matrices import gauss_inverse

        rec = f.matmul(gauss_inverse(f, sub.T).T, full[keep])
        # x = (sub^T)^-1 applied: full[keep] = sub^T x  =>  x = (sub^T)^-1 full[keep]
        rec = f.matmul(gauss_inverse(f, sub.T), full[keep])
        assert np.array_equal(rec, x), f"reconstruction failed for {keep}"


def test_cauchy_block_is_lagrange_when_unit():
    """Remark 9: u = v = 1 makes A_m a Lagrange matrix."""
    f = FERMAT
    sgrs = StructuredGRS.build(f, 8, 8)
    A = sgrs.grs.A_direct()
    L = lagrange_matrix(f, sgrs.grs.alphas, sgrs.grs.betas)
    assert np.array_equal(A, L)


def test_cauchy_costs_match_theorem7():
    f = FERMAT
    sgrs = StructuredGRS.build(f, 32, 8)
    x = f.rand(8, RNG)
    out = {}
    net = RoundNetwork(8, 1)
    net.run(cauchy_a2a(sgrs, 0, {k: x[k] for k in range(8)}, list(range(8)), 1, out))
    assert (net.C1, net.C2) == cost_cauchy(sgrs, 0, 1)


@pytest.mark.parametrize("K,R,p", [(10, 4, 1), (4, 27, 1), (4, 16, 2), (6, 6, 1), (3, 10, 1)])
def test_nonsystematic(K, R, p):
    f = FERMAT
    G = f.rand((K, K + R), RNG)
    x = f.rand((K, 1), RNG)
    y, _ = nonsystematic_encode(f, G, x, p=p)
    assert np.array_equal(y, f.matmul(G.T, x))


def test_port_constraint_enforced():
    """The simulator rejects schedules that exceed p ports — with a real
    exception (`PortViolationError`), not an -O-strippable assert."""
    from repro.core.simulator import Msg, PortViolationError

    net = RoundNetwork(4, p=1)

    def bad():
        yield [Msg(0, 1, 1), Msg(0, 2, 1)]  # two sends from proc 0, p=1

    with pytest.raises(PortViolationError, match="port violation"):
        net.run(bad())
