"""CodedSystem backend-parity checks on 8 forced host devices (subprocess
companion of test_system.py — jax locks the device count at first init).

For every code kind, the session round-trip `encode -> fail -> read ->
heal -> encode` must produce bitwise-identical codewords, repaired
symbols, and degraded reads across all three built-in backends
("simulator", "local", "mesh"), the full `rebuild` (from the (N, W)
codeword AND from (K, W) kept survivors, streamed included) must
re-materialize the identical codeword on all three, and the mesh
backend's declared device requirement must be enforced at plan time.

Prints 'SYSTEM_MESH_CHECKS_OK' on success; any assertion failure is fatal.
"""
from _fake_devices import force_host_devices

force_host_devices(8)

import numpy as np

from repro.api import BackendCapabilityError, CodedSystem, CodeSpec

f_q = 65537
rng = np.random.default_rng(31)

cases = [
    ("universal", 8, 4, (0, 9)),
    ("rs", 8, 4, (2, 4, 11)),
    ("rs", 8, 8, (0, 2, 9, 13)),
    ("lagrange", 8, 4, (1, 10)),
    ("dft", 8, 8, (5, 9, 13)),
]
for kind, K, R, erased in cases:
    spec = CodeSpec(kind=kind, K=K, R=R, W=16,
                    seed=9 if kind == "universal" else None)
    x = rng.integers(0, f_q, (K, 16))
    outs = {}
    for backend in ("simulator", "local", "mesh"):
        system = CodedSystem(spec, backend=backend)
        cw = system.codeword(x)
        system.fail(erased)
        lost = system.decode(cw)
        data = system.read(cw)
        assert np.array_equal(data, x % f_q), (kind, backend, "read")
        assert np.array_equal(lost, cw[list(sorted(erased))]), \
            (kind, backend, "decode")
        system.heal()
        assert np.array_equal(system.encode(x), cw[K:]), \
            (kind, backend, "re-encode")
        # rebuild: recompute ALL failed symbols, return the healed (N, W)
        system.fail(erased)
        assert np.array_equal(system.rebuild(cw), cw), \
            (kind, backend, "rebuild")
        assert system.failed == ()
        system.fail(erased)
        assert np.array_equal(system.rebuild(cw[list(system.kept)]), cw), \
            (kind, backend, "rebuild from survivors")
        system.fail(erased)
        streamed = np.concatenate(
            list(system.rebuild_stream(cw, chunk_w=8)), axis=1)
        assert np.array_equal(streamed, cw), \
            (kind, backend, "rebuild_stream")
        assert system.failed == ()
        outs[backend] = (cw, lost, data)
    for backend in ("local", "mesh"):
        for ya, yb in zip(outs["simulator"], outs[backend]):
            assert np.array_equal(ya, yb), (kind, backend, "parity")
    print(f"{kind} K={K} R={R} erased={erased}: 3-backend round-trip OK")

# the mesh device requirement is a plan-time capability error on this
# 8-device topology, not a deep shard_map failure
try:
    CodedSystem(CodeSpec(kind="rs", K=16, R=4), backend="mesh")
except BackendCapabilityError as exc:
    assert "devices" in str(exc)
else:
    raise AssertionError("mesh K=16 on 8 devices must fail at plan time")

print("SYSTEM_MESH_CHECKS_OK")
